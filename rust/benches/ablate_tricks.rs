//! Ablation A2 (paper App. C.3): centralization and column-outlier
//! excluding, on/off, at 2.3 and 3.3 average bits.

use raana::experiments::tables::ablate_tricks;
use raana::experiments::Env;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("RAANA_BENCH_MODEL").unwrap_or_else(|_| "tiny".into());
    let cap = std::env::var("RAANA_BENCH_EVAL_CAP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);
    let env = Env::load(&model)?;
    println!("=== Ablation: quantization tricks (paper App. C.3, model {model}) ===");
    let t = ablate_tricks(&env, cap)?;
    println!("{}", t.render());
    Ok(())
}
