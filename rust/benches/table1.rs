//! Paper Table 1: perplexity on the wikitext2 analog, methods x bits.
//! Regenerates the same rows (fp reference, grouped baselines at 2+/3+/4+
//! bits, RaanA at x+0.1 / x+0.3) on the tiny model.

use raana::experiments::tables::{method_grid, Dataset};
use raana::experiments::Env;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("RAANA_BENCH_MODEL").unwrap_or_else(|_| "tiny".into());
    let cap = std::env::var("RAANA_BENCH_EVAL_CAP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);
    let env = Env::load(&model)?;
    println!("=== Table 1: perplexity on {} (model {model}) ===",
             Dataset::SynthWiki.name());
    let t = method_grid(&env, Dataset::SynthWiki, cap)?;
    println!("{}", t.render());
    Ok(())
}
