//! Kernel micro-benchmarks (EXPERIMENTS.md §Perf): the fused packed-code
//! serving stack vs the pre-kernel paths.
//!
//! * FWHT: single-thread loop vs the batched parallel `fwht_batch`.
//! * RaBitQ column quantization throughput (weights/s — compare the
//!   paper's ~21 M weights/s for a 70B model in ~3300 s on 2x EPYC).
//! * Algorithm-3 estimator: the old serial `matmul_est_serial` vs the
//!   fused `qgemm` vs a dense matmul over pre-dequantized weights (and the
//!   Pallas `qmatmul` HLO artifact when PJRT is available).
//! * Serve loop: native `fwd_logits` tokens/s, dense weights vs resident
//!   packed codes.
//! * Generation: KV-cached `prefill` + `decode_step` vs full-recompute
//!   per token at generation length 64 (`serve_kv` vs `serve_recompute`
//!   in the JSON; acceptance: >= 2x tokens/s).
//! * Worker pool: persistent-pool dispatch vs `std::thread::scope`
//!   spawn/join on an empty job, and the resulting serve-loop ratio
//!   (`spawn_join_overhead_us`, `serve_tokps_pool_ratio` in the JSON;
//!   acceptance: >= 1.5x at demo scale).
//! * Quantized-KV attention: `attend_cached_q` over 8/4/2-bit codes vs
//!   the dense `attend_cached` on the same window, plus the
//!   `kv_bytes_per_lane` table (f32 vs 8/4/2-bit) and the lane counts a
//!   fixed KV budget buys (acceptance: >= 2x lanes at 4-bit vs f32).
//! * Vector index: the two-phase top-10 query (`index_scan_q`: 8-bit
//!   coded scan + exact rerank) vs the brute-force `index_scan_f32`
//!   baseline at n=4096, d=256, with the scan-payload bytes-per-row
//!   table and the recall@10 acceptance numbers in the JSON.
//! * Durability seal: the pre-segment whole-store snapshot encode vs
//!   the segmented head-only seal (`seal_ms_monolithic` /
//!   `seal_ms_segmented` in §segments), plus the query p50 while a
//!   deliberately slowed seal is in flight
//!   (`query_p50_during_seal_us`) — the lock-split acceptance that
//!   reads never wait on sealing.
//! * Cluster: two worker nodes behind the consistent-hashing router on
//!   loopback — the per-request routing tax (`router_overhead_us`:
//!   routed generate minus direct generate) and two-phase top-10 query
//!   throughput through the scatter-gather path vs a single node
//!   holding the same rows (`scatter_gather_qps` / `single_node_qps`
//!   in §cluster).
//!
//! Results print as tables and land in `BENCH_kernels.json` so future PRs
//! can diff the perf trajectory mechanically. Dimensions honor
//! `RAANA_BENCH_QGEMM_DIM` (default 2048) and threads honor
//! `RAANA_THREADS`.

use raana::benchlib::{bench, bench_json, write_json_report, Table};
use raana::hadamard::{fwht, fwht_batch};
use raana::json::{self, Value};
use raana::kernels::qgemm;
use raana::model::artifacts_root;
use raana::rabitq::{QuantizedMatrix, ScaleMode};
use raana::rng::Rng;
use raana::runtime::{lit_f32, ModelRuntime, Runtime};
use raana::tensor::Matrix;
use raana::threadpool::default_threads;

fn env_dim(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    println!("=== Kernel micro-benchmarks ===");
    let threads = default_threads();
    let mut report: Vec<(&str, Value)> = vec![
        ("bench", json::s("kernels")),
        ("threads", json::num(threads as f64)),
    ];

    // ------------------------------------------------------ FWHT throughput
    let mut t = Table::new(&["FWHT d", "rows", "serial", "batched", "GB/s (batched)"]);
    let mut fwht_entries: Vec<(&str, Value)> = Vec::new();
    for (key, d) in [("d256", 256usize), ("d1024", 1024), ("d4096", 4096)] {
        let rows = (1 << 22) / d; // ~16 MiB working set
        let mut data = Rng::new(1).gaussian_vec(rows * d);
        let serial = bench(&format!("fwht_{d}_serial"), 2, 8, || {
            for row in data.chunks_mut(d) {
                fwht(row);
            }
        });
        let batched = bench(&format!("fwht_{d}_batch"), 2, 8, || {
            fwht_batch(&mut data, d, threads);
        });
        let bytes = (rows * d * 4) as f64;
        t.row(vec![
            d.to_string(),
            rows.to_string(),
            format!("{:.2} ms", serial.median() * 1e3),
            format!("{:.2} ms", batched.median() * 1e3),
            format!("{:.2}", bytes / batched.median() / 1e9),
        ]);
        fwht_entries.push((
            key,
            json::obj(vec![
                ("serial", bench_json(&serial)),
                ("batched", bench_json(&batched)),
            ]),
        ));
    }
    println!("{}", t.render());
    report.push(("fwht", json::obj(fwht_entries)));

    // --------------------------------------- RaBitQ quantization throughput
    let mut t = Table::new(&["RaBitQ d x c", "bits", "mode", "median", "Mweights/s"]);
    let mut quant_entries: Vec<(&str, Value)> = Vec::new();
    for &(d, c) in &[(1024usize, 1024usize)] {
        let w = Matrix::from_vec(d, c, Rng::new(2).gaussian_vec(d * c));
        for (mode, name) in [(ScaleMode::MaxAbs, "maxabs"), (ScaleMode::Search(8), "search8")] {
            for bits in [2u8, 4] {
                let r = bench(&format!("rabitq_{name}_{bits}"), 1, 5, || {
                    std::hint::black_box(QuantizedMatrix::quantize(&w, bits, mode, threads));
                });
                t.row(vec![
                    format!("{d}x{c}"),
                    bits.to_string(),
                    name.into(),
                    format!("{:.1} ms", r.median() * 1e3),
                    format!("{:.1}", (d * c) as f64 / r.median() / 1e6),
                ]);
                if name == "maxabs" && bits == 4 {
                    quant_entries.push(("maxabs_b4_1024", bench_json(&r)));
                }
            }
        }
    }
    println!("{}", t.render());
    report.push(("rabitq_quantize", json::obj(quant_entries)));

    // ------------------------------------------- Algorithm-3 estimator paths
    // the ISSUE 1 acceptance shape: d = c = 2048, n = 128, 4-bit codes
    let big = env_dim("RAANA_BENCH_QGEMM_DIM", 2048);
    let mut qgemm_entries: Vec<(&str, Value)> = Vec::new();
    for (key, n, d, c, bits) in [
        ("n128_d256_c256_b4", 128usize, 256usize, 256usize, 4u8),
        ("n128_big_b4", 128, big, big, 4),
    ] {
        let v = Matrix::from_vec(d, c, Rng::new(3).gaussian_vec(d * c));
        let x = Matrix::from_vec(n, d, Rng::new(4).gaussian_vec(n * d));
        let qm = QuantizedMatrix::quantize(&v, bits, ScaleMode::MaxAbs, threads);
        let dense = qm.dequantize();

        let title = format!("Alg.3 path (n={n} d={d} c={c} b={bits})");
        let mut t = Table::new(&[title.as_str(), "median", "note"]);
        let serial = bench("est_serial", 1, 3, || {
            std::hint::black_box(qm.matmul_est_serial(&x));
        });
        t.row(vec![
            "old serial matmul_est".into(),
            format!("{:.2} ms", serial.median() * 1e3),
            "per-column unpack, f64 dots, 1 thread".into(),
        ]);
        let fused = bench("qgemm", 2, 8, || {
            std::hint::black_box(qgemm(&x, &qm, threads));
        });
        t.row(vec![
            "fused qgemm".into(),
            format!("{:.2} ms", fused.median() * 1e3),
            format!("tiled decode, {threads} threads"),
        ]);
        let dense_mm = bench("dense", 2, 8, || {
            std::hint::black_box(x.matmul(&dense));
        });
        t.row(vec![
            "dense matmul (pre-dequantized)".into(),
            format!("{:.2} ms", dense_mm.median() * 1e3),
            "excludes the dequantize cost".into(),
        ]);
        let speedup = serial.median() / fused.median().max(1e-12);
        t.row(vec![
            "qgemm speedup vs serial".into(),
            format!("{speedup:.1}x"),
            "acceptance: >= 3x at d=c=2048, n=128".into(),
        ]);
        println!("{}", t.render());

        qgemm_entries.push((
            key,
            json::obj(vec![
                ("n", json::num(n as f64)),
                ("d", json::num(d as f64)),
                ("c", json::num(c as f64)),
                ("bits", json::num(bits as f64)),
                ("serial", bench_json(&serial)),
                ("qgemm", bench_json(&fused)),
                ("dense", bench_json(&dense_mm)),
                ("speedup_vs_serial", json::num(speedup)),
            ]),
        ));

        // Pallas qmatmul HLO artifact comparison (PJRT builds only)
        if n == 128 && d == 256 {
            if let Ok(rt) = Runtime::cpu() {
                let path = artifacts_root()
                    .join("kernels")
                    .join(format!("qmatmul_{n}x{d}x{c}_b{bits}.hlo.txt"));
                if path.exists() {
                    let art = rt.load(&path)?;
                    let unpacked = qm.codes.unpack();
                    let mut codes_f32 = vec![0f32; d * c];
                    for j in 0..c {
                        for i in 0..d {
                            codes_f32[i * c + j] = unpacked[j * d + i] as f32;
                        }
                    }
                    let inputs = [
                        lit_f32(&x.data, &[n, d])?,
                        lit_f32(&codes_f32, &[d, c])?,
                        lit_f32(&qm.r, &[c])?,
                    ];
                    let r = bench("pallas_artifact", 2, 10, || {
                        std::hint::black_box(art.run(&inputs).unwrap());
                    });
                    println!(
                        "Pallas qmatmul artifact (PJRT): {:.2} ms median",
                        r.median() * 1e3
                    );
                }
            }
        }
    }
    report.push(("qgemm", json::obj(qgemm_entries)));

    // ------------------------------------------------- serve-loop tokens/s
    // native fwd_logits over a tiny-sized model: dense weights vs resident
    // packed codes — the request path the batching server runs.
    let (manifest, params, packed) =
        raana::experiments::native_demo_packed("bench-serve", 256, 4, 4, 7)?;
    let batch = manifest.eval_batch;
    let tokens: Vec<i32> = (0..batch * manifest.seq_len)
        .map(|i| (i * 31 % 256) as i32)
        .collect();

    let mrt_dense = ModelRuntime::native(manifest.clone())?;
    let dense_r = bench("serve_dense", 1, 4, || {
        std::hint::black_box(mrt_dense.last_logits(&params, &tokens).unwrap());
    });
    let mut mrt_packed = ModelRuntime::native(manifest.clone())?;
    mrt_packed.attach_packed(packed)?;
    let packed_r = bench("serve_packed", 1, 4, || {
        std::hint::black_box(mrt_packed.last_logits(&params, &tokens).unwrap());
    });
    let dense_tok_s = batch as f64 / dense_r.median();
    let packed_tok_s = batch as f64 / packed_r.median();
    let mut t = Table::new(&["Serve fwd_logits (B=8, S=128, tiny dims)", "median", "tok/s"]);
    t.row(vec![
        "native dense weights".into(),
        format!("{:.1} ms", dense_r.median() * 1e3),
        format!("{dense_tok_s:.1}"),
    ]);
    t.row(vec![
        "native packed codes (qgemm)".into(),
        format!("{:.1} ms", packed_r.median() * 1e3),
        format!("{packed_tok_s:.1}"),
    ]);
    println!("{}", t.render());
    report.push((
        "serve",
        json::obj(vec![
            ("batch", json::num(batch as f64)),
            ("seq_len", json::num(manifest.seq_len as f64)),
            ("dense", bench_json(&dense_r)),
            ("packed", bench_json(&packed_r)),
            ("dense_tok_s", json::num(dense_tok_s)),
            ("packed_tok_s", json::num(packed_tok_s)),
        ]),
    ));

    // ------------------------------ KV-cached generation vs recompute
    // single-stream generation on the demo model: prefill + decode_step
    // (cached K/V, one row per token) vs recomputing the whole window per
    // token — the per-token serve cost before this existed. Greedy
    // sampling so both paths walk the identical token sequence.
    fn argmax(logits: &[f32]) -> i32 {
        raana::util::argmax(logits) as i32
    }
    let (gen_len, prompt_len) = (64usize, 32usize);
    let prompt: Vec<i32> = (0..prompt_len).map(|i| (i * 17 % 256) as i32).collect();
    let mut cache = mrt_packed.new_kv_cache(1);
    let kv_r = bench("serve_kv", 1, 4, || {
        let mut logits = mrt_packed.prefill(&params, &mut cache, 0, &prompt).unwrap();
        for _ in 0..gen_len - 1 {
            let tok = argmax(&logits);
            logits = mrt_packed
                .decode_step(&params, &mut cache, &[0], &[tok])
                .unwrap();
        }
        std::hint::black_box(&logits);
    });
    let rec_r = bench("serve_recompute", 1, 4, || {
        let mut ctx = prompt.clone();
        let mut logits = mrt_packed.last_logits_ctx(&params, &ctx).unwrap();
        for _ in 0..gen_len - 1 {
            ctx.push(argmax(&logits));
            logits = mrt_packed.last_logits_ctx(&params, &ctx).unwrap();
        }
        std::hint::black_box(&logits);
    });
    let kv_tok_s = gen_len as f64 / kv_r.median();
    let rec_tok_s = gen_len as f64 / rec_r.median();
    let kv_speedup = rec_r.median() / kv_r.median().max(1e-12);
    let mut t = Table::new(&[
        "Generation (prompt=32, gen=64, packed demo model)",
        "median",
        "tok/s",
    ]);
    t.row(vec![
        "recompute per token (last_logits_ctx)".into(),
        format!("{:.1} ms", rec_r.median() * 1e3),
        format!("{rec_tok_s:.1}"),
    ]);
    t.row(vec![
        "KV cached (prefill + decode_step)".into(),
        format!("{:.1} ms", kv_r.median() * 1e3),
        format!("{kv_tok_s:.1}"),
    ]);
    t.row(vec![
        "serve_kv speedup".into(),
        format!("{kv_speedup:.1}x"),
        "acceptance: >= 2x at gen length 64".into(),
    ]);
    println!("{}", t.render());
    report.push((
        "serve_recompute",
        json::obj(vec![
            ("prompt_len", json::num(prompt_len as f64)),
            ("gen_len", json::num(gen_len as f64)),
            ("gen", bench_json(&rec_r)),
            ("tok_s", json::num(rec_tok_s)),
        ]),
    ));
    report.push((
        "serve_kv",
        json::obj(vec![
            ("prompt_len", json::num(prompt_len as f64)),
            ("gen_len", json::num(gen_len as f64)),
            ("gen", bench_json(&kv_r)),
            ("tok_s", json::num(kv_tok_s)),
            ("speedup_vs_recompute", json::num(kv_speedup)),
        ]),
    ));

    // -------------------------- worker pool vs scoped spawn/join tax
    // ISSUE 7: every parallel kernel call used to spawn and join scoped
    // OS threads; the persistent pool hands the same index ranges to
    // parked workers instead. Measure both dispatch costs head to head
    // on an empty job, then convert the per-call delta into the serve
    // ratio: a decode step on the demo model crosses one pool barrier
    // per linear (6 per layer) plus the logit projection, so the scoped
    // equivalent of the measured pooled step is
    // `step + barriers * (scoped - pool)`.
    {
        use raana::threadpool::parallel_for;
        let idxs: Vec<usize> = (0..threads).collect();
        let pool_r = bench("pool_dispatch", 8, 256, || {
            parallel_for(&idxs, threads, |_, _| {
                std::hint::black_box(());
            });
        });
        let scoped_r = bench("scoped_spawn_join", 8, 256, || {
            std::thread::scope(|s| {
                for _ in 0..threads.saturating_sub(1) {
                    s.spawn(|| std::hint::black_box(()));
                }
            });
        });
        let overhead_s = (scoped_r.median() - pool_r.median()).max(0.0);
        let barriers = 6 * manifest.n_layers + 1;
        let step_s = kv_r.median() / gen_len as f64;
        let scoped_step_s = step_s + barriers as f64 * overhead_s;
        let pool_ratio = scoped_step_s / step_s.max(1e-12);

        let mut t = Table::new(&["Worker pool dispatch", "median", "note"]);
        t.row(vec![
            "persistent pool (parallel_for, empty job)".into(),
            format!("{:.1} us", pool_r.median() * 1e6),
            format!("{threads} threads, warm workers"),
        ]);
        t.row(vec![
            "std::thread::scope spawn + join".into(),
            format!("{:.1} us", scoped_r.median() * 1e6),
            "the pre-pool per-call cost".into(),
        ]);
        t.row(vec![
            "serve tok/s ratio, pooled vs scoped".into(),
            format!("{pool_ratio:.2}x"),
            format!("{barriers} barriers/decode step; acceptance: >= 1.5x"),
        ]);
        println!("{}", t.render());
        report.push((
            "pool",
            json::obj(vec![
                ("threads", json::num(threads as f64)),
                ("pool_dispatch", bench_json(&pool_r)),
                ("scoped_spawn_join", bench_json(&scoped_r)),
                ("spawn_join_overhead_us", json::num(overhead_s * 1e6)),
                ("barriers_per_decode_step", json::num(barriers as f64)),
                ("serve_tokps_pool", json::num(kv_tok_s)),
                ("serve_tokps_scoped_equiv", json::num(1.0 / scoped_step_s.max(1e-12))),
                ("serve_tokps_pool_ratio", json::num(pool_ratio)),
            ]),
        ));
    }

    // ------------------ quantized-KV attention + lanes-per-byte economics
    // attend_cached_q (scores + mixing straight over RaBitQ codes) vs the
    // dense f32 attend_cached on the same 128-row window, and the
    // kv_bytes_per_lane table that converts a KV RAM budget into lanes —
    // the acceptance number is >= 2x lanes at 4-bit vs f32.
    {
        use raana::kernels::attend_cached;
        use raana::kvq::{dense_bytes_per_lane, KvqPlan, QuantizedKvStore, DEFAULT_ROT_SEED};

        let (heads, hd, ctx) = (4usize, 64usize, 128usize);
        let d = heads * hd;
        let mut rng = Rng::new(12);
        let q = rng.gaussian_vec(d);
        let krows = rng.gaussian_vec(ctx * d);
        let vrows = rng.gaussian_vec(ctx * d);

        let mut t = Table::new(&[
            "Cached attention (ctx=128, d=256, 4 heads)",
            "median",
            "note",
        ]);
        let mut scores = vec![0f32; ctx];
        let mut out = vec![0f32; d];
        let dense_r = bench("attend_cached", 4, 64, || {
            out.iter_mut().for_each(|x| *x = 0.0);
            attend_cached(&q, &krows, &vrows, ctx, heads, hd, &mut scores, &mut out);
            std::hint::black_box(&out);
        });
        t.row(vec![
            "attend_cached (dense f32 rows)".into(),
            format!("{:.1} us", dense_r.median() * 1e6),
            "the PR-2 kernel".into(),
        ]);
        let mut kvq_entries: Vec<(&str, Value)> = vec![("attend_dense", bench_json(&dense_r))];
        for bits in [8u8, 4, 2] {
            // the real serving path: rows quantized+packed by the store,
            // attention via attend_cached_q over its codes
            let plan = KvqPlan::uniform(1, bits).expect("valid bits");
            let mut store =
                QuantizedKvStore::new(1, 1, ctx, d, heads, plan, DEFAULT_ROT_SEED)
                    .expect("valid store shape");
            for ki in 0..ctx {
                store.store_row(0, 0, ki, &krows[ki * d..(ki + 1) * d],
                                &vrows[ki * d..(ki + 1) * d]);
            }
            let mut scratch = store.scratch();
            let mut qout = vec![0f32; d];
            let r = bench(&format!("attend_cached_q_b{bits}"), 4, 64, || {
                qout.iter_mut().for_each(|x| *x = 0.0);
                store.attend(0, 0, ctx, &q, &mut scratch, &mut qout);
                std::hint::black_box(&qout);
            });
            t.row(vec![
                format!("attend_cached_q ({bits}-bit codes)"),
                format!("{:.1} us", r.median() * 1e6),
                format!("{:.2}x dense", r.median() / dense_r.median().max(1e-12)),
            ]);
            match bits {
                8 => kvq_entries.push(("attend_q8", bench_json(&r))),
                4 => kvq_entries.push(("attend_q4", bench_json(&r))),
                _ => kvq_entries.push(("attend_q2", bench_json(&r))),
            }
        }
        println!("{}", t.render());

        // lanes-per-byte: the memory -> concurrency conversion
        let (nl, cap) = (4usize, 128usize);
        let dense_lane = dense_bytes_per_lane(nl, cap, d);
        let budget = 16 * dense_lane; // sized for exactly 16 f32 lanes
        let mut t = Table::new(&[
            "KV bytes/lane (4 layers, ctx 128, d=256)",
            "bytes",
            "lanes @ same budget",
        ]);
        t.row(vec!["f32".into(), dense_lane.to_string(), "16".to_string()]);
        let mut lane_entries: Vec<(&str, Value)> =
            vec![("f32", json::num(dense_lane as f64))];
        let mut lanes_4bit = 0usize;
        for (key, bits) in [("b8", 8u8), ("b4", 4), ("b2", 2)] {
            let lane = KvqPlan::uniform(nl, bits)
                .expect("valid bits")
                .bytes_per_lane(cap, d, heads);
            let lanes = budget / lane;
            if bits == 4 {
                lanes_4bit = lanes;
            }
            t.row(vec![format!("{bits}-bit"), lane.to_string(), lanes.to_string()]);
            lane_entries.push((key, json::num(lane as f64)));
        }
        println!("{}", t.render());
        let ratio = lanes_4bit as f64 / 16.0;
        println!("lanes at 4-bit vs f32 under the same budget: {ratio:.1}x (acceptance: >= 2x)");
        kvq_entries.push(("kv_bytes_per_lane", json::obj(lane_entries)));
        kvq_entries.push(("budget_bytes", json::num(budget as f64)));
        kvq_entries.push(("lanes_f32", json::num(16.0)));
        kvq_entries.push(("lanes_4bit", json::num(lanes_4bit as f64)));
        kvq_entries.push(("lanes_ratio_4bit_vs_f32", json::num(ratio)));
        report.push(("kvq", json::obj(kvq_entries)));
    }

    // --------------------- vector-index scan QPS + bytes-per-row economics
    // the retrieval subsystem's two-phase query (estimated scan over
    // packed codes + exact rerank) vs the brute-force f32 baseline at
    // n=4096, d=256, and the scan-payload bytes-per-row table. The two
    // acceptance numbers land in the JSON: recall@10 at 8-bit with
    // rerank_factor 4 (>= 0.95) and the 8-bit bytes-per-row ratio vs
    // f32 (<= 1/3).
    {
        use raana::index::{IndexConfig, IndexPolicy, VectorStore, DEFAULT_RERANK_FACTOR};

        let (n, d, k) = (4096usize, 256usize, 10usize);
        let mut store = VectorStore::new(IndexConfig {
            policy: IndexPolicy::Uniform(8),
            ..Default::default()
        })?;
        store.add("bench", &Rng::new(20).gaussian_vec(n * d), d, threads)?;
        let c = store.get("bench")?;
        let queries: Vec<Vec<f32>> =
            (0..32).map(|i| Rng::new(300 + i).gaussian_vec(d)).collect();

        // recall@10 of the two-phase query vs the exact baseline
        let mut hits = 0usize;
        for q in &queries {
            let got = c.query(q, k, DEFAULT_RERANK_FACTOR, threads)?;
            let want: Vec<usize> =
                c.brute_force(q, k, threads)?.iter().map(|h| h.id).collect();
            hits += got.iter().filter(|h| want.contains(&h.id)).count();
        }
        let recall = hits as f64 / (queries.len() * k) as f64;

        let q0 = &queries[0];
        let scan_q = bench("index_scan_q", 2, 16, || {
            std::hint::black_box(c.query(q0, k, DEFAULT_RERANK_FACTOR, threads).unwrap());
        });
        let scan_f32 = bench("index_scan_f32", 2, 16, || {
            std::hint::black_box(c.brute_force(q0, k, threads).unwrap());
        });
        let qps_q = 1.0 / scan_q.median().max(1e-12);
        let qps_f32 = 1.0 / scan_f32.median().max(1e-12);

        let mut t = Table::new(&[
            "Index top-10 (n=4096, d=256, cosine)",
            "median",
            "QPS",
        ]);
        t.row(vec![
            "index_scan_q (8-bit codes + rerank x4)".into(),
            format!("{:.2} ms", scan_q.median() * 1e3),
            format!("{qps_q:.0}"),
        ]);
        t.row(vec![
            "index_scan_f32 (brute-force exact)".into(),
            format!("{:.2} ms", scan_f32.median() * 1e3),
            format!("{qps_f32:.0}"),
        ]);
        t.row(vec![
            "recall@10 of the two-phase query".into(),
            format!("{recall:.4}"),
            "acceptance: >= 0.95".into(),
        ]);
        println!("{}", t.render());

        // scan-payload bytes per row: f32 baseline vs 8/4/2-bit codes
        let f32_row = 4 * d;
        let mut t = Table::new(&["Index bytes/row (d=256)", "bytes", "vs f32"]);
        t.row(vec!["f32".into(), f32_row.to_string(), "1.00".to_string()]);
        let mut lane_entries: Vec<(&str, Value)> =
            vec![("f32", json::num(f32_row as f64))];
        let mut ratio_8bit = 0f64;
        for (key, bits) in [("b8", 8u8), ("b4", 4), ("b2", 2)] {
            let row = (d * bits as usize).div_ceil(8) + 4;
            let ratio = row as f64 / f32_row as f64;
            if bits == 8 {
                ratio_8bit = ratio;
            }
            t.row(vec![format!("{bits}-bit"), row.to_string(), format!("{ratio:.3}")]);
            lane_entries.push((key, json::num(row as f64)));
        }
        println!("{}", t.render());
        println!(
            "index acceptance: recall@10 {recall:.4} (>= 0.95) at {:.3}x the f32 \
             bytes/row (<= 1/3)",
            ratio_8bit
        );

        report.push((
            "index",
            json::obj(vec![
                ("n", json::num(n as f64)),
                ("d", json::num(d as f64)),
                ("k", json::num(k as f64)),
                ("rerank_factor", json::num(DEFAULT_RERANK_FACTOR as f64)),
                ("scan_q", bench_json(&scan_q)),
                ("scan_f32", bench_json(&scan_f32)),
                ("qps_q", json::num(qps_q)),
                ("qps_f32", json::num(qps_f32)),
                ("recall_at10_8bit", json::num(recall)),
                ("bytes_per_row", json::obj(lane_entries)),
                ("bytes_per_row_ratio_8bit", json::num(ratio_8bit)),
            ]),
        ));
    }

    // ------------------- segmented seal cost + query latency mid-seal
    // ISSUE 8: the old durability layer re-encoded EVERY row of every
    // collection on each cadence snapshot; sealing now writes only the
    // mutable head as an immutable segment plus a small manifest, and
    // the RwLock split lets queries run while the seal's file I/O is in
    // flight. Three numbers: the monolithic whole-store encode, the
    // real segmented seal path (append one head batch + seal_now on a
    // durable store over MemIo), and the query p50 while a deliberately
    // slowed seal holds the durability engine.
    {
        use raana::index::durability::{DurabilityConfig, DurableStore, FsyncPolicy};
        use raana::index::io::{Fault, FaultIo, MemIo};
        use raana::index::snapshot::encode_snapshot;
        use raana::index::{IndexConfig, IndexPolicy, VectorStore, DEFAULT_RERANK_FACTOR};
        use raana::util::percentile;

        let (n_sealed, n_head, d) = (8192usize, 256usize, 256usize);
        let icfg =
            || IndexConfig { policy: IndexPolicy::Uniform(8), ..Default::default() };
        let dcfg = || DurabilityConfig {
            data_dir: std::path::PathBuf::from("/bench"),
            fsync: FsyncPolicy::Never,
            snapshot_every: 0,
            segment_rows: 0,
        };
        let sealed_rows = Rng::new(40).gaussian_vec(n_sealed * d);
        let head_rows = Rng::new(41).gaussian_vec(n_head * d);

        // the pre-segment cadence cost: serialize the whole store
        let mut mono = VectorStore::new(icfg())?;
        mono.add("bench", &sealed_rows, d, threads)?;
        let mono_r = bench("seal_monolithic", 1, 8, || {
            std::hint::black_box(encode_snapshot(&mono, 0));
        });

        // the segmented cost: append a head batch, seal it — O(head)
        let durable = DurableStore::open_with(icfg(), dcfg(), Box::new(MemIo::new()))?;
        durable.add("bench", &sealed_rows, d, threads)?;
        durable.seal_now()?;
        let seg_r = bench("seal_segmented", 1, 8, || {
            durable.add("bench", &head_rows, d, threads).unwrap();
            durable.seal_now().unwrap();
        });

        // query latency while a seal is in flight: SlowWrite stalls the
        // seal's segment write (write 2 — the add's WAL append is
        // write 1) for 300 ms; the store read lock stays free, so the
        // queries below must keep completing at their normal latency
        let slow = DurableStore::open_with(
            icfg(),
            dcfg(),
            Box::new(FaultIo::new(MemIo::new(), Fault::SlowWrite { nth: 2, millis: 300 })),
        )?;
        slow.add("bench", &sealed_rows, d, threads)?;
        let q = Rng::new(42).gaussian_vec(d);
        let done = std::sync::atomic::AtomicBool::new(false);
        let mut lat_us: Vec<f64> = Vec::new();
        std::thread::scope(|s| {
            s.spawn(|| {
                slow.seal_now().unwrap();
                done.store(true, std::sync::atomic::Ordering::SeqCst);
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            while !done.load(std::sync::atomic::Ordering::SeqCst) {
                let t0 = std::time::Instant::now();
                std::hint::black_box(
                    slow.query("bench", &q, 10, DEFAULT_RERANK_FACTOR, threads).unwrap(),
                );
                lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
            }
        });
        let p50_us = percentile(&lat_us, 50.0);

        let mono_ms = mono_r.median() * 1e3;
        let seg_ms = seg_r.median() * 1e3;
        let speedup = mono_ms / seg_ms.max(1e-12);
        let mut t = Table::new(&[
            "Durability seal (8192 sealed rows, 256-row head, d=256)",
            "median",
            "note",
        ]);
        t.row(vec![
            "monolithic snapshot (whole-store encode)".into(),
            format!("{mono_ms:.2} ms"),
            "the pre-segment per-cadence cost".into(),
        ]);
        t.row(vec![
            "segmented seal (add head + seal_now)".into(),
            format!("{seg_ms:.2} ms"),
            format!("{speedup:.1}x; O(head), includes the head quantize"),
        ]);
        t.row(vec![
            "query p50 during a 300 ms-stalled seal".into(),
            format!("{p50_us:.0} us"),
            format!("{} queries completed mid-seal", lat_us.len()),
        ]);
        println!("{}", t.render());
        report.push((
            "segments",
            json::obj(vec![
                ("n_sealed", json::num(n_sealed as f64)),
                ("n_head", json::num(n_head as f64)),
                ("d", json::num(d as f64)),
                ("seal_monolithic", bench_json(&mono_r)),
                ("seal_segmented", bench_json(&seg_r)),
                ("seal_ms_monolithic", json::num(mono_ms)),
                ("seal_ms_segmented", json::num(seg_ms)),
                ("seal_speedup", json::num(speedup)),
                ("query_p50_during_seal_us", json::num(p50_us)),
                ("queries_during_seal", json::num(lat_us.len() as f64)),
            ]),
        ));
    }

    // ------------------------------ HTTP front-end overhead vs in-process
    // same packed demo model behind the batching server; one greedy
    // request of gen_len tokens, submitted in-process (Server::submit)
    // vs over the loopback HTTP API. The delta is the full front-end tax:
    // socket, request parse, JSON response — per *request*, so it
    // amortizes over generation length.
    let (manifest, params, packed) =
        raana::experiments::native_demo_packed("bench-serve-http", 256, 4, 4, 7)?;
    let server = std::sync::Arc::new(raana::serve::Server::start_native_packed(
        manifest, params, packed,
    )?);
    let http = raana::net::HttpServer::bind(std::sync::Arc::clone(&server), "127.0.0.1:0", 2)?;
    let addr = http.local_addr().to_string();
    let http_gen = 32usize;
    let prompt: Vec<i32> = (0..16).map(|i| (i * 13 % 256) as i32).collect();
    // baseline rides submit_streaming like the HTTP handler does, so the
    // measured delta is purely the network front-end (socket + parse +
    // serialize), not the per-token event channel both paths share
    let inproc_r = bench("serve_inprocess", 1, 8, || {
        let handle = server.submit_streaming(prompt.clone(), http_gen, 0.0, 0).unwrap();
        let mut done = None;
        for ev in handle.events.iter() {
            if let raana::serve::StreamEvent::Done(c) = ev {
                done = Some(c);
                break;
            }
        }
        std::hint::black_box(done.expect("stream must complete"));
    });
    let body = format!(
        "{{\"prompt\":{:?},\"max_new_tokens\":{http_gen}}}",
        prompt
    );
    let http_r = bench("serve_http", 1, 8, || {
        let resp = raana::net::http_request(&addr, "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(resp.status, 200);
        std::hint::black_box(resp.body.len());
    });
    http.shutdown()?;
    let server = match std::sync::Arc::try_unwrap(server) {
        Ok(s) => s,
        Err(_) => anyhow::bail!("HTTP layer still holds the server"),
    };
    server.shutdown()?;
    let overhead_ms = (http_r.median() - inproc_r.median()) * 1e3;
    let overhead_frac = (http_r.median() - inproc_r.median()) / inproc_r.median().max(1e-12);
    let mut t = Table::new(&[
        "Serving front-end (gen=32, packed demo model)",
        "median",
        "tok/s",
    ]);
    t.row(vec![
        "in-process Server::submit".into(),
        format!("{:.1} ms", inproc_r.median() * 1e3),
        format!("{:.1}", http_gen as f64 / inproc_r.median()),
    ]);
    t.row(vec![
        "HTTP POST /v1/generate (loopback)".into(),
        format!("{:.1} ms", http_r.median() * 1e3),
        format!("{:.1}", http_gen as f64 / http_r.median()),
    ]);
    t.row(vec![
        "front-end overhead per request".into(),
        format!("{overhead_ms:.2} ms"),
        format!("{:.1}%", overhead_frac * 100.0),
    ]);
    println!("{}", t.render());
    report.push((
        "serve_http",
        json::obj(vec![
            ("gen_len", json::num(http_gen as f64)),
            ("prompt_len", json::num(prompt.len() as f64)),
            ("http", bench_json(&http_r)),
            ("inprocess", bench_json(&inproc_r)),
            ("overhead_ms", json::num(overhead_ms)),
            ("overhead_frac", json::num(overhead_frac)),
        ]),
    ));

    // -------------------------- cluster router tax + scatter-gather QPS
    // two full worker nodes behind the consistent-hashing router, all on
    // loopback. Two numbers land in the JSON: `router_overhead_us` (the
    // per-request tax of the extra hop: routed generate minus direct
    // generate) and `scatter_gather_qps` (two-phase top-10 queries/s
    // through the router over a 2-way sharded collection, with the
    // single-node direct QPS alongside for the fan-out tax).
    {
        use raana::cluster::{Router, RouterConfig};
        use raana::net::{http_request, ClientConfig, HttpConfig, HttpServer};
        use raana::serve::index::IndexServer;
        use raana::serve::Server;
        use std::sync::Arc;

        let mk_worker = |seed: u64| -> anyhow::Result<(Arc<Server>, HttpServer, String)> {
            let (manifest, params, packed) =
                raana::experiments::native_demo_packed("bench-cluster", 256, 2, 4, seed)?;
            let index = Arc::new(IndexServer::with_embedder(
                raana::index::IndexConfig::default(),
                None,
                manifest.clone(),
                params.clone(),
                Some(packed.clone()),
            )?);
            let server = Arc::new(Server::start_native_packed(manifest, params, packed)?);
            let http = HttpServer::bind_with_index(
                Arc::clone(&server),
                Some(index),
                "127.0.0.1:0",
                HttpConfig { workers: 2, ..Default::default() },
            )?;
            let addr = format!("127.0.0.1:{}", http.local_addr().port());
            Ok((server, http, addr))
        };
        let (s0, h0, a0) = mk_worker(7)?;
        let (s1, h1, a1) = mk_worker(7)?;
        let router = Router::bind(
            "127.0.0.1:0",
            RouterConfig {
                workers: vec![a0.clone(), a1.clone()],
                client: ClientConfig::timeout_ms(5000),
                ..Default::default()
            },
        )?;
        let ra = format!("127.0.0.1:{}", router.local_addr().port());

        let gen_body = "{\"prompt\":[1,2,3],\"max_new_tokens\":8}";
        let direct_r = bench("cluster_gen_direct", 1, 8, || {
            let resp = http_request(&a0, "POST", "/v1/generate", Some(gen_body)).unwrap();
            assert_eq!(resp.status, 200);
            std::hint::black_box(resp.body.len());
        });
        let routed_r = bench("cluster_gen_routed", 1, 8, || {
            let resp = http_request(&ra, "POST", "/v1/generate", Some(gen_body)).unwrap();
            assert_eq!(resp.status, 200);
            std::hint::black_box(resp.body.len());
        });
        let router_overhead_us = (routed_r.median() - direct_r.median()) * 1e6;

        // sharded collection via the router; identical rows whole on one
        // worker for the single-node baseline
        // sized so the one-shot JSON add body stays under MAX_BODY_BYTES
        let (rows, d) = (1024usize, 32usize);
        let data = Rng::new(11).gaussian_vec(rows * d);
        let row_json = |r: &[f32]| {
            let vals: Vec<String> = r.iter().map(|x| format!("{x}")).collect();
            format!("[{}]", vals.join(","))
        };
        let all: Vec<String> = data.chunks_exact(d).map(row_json).collect();
        let body = format!("{{\"vectors\":[{}]}}", all.join(","));
        let resp = http_request(&ra, "POST", "/v1/collections/fleet/add", Some(&body))?;
        anyhow::ensure!(resp.status == 200, "cluster add failed: {}", resp.status);
        let resp = http_request(&a0, "POST", "/v1/collections/solo/add", Some(&body))?;
        anyhow::ensure!(resp.status == 200, "solo add failed: {}", resp.status);

        let q = Rng::new(12).gaussian_vec(d);
        let sg_body = format!("{{\"vector\":{},\"k\":10}}", row_json(&q));
        let solo_r = bench("cluster_query_single", 1, 16, || {
            let resp = http_request(
                &a0,
                "POST",
                "/v1/collections/solo/query",
                Some(&sg_body),
            )
            .unwrap();
            assert_eq!(resp.status, 200);
            std::hint::black_box(resp.body.len());
        });
        let sg_r = bench("cluster_query_scatter", 1, 16, || {
            let resp = http_request(
                &ra,
                "POST",
                "/v1/collections/fleet/query",
                Some(&sg_body),
            )
            .unwrap();
            assert_eq!(resp.status, 200);
            std::hint::black_box(resp.body.len());
        });
        router.shutdown()?;
        for (s, h) in [(s0, h0), (s1, h1)] {
            h.shutdown()?;
            match Arc::try_unwrap(s) {
                Ok(s) => {
                    s.shutdown()?;
                }
                Err(_) => anyhow::bail!("HTTP layer still holds a cluster worker"),
            }
        }
        let scatter_gather_qps = 1.0 / sg_r.median().max(1e-12);
        let single_node_qps = 1.0 / solo_r.median().max(1e-12);

        let mut t = Table::new(&[
            "Cluster (2 workers, loopback)",
            "median",
            "throughput",
        ]);
        t.row(vec![
            "generate direct to worker".into(),
            format!("{:.2} ms", direct_r.median() * 1e3),
            String::new(),
        ]);
        t.row(vec![
            "generate via router".into(),
            format!("{:.2} ms", routed_r.median() * 1e3),
            format!("+{router_overhead_us:.0} us/req"),
        ]);
        t.row(vec![
            format!("top-10 query, single node (n={rows})"),
            format!("{:.2} ms", solo_r.median() * 1e3),
            format!("{single_node_qps:.0} qps"),
        ]);
        t.row(vec![
            "top-10 query, scatter-gather (2 shards)".into(),
            format!("{:.2} ms", sg_r.median() * 1e3),
            format!("{scatter_gather_qps:.0} qps"),
        ]);
        println!("{}", t.render());
        report.push((
            "cluster",
            json::obj(vec![
                ("workers", json::num(2.0)),
                ("rows", json::num(rows as f64)),
                ("d", json::num(d as f64)),
                ("gen_direct", bench_json(&direct_r)),
                ("gen_routed", bench_json(&routed_r)),
                ("router_overhead_us", json::num(router_overhead_us)),
                ("query_single_node", bench_json(&solo_r)),
                ("query_scatter_gather", bench_json(&sg_r)),
                ("scatter_gather_qps", json::num(scatter_gather_qps)),
                ("single_node_qps", json::num(single_node_qps)),
            ]),
        ));
    }

    // ------------------------------------------- observability tax (obs)
    // two numbers land in the JSON: `metrics_overhead_us` (the cost of one
    // pre-registered histogram observation plus a counter bump — the whole
    // per-step hot-path instrumentation, no string lookups) and
    // `serve_tokps_traced_ratio` (demo-scale serve throughput with span
    // tracing enabled over throughput with it disabled; the acceptance
    // floor is 0.99 — tracing must be free at serving granularity).
    {
        use raana::obs::{self, trace};
        use raana::serve::Server;
        use std::sync::Arc;

        let m = obs::metrics();
        const OBS_PER_ITER: usize = 1024;
        let obs_r = bench("metrics_observe", 2, 64, || {
            for i in 0..OBS_PER_ITER {
                m.decode_step_us.observe_us(i as u64);
                m.tokens_generated.inc();
            }
        });
        let metrics_overhead_us = obs_r.median() * 1e6 / OBS_PER_ITER as f64;

        let (manifest, params, packed) =
            raana::experiments::native_demo_packed("bench-obs", 256, 2, 4, 7)?;
        let server = Arc::new(Server::start_native_packed(manifest, params, packed)?);
        let gen_len = 32usize;
        let prompt = vec![1i32, 2, 3];
        let run = || {
            let (_, rx) = server.submit(prompt.clone(), gen_len, 0.0, 0).unwrap();
            let done = rx.recv().unwrap();
            std::hint::black_box(done.tokens.len());
        };
        trace::tracer().set_enabled(false);
        let plain_r = bench("serve_untraced", 1, 8, || run());
        trace::tracer().set_enabled(true);
        let traced_r = bench("serve_traced", 1, 8, || run());
        trace::tracer().set_enabled(false);
        trace::tracer().clear();
        match Arc::try_unwrap(server) {
            Ok(s) => {
                s.shutdown()?;
            }
            Err(_) => anyhow::bail!("bench closure still holds the obs server"),
        }

        let tokps_plain = gen_len as f64 / plain_r.median().max(1e-12);
        let tokps_traced = gen_len as f64 / traced_r.median().max(1e-12);
        let serve_tokps_traced_ratio = tokps_traced / tokps_plain.max(1e-12);

        let mut t = Table::new(&["Observability", "median", "derived"]);
        t.row(vec![
            "histogram observe + counter inc".into(),
            format!("{:.1} ns", metrics_overhead_us * 1e3),
            format!("{metrics_overhead_us:.4} us/step"),
        ]);
        t.row(vec![
            format!("serve {gen_len} tok, tracing off"),
            format!("{:.2} ms", plain_r.median() * 1e3),
            format!("{tokps_plain:.0} tok/s"),
        ]);
        t.row(vec![
            format!("serve {gen_len} tok, tracing on"),
            format!("{:.2} ms", traced_r.median() * 1e3),
            format!("{tokps_traced:.0} tok/s (ratio {serve_tokps_traced_ratio:.3})"),
        ]);
        println!("{}", t.render());
        report.push((
            "obs",
            json::obj(vec![
                ("observe_batch", bench_json(&obs_r)),
                ("metrics_overhead_us", json::num(metrics_overhead_us)),
                ("gen_len", json::num(gen_len as f64)),
                ("serve_untraced", bench_json(&plain_r)),
                ("serve_traced", bench_json(&traced_r)),
                ("serve_tokps_untraced", json::num(tokps_plain)),
                ("serve_tokps_traced", json::num(tokps_traced)),
                ("serve_tokps_traced_ratio", json::num(serve_tokps_traced_ratio)),
            ]),
        ));
    }

    let out = std::path::Path::new("BENCH_kernels.json");
    write_json_report(out, &json::obj(report))?;
    println!("wrote {}", out.display());
    Ok(())
}
