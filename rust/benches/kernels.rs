//! Ablation A4 (paper §6.3 device-independence): kernel micro-benchmarks.
//!
//! * Rust FWHT throughput across dimensions (the quantization hot path).
//! * RaBitQ column quantization throughput (weights/s — compare the
//!   paper's ~21 M weights/s for a 70B model in ~3300 s on 2x EPYC).
//! * Rust Algorithm-3 estimator vs the Pallas `qmatmul` HLO artifact and
//!   vs the dense dequantized matmul.

use raana::benchlib::{bench, Table};
use raana::hadamard::{fwht, PracticalRht};
use raana::model::artifacts_root;
use raana::rabitq::{QuantizedMatrix, ScaleMode};
use raana::rng::Rng;
use raana::runtime::{lit_f32, Runtime};
use raana::tensor::Matrix;
use raana::threadpool::default_threads;

fn main() -> anyhow::Result<()> {
    println!("=== Kernel micro-benchmarks ===");

    // FWHT throughput
    let mut t = Table::new(&["FWHT d", "rows", "median", "GB/s"]);
    for &d in &[256usize, 1024, 4096] {
        let rows = (1 << 22) / d; // ~16 MiB working set
        let mut data = Rng::new(1).gaussian_vec(rows * d);
        let r = bench(&format!("fwht_{d}"), 2, 8, || {
            for row in data.chunks_mut(d) {
                fwht(row);
            }
        });
        let bytes = (rows * d * 4) as f64;
        t.row(vec![
            d.to_string(),
            rows.to_string(),
            format!("{:.2} ms", r.median() * 1e3),
            format!("{:.2}", bytes / r.median() / 1e9),
        ]);
    }
    println!("{}", t.render());

    // RaBitQ quantization throughput
    let mut t = Table::new(&["RaBitQ d x c", "bits", "mode", "median", "Mweights/s"]);
    let threads = default_threads();
    for &(d, c) in &[(1024usize, 1024usize)] {
        let w = Matrix::from_vec(d, c, Rng::new(2).gaussian_vec(d * c));
        for (mode, name) in [(ScaleMode::MaxAbs, "maxabs"), (ScaleMode::Search(8), "search8")] {
            for bits in [2u8, 4] {
                let r = bench(&format!("rabitq_{name}_{bits}"), 1, 5, || {
                    std::hint::black_box(QuantizedMatrix::quantize(&w, bits, mode, threads));
                });
                t.row(vec![
                    format!("{d}x{c}"),
                    bits.to_string(),
                    name.into(),
                    format!("{:.1} ms", r.median() * 1e3),
                    format!("{:.1}", (d * c) as f64 / r.median() / 1e6),
                ]);
            }
        }
    }
    println!("{}", t.render());

    // Algorithm-3 estimator paths
    let (n, d, c, bits) = (128usize, 256usize, 256usize, 4u8);
    let v = Matrix::from_vec(d, c, Rng::new(3).gaussian_vec(d * c));
    let x = Matrix::from_vec(n, d, Rng::new(4).gaussian_vec(n * d));
    let qm = QuantizedMatrix::quantize(&v, bits, ScaleMode::MaxAbs, threads);
    let dense = qm.dequantize();

    let mut t = Table::new(&["Alg.3 path", "median", "note"]);
    let r = bench("rust_stream", 2, 10, || {
        std::hint::black_box(qm.matmul_est(&x));
    });
    t.row(vec!["Rust streaming codes".into(), format!("{:.2} ms", r.median() * 1e3),
               "no dequant materialization".into()]);
    let r = bench("rust_dense", 2, 10, || {
        std::hint::black_box(x.matmul(&dense));
    });
    t.row(vec!["Rust dense dequant".into(), format!("{:.2} ms", r.median() * 1e3),
               "after one-time dequant".into()]);

    if let Ok(rt) = Runtime::cpu() {
        let path = artifacts_root()
            .join("kernels")
            .join(format!("qmatmul_{n}x{d}x{c}_b{bits}.hlo.txt"));
        if path.exists() {
            let art = rt.load(&path)?;
            let unpacked = qm.codes.unpack();
            let mut codes_f32 = vec![0f32; d * c];
            for j in 0..c {
                for i in 0..d {
                    codes_f32[i * c + j] = unpacked[j * d + i] as f32;
                }
            }
            let inputs = [
                lit_f32(&x.data, &[n, d])?,
                lit_f32(&codes_f32, &[d, c])?,
                lit_f32(&qm.r, &[c])?,
            ];
            let r = bench("pallas_artifact", 2, 10, || {
                std::hint::black_box(art.run(&inputs).unwrap());
            });
            t.row(vec![
                "Pallas qmatmul artifact (PJRT)".into(),
                format!("{:.2} ms", r.median() * 1e3),
                "fused L1 kernel via XLA".into(),
            ]);
        }
    }
    println!("{}", t.render());
    Ok(())
}
