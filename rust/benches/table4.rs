//! Paper Table 4: perplexity on the c4 analog (out-of-distribution for the
//! synthwiki-trained model), methods x bits.

use raana::experiments::tables::{method_grid, Dataset};
use raana::experiments::Env;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("RAANA_BENCH_MODEL").unwrap_or_else(|_| "tiny".into());
    let cap = std::env::var("RAANA_BENCH_EVAL_CAP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);
    let env = Env::load(&model)?;
    println!("=== Table 4: perplexity on {} (model {model}) ===",
             Dataset::SynthC4.name());
    let t = method_grid(&env, Dataset::SynthC4, cap)?;
    println!("{}", t.render());
    Ok(())
}
