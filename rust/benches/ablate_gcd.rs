//! Ablation A1 (paper §4.1): the divide-by-GCD trick in AllocateBits.
//! "without it, the algorithm would be millions of times slower."
//!
//! We measure DP wall time and touched states with and without the
//! reduction, on (a) the tiny model's real layer sizes and (b) synthetic
//! LLaMA-7B-like layer sizes with a scaled budget so the no-GCD run stays
//! feasible (the full no-GCD LLaMA problem really would take ~10^6 x
//! longer — that is the point).

use raana::allocate::AllocProblem;
use raana::benchlib::{bench_once, Table};
use raana::experiments::Env;

fn run_case(name: &str, m: Vec<usize>, alphas: Vec<f64>, avg_bits: f64, table: &mut Table) {
    let budget = AllocProblem::budget_for_avg_bits(&m, avg_bits);
    let p = AllocProblem { alphas, m, bit_choices: (1..=8).collect(), budget };
    let (t_gcd, with) = bench_once("gcd", || p.solve().unwrap());
    let (t_raw, without) = bench_once("no-gcd", || p.solve_no_gcd_reduction().unwrap());
    assert!((with.cost - without.cost).abs() < 1e-9, "solutions must match");
    table.row(vec![
        name.into(),
        format!("{}", with.g),
        format!("{:.3} ms", t_gcd.median() * 1e3),
        format!("{:.1} ms", t_raw.median() * 1e3),
        format!("{:.0}x", t_raw.median() / t_gcd.median().max(1e-9)),
        format!("{} vs {}", with.dp_states, without.dp_states),
    ]);
}

fn main() -> anyhow::Result<()> {
    println!("=== Ablation: AllocateBits divide-by-GCD (paper section 4.1) ===");
    let mut table = Table::new(&[
        "Problem", "g", "with GCD", "without", "speedup", "DP states",
    ]);

    // (a) the real tiny-model problem
    if let Ok(env) = Env::load("tiny") {
        let m: Vec<usize> = env.mrt.manifest.linears.iter().map(|l| l.m).collect();
        let alphas: Vec<f64> = (0..m.len()).map(|i| 1.0 + (i as f64).sin().abs()).collect();
        run_case("tiny model (24 layers)", m, alphas, 3.1, &mut table);
    }

    // (b) LLaMA-7B-like layer sizes, scaled-down budget via fewer layers
    let llama_like: Vec<usize> = (0..8)
        .flat_map(|_| {
            [4096 * 4096, 4096 * 4096, 4096 * 4096, 4096 * 4096,
             4096 * 11008, 11008 * 4096]
        })
        .take(12)
        .collect();
    // g = gcd(...) = 4096*16 here; full no-GCD would be ~10^9 states, so
    // scale m down by 256 to keep the comparison finishable.
    let scaled: Vec<usize> = llama_like.iter().map(|&x| x / 256).collect();
    let alphas: Vec<f64> = (0..scaled.len()).map(|i| 1.0 + i as f64 * 0.1).collect();
    run_case("llama-like /256 (12 layers)", scaled, alphas, 2.1, &mut table);

    println!("{}", table.render());
    println!(
        "note: the speedup scales ~linearly with g; on unscaled LLaMA-7B \
         sizes g ~ 2^24 -> the paper's 'millions of times' claim."
    );
    Ok(())
}
