//! Paper Table 2: zero-shot vs few-shot calibration on the wikitext2
//! analog (RaanA-few = 5 sequences, RaanA-zero = the synthetic sentence).

use raana::experiments::tables::{calib_comparison, Dataset};
use raana::experiments::Env;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("RAANA_BENCH_MODEL").unwrap_or_else(|_| "tiny".into());
    let cap = std::env::var("RAANA_BENCH_EVAL_CAP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);
    let env = Env::load(&model)?;
    println!("=== Table 2: calibration comparison on {} (model {model}) ===",
             Dataset::SynthWiki.name());
    let t = calib_comparison(&env, Dataset::SynthWiki, cap)?;
    println!("{}", t.render());
    Ok(())
}
