//! Paper Table 3: quantization wall-clock time vs model size (RaanA @2.1,
//! few-shot), with the calibration / allocation / RaBitQ-H phase split the
//! paper discusses in §6.3 (CPU-bound RaBitQ; calibration is the only part
//! needing the model runtime).

use raana::experiments::tables::quant_time;

fn main() -> anyhow::Result<()> {
    let models_env =
        std::env::var("RAANA_BENCH_MODELS").unwrap_or_else(|_| "micro,tiny".into());
    let models: Vec<&str> = models_env.split(',').filter(|s| !s.is_empty()).collect();
    println!("=== Table 3: quantization time (RaanA @2.1 avg bits) ===");
    let t = quant_time(&models)?;
    println!("{}", t.render());
    Ok(())
}
