//! Paper Table 5: zero-shot vs few-shot calibration on the c4 analog.

use raana::experiments::tables::{calib_comparison, Dataset};
use raana::experiments::Env;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("RAANA_BENCH_MODEL").unwrap_or_else(|_| "tiny".into());
    let cap = std::env::var("RAANA_BENCH_EVAL_CAP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);
    let env = Env::load(&model)?;
    println!("=== Table 5: calibration comparison on {} (model {model}) ===",
             Dataset::SynthC4.name());
    let t = calib_comparison(&env, Dataset::SynthC4, cap)?;
    println!("{}", t.render());
    Ok(())
}
