//! Ablation A3: the RaBitQ error bound (paper eq. 11 / Assumption 4.1).
//!
//! Empirically measures max and p99 of |<x,w> - est| / (||x|| ||w||) across
//! dimensions d and bit-widths b, against the paper's c_err/(sqrt(d) 2^b)
//! envelope with c_err = 5.75. The observed error must scale as 2^-b and
//! 1/sqrt(d) — the scaling Assumption 4.1 feeds into AllocateBits.

use raana::benchlib::Table;
use raana::hadamard::PracticalRht;
use raana::rabitq::{estimate_ip, quantize_column, ScaleMode, C_ERROR};
use raana::rng::Rng;
use raana::tensor::{dot, norm};

fn main() -> anyhow::Result<()> {
    println!("=== RaBitQ-H empirical error vs paper eq. (11) bound ===");
    let mut table = Table::new(&[
        "d", "bits", "p50 err", "p99 err", "max err", "bound 5.75/(sqrt(d) 2^b)",
    ]);
    let trials = 400;
    for &d in &[128usize, 512, 2048] {
        for &bits in &[2u8, 4, 6] {
            let mut rng = Rng::new(d as u64 * 31 + bits as u64);
            let rot = PracticalRht::sample(d, &mut rng);
            let mut errs = Vec::with_capacity(trials);
            for t in 0..trials {
                let mut w = Rng::new(1000 + t as u64).gaussian_vec(d);
                let mut x = Rng::new(9000 + t as u64).gaussian_vec(d);
                rot.apply(&mut w);
                rot.apply(&mut x);
                let (codes, r) = quantize_column(&w, bits, ScaleMode::default());
                let est = estimate_ip(&x, &codes, r, bits);
                let exact = dot(&x, &w);
                errs.push((est - exact).abs() / (norm(&x) * norm(&w)));
            }
            errs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let bound = C_ERROR / ((d as f64).sqrt() * 2f64.powi(bits as i32));
            table.row(vec![
                d.to_string(),
                bits.to_string(),
                format!("{:.2e}", errs[trials / 2]),
                format!("{:.2e}", errs[trials * 99 / 100]),
                format!("{:.2e}", errs[trials - 1]),
                format!("{bound:.2e}"),
            ]);
        }
    }
    println!("{}", table.render());
    println!("expected: errors scale ~2^-b (rows) and ~1/sqrt(d) (groups), max <= bound");
    Ok(())
}
