//! Perplexity evaluation (paper §6 "Datasets"): split the test set into
//! fixed-length sequences and report `exp(mean per-token NLL)` through the
//! AOT `fwd_loss` artifact.

use anyhow::Result;

use crate::data::Corpus;
use crate::model::ModelParams;
use crate::runtime::ModelRuntime;

/// Perplexity of `params` on the corpus test split.
///
/// `max_sequences` caps evaluation cost (the paper caps c4 at 500 samples);
/// 0 = evaluate everything.
pub fn perplexity(
    mrt: &ModelRuntime,
    params: &ModelParams,
    corpus: &Corpus,
    max_sequences: usize,
) -> Result<f64> {
    let m = &mrt.manifest;
    anyhow::ensure!(corpus.seq_len == m.seq_len, "corpus/model seq_len mismatch");
    let mut total_nll = 0f64;
    let mut total_tok = 0usize;
    let mut seqs_done = 0usize;
    for (flat, real) in corpus.test_batches(m.eval_batch) {
        let real = if max_sequences > 0 {
            real.min(max_sequences - seqs_done)
        } else {
            real
        };
        if real == 0 {
            break;
        }
        let nll = mrt.token_nll(params, &flat)?;
        let per_seq = m.seq_len - 1;
        anyhow::ensure!(nll.len() == m.eval_batch * per_seq, "nll arity");
        for row in 0..real {
            for t in 0..per_seq {
                total_nll += nll[row * per_seq + t] as f64;
            }
            total_tok += per_seq;
        }
        seqs_done += real;
        if max_sequences > 0 && seqs_done >= max_sequences {
            break;
        }
    }
    anyhow::ensure!(total_tok > 0, "no test tokens evaluated");
    Ok((total_nll / total_tok as f64).exp())
}

/// Bits-per-byte from perplexity (byte-level tokens): log2(ppl).
pub fn bits_per_byte(ppl: f64) -> f64 {
    ppl.log2()
}

#[cfg(test)]
mod tests {
    #[test]
    fn bpb_sanity() {
        assert!((super::bits_per_byte(2.0) - 1.0).abs() < 1e-12);
        assert!((super::bits_per_byte(256.0) - 8.0).abs() < 1e-12);
    }
}
