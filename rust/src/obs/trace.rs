//! Per-request tracing: request ids and span timelines.
//!
//! # Request ids
//!
//! Every HTTP request gets a **request id**: either the one the client
//! sent in an `X-Request-Id` header (accepted when it passes
//! [`sanitize_rid`]) or one minted here at admission ([`mint_rid`]). The
//! id is carried in a thread-local for the duration of the connection
//! ([`set_current_rid`] / [`current_rid`]), which is what makes the
//! propagation cheap and uniform:
//!
//! * every response writer (including every error path) echoes it back
//!   as `X-Request-Id`,
//! * the HTTP client attaches it to outgoing requests, so a router
//!   thread serving a request forwards the *same* id on every
//!   router→worker RPC — including each attempt of the bounded-retry
//!   client, which is what makes client retries correlatable,
//! * library-level submissions ([`crate::serve::Server::submit`]) adopt
//!   the ambient id so batcher-side spans land under the right request.
//!
//! # Spans
//!
//! A [`Span`] is one timed phase of one request: queue-wait, prefill,
//! one decode step, a kvq attend, an index scan/rerank, a WAL
//! append/seal, a router hop. Spans go to a bounded in-memory ring
//! (always, while tracing is enabled) and optionally to a JSONL sink
//! (`--trace-log`): one self-contained JSON object per line, so one
//! request's full span tree reconstructs offline by grouping lines on
//! `rid` and ordering by `start_us`.
//!
//! Tracing is **off by default** ([`Tracer::enabled`] is a single
//! relaxed atomic load on the fast path) and recording never perturbs
//! generation: spans observe time, they never participate in compute —
//! the bit-determinism suite runs with tracing enabled to pin that.
//!
//! Time flows through the [`super::clock::Clock`] seam; the global
//! tracer uses [`super::clock::StdClock`], tests build a private
//! [`Tracer::with_clock`] over a manual clock to pin span values.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::clock::{Clock, StdClock};

/// Spans retained in the in-memory ring; older spans are dropped (and
/// counted in [`spans_dropped`]) once the ring is full.
pub const TRACE_RING_CAP: usize = 4096;

/// Longest accepted inbound `X-Request-Id`; longer ids are replaced by a
/// minted one rather than truncated (a truncated id correlates nothing).
pub const MAX_RID_LEN: usize = 64;

/// One timed phase of one request.
#[derive(Clone, Debug)]
pub struct Span {
    /// The request id this span belongs to (`-` when a phase ran outside
    /// any request context, e.g. batch-level work).
    pub rid: Arc<str>,
    /// Phase name (static by design: span names are a closed vocabulary,
    /// never per-request strings).
    pub name: &'static str,
    /// Clock reading at phase start (µs, tracer-clock epoch).
    pub start_us: u64,
    /// Phase duration in µs.
    pub dur_us: u64,
    /// Phase-specific small integer (token index for `decode`, prompt
    /// tokens for `prefill`, worker index for `router_hop`); `-1` when
    /// the phase has nothing to attach.
    pub note: i64,
}

impl Span {
    /// The JSONL line for this span (no trailing newline). Field order
    /// is fixed so sinks are byte-stable for a given span sequence.
    pub fn to_jsonl(&self) -> String {
        format!(
            "{{\"rid\":\"{}\",\"span\":\"{}\",\"start_us\":{},\"dur_us\":{},\"note\":{}}}",
            self.rid, self.name, self.start_us, self.dur_us, self.note
        )
    }
}

/// Span recorder: bounded ring + optional JSONL sink, behind one
/// enable flag. See the module docs for the protocol.
pub struct Tracer {
    enabled: AtomicBool,
    clock: Box<dyn Clock>,
    ring: Mutex<VecDeque<Span>>,
    sink: Mutex<Option<std::io::BufWriter<std::fs::File>>>,
    dropped: AtomicU64,
}

impl Tracer {
    /// A disabled tracer over `clock` (tests pass a
    /// [`super::clock::ManualClock`]).
    pub fn with_clock(clock: Box<dyn Clock>) -> Tracer {
        Tracer {
            enabled: AtomicBool::new(false),
            clock,
            ring: Mutex::new(VecDeque::with_capacity(64)),
            sink: Mutex::new(None),
            dropped: AtomicU64::new(0),
        }
    }

    /// Current tracer-clock reading in µs. Cheap; callers bracket phases
    /// with two reads and hand the pair to [`Tracer::record`].
    pub fn now_us(&self) -> u64 {
        self.clock.now_us()
    }

    /// Turn span recording on or off (idempotent).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Whether spans are currently recorded — the hot path's only cost
    /// when tracing is off.
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Attach a JSONL sink at `path` (append mode) and enable tracing.
    /// Every recorded span becomes one line, flushed per span so a
    /// mid-stream disconnect still leaves the request's spans on disk.
    pub fn set_jsonl_sink(&self, path: &Path) -> std::io::Result<()> {
        let f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        *self.sink.lock().unwrap_or_else(|e| e.into_inner()) =
            Some(std::io::BufWriter::new(f));
        self.set_enabled(true);
        Ok(())
    }

    /// Detach the JSONL sink (tracing stays in whatever enabled state it
    /// had; the ring keeps recording if enabled).
    pub fn clear_jsonl_sink(&self) {
        *self.sink.lock().unwrap_or_else(|e| e.into_inner()) = None;
    }

    /// Record one span. No-op while disabled (one relaxed load).
    pub fn record(&self, rid: &Arc<str>, name: &'static str, start_us: u64, dur_us: u64, note: i64) {
        if !self.is_enabled() {
            return;
        }
        let span = Span { rid: Arc::clone(rid), name, start_us, dur_us, note };
        if let Some(w) = self.sink.lock().unwrap_or_else(|e| e.into_inner()).as_mut() {
            let _ = writeln!(w, "{}", span.to_jsonl());
            let _ = w.flush();
        }
        let mut ring = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        if ring.len() >= TRACE_RING_CAP {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(span);
    }

    /// Copy of the current ring contents, oldest first.
    pub fn snapshot(&self) -> Vec<Span> {
        self.ring.lock().unwrap_or_else(|e| e.into_inner()).iter().cloned().collect()
    }

    /// Drop all ring contents (tests isolate themselves with this).
    pub fn clear(&self) {
        self.ring.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }

    /// Spans evicted from the full ring since process start.
    pub fn dropped(&self) -> usize {
        self.dropped.load(Ordering::Relaxed) as usize
    }
}

/// The process-wide tracer (std clock, disabled until `--trace-log` or a
/// test enables it).
pub fn tracer() -> &'static Tracer {
    static TRACER: OnceLock<Tracer> = OnceLock::new();
    TRACER.get_or_init(|| Tracer::with_clock(Box::new(StdClock)))
}

/// Ring evictions of the global tracer — registered in the metrics
/// registry as `raana_trace_spans_dropped_total`.
pub fn spans_dropped() -> usize {
    tracer().dropped()
}

// ------------------------------------------------------------ request ids

thread_local! {
    static CURRENT_RID: RefCell<Option<Arc<str>>> = const { RefCell::new(None) };
}

/// Install (or clear) the ambient request id for this thread. Connection
/// handlers set it right after reading a request head and clear it when
/// the connection is done.
pub fn set_current_rid(rid: Option<Arc<str>>) {
    CURRENT_RID.with(|c| *c.borrow_mut() = rid);
}

/// The ambient request id, if a connection handler installed one.
pub fn current_rid() -> Option<Arc<str>> {
    CURRENT_RID.with(|c| c.borrow().clone())
}

/// Validate an inbound `X-Request-Id`: 1..=[`MAX_RID_LEN`] chars from
/// `[A-Za-z0-9._-]`. Anything else is rejected (the caller mints
/// instead) — ids are echoed into response headers and JSONL, so the
/// accepted alphabet must be header- and JSON-safe by construction.
pub fn sanitize_rid(s: &str) -> Option<Arc<str>> {
    let ok = !s.is_empty()
        && s.len() <= MAX_RID_LEN
        && s.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'.' || b == b'_' || b == b'-');
    ok.then(|| Arc::from(s))
}

/// Mint a fresh request id: monotonic sequence + µs timestamp, e.g.
/// `r-0000002a-017b2f3c`. Unique within a process and unlikely to
/// collide across a small fleet; not a secret and not guessproof.
pub fn mint_rid() -> Arc<str> {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    Arc::from(format!("r-{seq:08x}-{:08x}", StdClock.now_us() & 0xffff_ffff).as_str())
}

/// The inbound id when valid, else a minted one — the single admission
/// rule both the worker front-end and the router apply.
pub fn admit_rid(inbound: Option<&str>) -> Arc<str> {
    inbound.and_then(sanitize_rid).unwrap_or_else(mint_rid)
}

/// Record a span attributed to the ambient request id (`-` when none):
/// the helper for phases that run on request-serving threads (index
/// scan/rerank, WAL append/seal) or batch-level phases with no single
/// owner (kvq attend inside a batched decode).
pub fn record_ambient(name: &'static str, start_us: u64, dur_us: u64, note: i64) {
    let t = tracer();
    if !t.is_enabled() {
        return;
    }
    let rid = current_rid().unwrap_or_else(|| Arc::from("-"));
    t.record(&rid, name, start_us, dur_us, note);
}

#[cfg(test)]
mod tests {
    use super::super::clock::ManualClock;
    use super::*;

    #[test]
    fn sanitize_accepts_header_safe_ids_only() {
        assert!(sanitize_rid("abc-123_X.z").is_some());
        assert!(sanitize_rid("").is_none());
        assert!(sanitize_rid("has space").is_none());
        assert!(sanitize_rid("quote\"").is_none());
        assert!(sanitize_rid(&"x".repeat(MAX_RID_LEN)).is_some());
        assert!(sanitize_rid(&"x".repeat(MAX_RID_LEN + 1)).is_none());
    }

    #[test]
    fn minted_ids_are_distinct_and_sanitizable() {
        let a = mint_rid();
        let b = mint_rid();
        assert_ne!(a, b);
        assert!(sanitize_rid(&a).is_some(), "minted id must round-trip the header filter");
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::with_clock(Box::new(ManualClock::new(0)));
        t.record(&Arc::from("r1"), "prefill", 0, 5, -1);
        assert!(t.snapshot().is_empty());
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let t = Tracer::with_clock(Box::new(ManualClock::new(0)));
        t.set_enabled(true);
        let rid: Arc<str> = Arc::from("r1");
        for i in 0..(TRACE_RING_CAP + 10) {
            t.record(&rid, "decode", i as u64, 1, i as i64);
        }
        let snap = t.snapshot();
        assert_eq!(snap.len(), TRACE_RING_CAP);
        assert_eq!(t.dropped(), 10);
        assert_eq!(snap[0].note, 10, "oldest spans evicted first");
    }

    #[test]
    fn manual_clock_pins_span_values_exactly() {
        let clock = ManualClock::new(1_000);
        // Tracer owns a boxed clock; drive an identical twin for asserts.
        let t = Tracer::with_clock(Box::new(ManualClock::new(1_000)));
        t.set_enabled(true);
        let rid: Arc<str> = Arc::from("req-7");
        let start = t.now_us();
        clock.advance(250);
        // the tracer's own clock did not move (it is a separate manual
        // clock), so durations are whatever the caller measured
        t.record(&rid, "queue_wait", start, 250, -1);
        let snap = t.snapshot();
        assert_eq!((snap[0].start_us, snap[0].dur_us), (1_000, 250));
        assert_eq!(
            snap[0].to_jsonl(),
            r#"{"rid":"req-7","span":"queue_wait","start_us":1000,"dur_us":250,"note":-1}"#
        );
    }

    #[test]
    fn ambient_rid_is_thread_local() {
        set_current_rid(Some(Arc::from("outer")));
        let inner = std::thread::spawn(|| current_rid().is_none()).join().unwrap();
        assert!(inner, "a fresh thread must not inherit the rid");
        assert_eq!(current_rid().as_deref(), Some("outer"));
        set_current_rid(None);
        assert!(current_rid().is_none());
    }
}
