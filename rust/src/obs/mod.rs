//! Observability: metrics registry, Prometheus exposition, request
//! tracing, and the `Clock` seam — the cross-cutting telemetry layer
//! for the serving stack.
//!
//! # Registry
//!
//! A std-only metrics registry: [`Counter`]s and [`Gauge`]s are single
//! atomics, [`Histogram`]s are fixed log-spaced microsecond buckets
//! ([`LATENCY_BUCKETS_US`]) of atomics. Handles are **pre-registered at
//! startup** and held as `Arc`s by the code that observes into them —
//! the hot path performs zero string lookups and zero allocation per
//! observation, the same discipline as the runtime's `ForwardIdx`
//! (PR 7) that removed per-step name resolution from decode.
//!
//! [`Registry::render`] emits Prometheus text exposition (v0.0.4):
//! families sorted by name, `# HELP`/`# TYPE` once per family,
//! cumulative `_bucket{le=...}` lines plus `_sum`/`_count` for
//! histograms. All sample values are integers, so the rendering is
//! byte-deterministic for a given registry state — pinned by the
//! committed `metrics_exposition.json` golden fixture and its numpy
//! mirror (`python/tests/test_obs.py`), like every other subsystem.
//!
//! Existing flat counters (dequant calls, name resolutions, rerank row
//! reads, qgemm calls) join the registry as **read-at-render** functions
//! ([`Registry::register_fn_counter`]) — their call sites keep the
//! single relaxed `fetch_add` they already had.
//!
//! # Fleet aggregation
//!
//! The cluster router's `GET /metrics` concatenates each worker's
//! exposition with a `worker="<i>"` label injected into every sample
//! line ([`relabel_exposition`]) and duplicate `# HELP`/`# TYPE` lines
//! suppressed. Histogram buckets are *summable* across workers, which is
//! exactly why buckets (not percentiles) are what crosses the wire —
//! percentiles are still computed once over concatenated windows
//! (`/v1/stats`), never averaged.
//!
//! # Tracing and time
//!
//! Per-request tracing lives in [`trace`]; time flows through the
//! [`clock::Clock`] seam (production [`clock::StdClock`], tests a
//! [`clock::ManualClock`]) so histogram bucketing and span timelines
//! are deterministic under test.

pub mod clock;
pub mod trace;

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Histogram bucket upper bounds in microseconds: a log-spaced 1-2-5
/// ladder from 1 µs to 5 s, plus the implicit `+Inf` overflow bucket.
/// One shared layout for every duration histogram keeps fleet
/// aggregation a plain element-wise sum.
pub const LATENCY_BUCKETS_US: [u64; 21] = [
    1, 2, 5, 10, 20, 50, 100, 200, 500, 1_000, 2_000, 5_000, 10_000, 20_000, 50_000, 100_000,
    200_000, 500_000, 1_000_000, 2_000_000, 5_000_000,
];

/// Monotonic event counter (rendered with Prometheus `counter` type;
/// names end in `_total` by convention).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add 1.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed value (queue depth, active lanes).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    /// Overwrite the value.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket duration histogram over [`LATENCY_BUCKETS_US`].
///
/// Buckets are stored **non-cumulative** (index `i` counts observations
/// `v <= LATENCY_BUCKETS_US[i]` and greater than the previous edge; the
/// final slot is the `+Inf` overflow) and rendered cumulative, per the
/// exposition format. `observe_us` is a short branchless-ish scan over
/// 21 edges plus two relaxed `fetch_add`s — cheap enough for per-phase
/// hot-path use.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; LATENCY_BUCKETS_US.len() + 1],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// Record one duration in microseconds.
    pub fn observe_us(&self, us: u64) {
        let idx = LATENCY_BUCKETS_US
            .iter()
            .position(|&edge| us <= edge)
            .unwrap_or(LATENCY_BUCKETS_US.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of observed microseconds.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Non-cumulative bucket counts (`LATENCY_BUCKETS_US.len() + 1`
    /// entries; the last is the `+Inf` overflow).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

/// Place `values_us` into the shared bucket layout: non-cumulative
/// counts, `LATENCY_BUCKETS_US.len() + 1` entries (last = `+Inf`). This
/// is the helper `/v1/stats` uses to expose the completion-latency
/// window as summable buckets — see `net::stats_json` for the
/// aggregation invariant.
pub fn bucketize_us<I: IntoIterator<Item = u64>>(values_us: I) -> Vec<u64> {
    let mut counts = vec![0u64; LATENCY_BUCKETS_US.len() + 1];
    for v in values_us {
        let idx = LATENCY_BUCKETS_US
            .iter()
            .position(|&edge| v <= edge)
            .unwrap_or(LATENCY_BUCKETS_US.len());
        counts[idx] += 1;
    }
    counts
}

enum Sample {
    C(Arc<Counter>),
    G(Arc<Gauge>),
    H(Arc<Histogram>),
    /// Read-at-render bridge for pre-existing flat counters.
    F(fn() -> usize),
}

struct Family {
    name: String,
    help: String,
    kind: &'static str,
    labels: Vec<(String, String)>,
    sample: Sample,
}

/// Metric registry: registration happens at startup (mutex-guarded,
/// allocation allowed), observation happens through the returned `Arc`
/// handles (lock-free), rendering walks the registration list.
#[derive(Default)]
pub struct Registry {
    families: Mutex<Vec<Family>>,
}

impl Registry {
    /// An empty registry (tests; production uses [`metrics`]).
    pub fn new() -> Registry {
        Registry::default()
    }

    fn push(&self, name: &str, help: &str, kind: &'static str, labels: &[(&str, &str)], sample: Sample) {
        self.families.lock().unwrap_or_else(|e| e.into_inner()).push(Family {
            name: name.to_string(),
            help: help.to_string(),
            kind,
            labels: labels.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect(),
            sample,
        });
    }

    /// Register an unlabeled counter and return its handle.
    pub fn register_counter(&self, name: &str, help: &str) -> Arc<Counter> {
        let c = Arc::new(Counter::default());
        self.push(name, help, "counter", &[], Sample::C(Arc::clone(&c)));
        c
    }

    /// Register a labeled counter sample under `name` (several samples
    /// may share a family name with distinct labels).
    pub fn register_counter_with(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
    ) -> Arc<Counter> {
        let c = Arc::new(Counter::default());
        self.push(name, help, "counter", labels, Sample::C(Arc::clone(&c)));
        c
    }

    /// Register a counter whose value is read at render time from `f` —
    /// the bridge for pre-existing flat counters (dequant calls, name
    /// resolutions, rerank row reads) whose increment sites stay as they
    /// are.
    pub fn register_fn_counter(&self, name: &str, help: &str, f: fn() -> usize) {
        self.push(name, help, "counter", &[], Sample::F(f));
    }

    /// Register an unlabeled gauge and return its handle.
    pub fn register_gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        let g = Arc::new(Gauge::default());
        self.push(name, help, "gauge", &[], Sample::G(Arc::clone(&g)));
        g
    }

    /// Register a labeled gauge sample under `name` (several samples may
    /// share a family name with distinct labels).
    pub fn register_gauge_with(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
    ) -> Arc<Gauge> {
        let g = Arc::new(Gauge::default());
        self.push(name, help, "gauge", labels, Sample::G(Arc::clone(&g)));
        g
    }

    /// Register an unlabeled histogram over [`LATENCY_BUCKETS_US`] and
    /// return its handle.
    pub fn register_histogram(&self, name: &str, help: &str) -> Arc<Histogram> {
        let h = Arc::new(Histogram::default());
        self.push(name, help, "histogram", &[], Sample::H(Arc::clone(&h)));
        h
    }

    /// Render the registry as Prometheus text exposition: families
    /// sorted by name; `# HELP`/`# TYPE` emitted once per family name
    /// (first registration's help wins); samples in registration order
    /// within a name; histograms as cumulative `_bucket{le="..."}` lines
    /// plus `_sum` and `_count`. Every value is an integer, so the
    /// output is byte-deterministic for a given state — the property the
    /// golden fixture pins.
    pub fn render(&self) -> String {
        let fams = self.families.lock().unwrap_or_else(|e| e.into_inner());
        let mut order: Vec<usize> = (0..fams.len()).collect();
        order.sort_by(|&a, &b| fams[a].name.cmp(&fams[b].name).then(a.cmp(&b)));
        let mut out = String::new();
        let mut last_name: Option<&str> = None;
        for &i in &order {
            let f = &fams[i];
            if last_name != Some(f.name.as_str()) {
                out.push_str("# HELP ");
                out.push_str(&f.name);
                out.push(' ');
                out.push_str(&f.help);
                out.push('\n');
                out.push_str("# TYPE ");
                out.push_str(&f.name);
                out.push(' ');
                out.push_str(f.kind);
                out.push('\n');
                last_name = Some(f.name.as_str());
            }
            let label_str = |extra: Option<(&str, &str)>| -> String {
                let mut parts: Vec<String> =
                    f.labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
                if let Some((k, v)) = extra {
                    parts.push(format!("{k}=\"{v}\""));
                }
                if parts.is_empty() {
                    String::new()
                } else {
                    format!("{{{}}}", parts.join(","))
                }
            };
            match &f.sample {
                Sample::C(c) => {
                    out.push_str(&format!("{}{} {}\n", f.name, label_str(None), c.get()));
                }
                Sample::F(get) => {
                    out.push_str(&format!("{}{} {}\n", f.name, label_str(None), get()));
                }
                Sample::G(g) => {
                    out.push_str(&format!("{}{} {}\n", f.name, label_str(None), g.get()));
                }
                Sample::H(h) => {
                    let counts = h.bucket_counts();
                    let mut cum = 0u64;
                    for (bi, &edge) in LATENCY_BUCKETS_US.iter().enumerate() {
                        cum += counts[bi];
                        out.push_str(&format!(
                            "{}_bucket{} {}\n",
                            f.name,
                            label_str(Some(("le", &edge.to_string()))),
                            cum
                        ));
                    }
                    cum += counts[LATENCY_BUCKETS_US.len()];
                    out.push_str(&format!(
                        "{}_bucket{} {}\n",
                        f.name,
                        label_str(Some(("le", "+Inf"))),
                        cum
                    ));
                    out.push_str(&format!("{}_sum{} {}\n", f.name, label_str(None), h.sum_us()));
                    out.push_str(&format!("{}_count{} {}\n", f.name, label_str(None), h.count()));
                }
            }
        }
        out
    }
}

/// Inject `key="value"` as the **first** label of every sample line in
/// an exposition text (comment lines pass through; the caller dedupes
/// those). `name 3` becomes `name{key="value"} 3`; `name{le="5"} 3`
/// becomes `name{key="value",le="5"} 3`. This is how the router folds N
/// workers' metrics into one exposition without parsing values.
pub fn relabel_exposition(text: &str, key: &str, value: &str) -> String {
    let mut out = String::with_capacity(text.len() + 64);
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            out.push_str(line);
            out.push('\n');
            continue;
        }
        let Some(sp) = line.rfind(' ') else {
            out.push_str(line);
            out.push('\n');
            continue;
        };
        let (series, val) = line.split_at(sp);
        match series.find('{') {
            Some(b) => {
                out.push_str(&series[..=b]);
                out.push_str(&format!("{key}=\"{value}\","));
                out.push_str(&series[b + 1..]);
            }
            None => {
                out.push_str(series);
                out.push_str(&format!("{{{key}=\"{value}\"}}"));
            }
        }
        out.push_str(val);
        out.push('\n');
    }
    out
}

/// The pre-registered handle set every subsystem observes into: one
/// global [`Registry`] plus `Arc` handles resolved **once**, at first
/// use — never per request, never per token (the `ForwardIdx`
/// discipline applied to telemetry).
pub struct Metrics {
    /// The registry behind `GET /metrics`.
    pub registry: Registry,

    // ---- HTTP front-end
    /// Requests dispatched by the HTTP front-end (router or worker).
    pub http_requests: Arc<Counter>,
    /// Error responses written (any 4xx/5xx path).
    pub http_errors: Arc<Counter>,

    // ---- batching server phases
    /// Admission-to-lane wait per request.
    pub queue_wait_us: Arc<Histogram>,
    /// Serve-level prefill (admission or window slide), per request.
    pub prefill_us: Arc<Histogram>,
    /// One batched decode step (all active lanes advance one token).
    pub decode_step_us: Arc<Histogram>,
    /// Tokens sampled.
    pub tokens_generated: Arc<Counter>,
    /// Completed generations.
    pub completions: Arc<Counter>,
    /// Abandoned generations (cancel, disconnect, invalid prompt).
    pub cancelled: Arc<Counter>,
    /// Full-window re-prefills.
    pub window_slides: Arc<Counter>,
    /// Requests admitted but not yet on a KV lane (live gauge).
    pub queue_depth: Arc<Gauge>,
    /// KV lanes currently holding an active request (live gauge).
    pub lanes_active: Arc<Gauge>,

    // ---- model runtime / kernels
    /// `NativeModel::prefill` body (model work only, no serve overhead).
    pub native_prefill_us: Arc<Histogram>,
    /// `NativeModel::decode_step` body.
    pub native_decode_us: Arc<Histogram>,
    /// One attention pass over packed KV codes (per layer, per lane).
    pub kvq_attend_us: Arc<Histogram>,

    // ---- vector index
    /// Single-node two-phase query (scan + rerank together).
    pub index_query_us: Arc<Histogram>,
    /// Phase-1 estimated scan (scatter-gather shard side).
    pub index_scan_us: Arc<Histogram>,
    /// Phase-2 exact rerank (scatter-gather shard side).
    pub index_rerank_us: Arc<Histogram>,

    // ---- durability
    /// One WAL record append (encode + io append [+ fsync]).
    pub wal_append_us: Arc<Histogram>,
    /// One seal: segment writes + manifest commit + WAL pruning.
    pub wal_seal_us: Arc<Histogram>,

    // ---- cluster
    /// Successful worker probes / RPC outcomes.
    pub probe_success: Arc<Counter>,
    /// Failed worker probes / RPC outcomes.
    pub probe_failure: Arc<Counter>,
    /// Generate relays retried on another worker after a pre-response
    /// failure.
    pub relay_retries: Arc<Counter>,
    /// One router→worker generate relay, connect to last byte.
    pub router_hop_us: Arc<Histogram>,
}

impl Metrics {
    fn new() -> Metrics {
        let r = Registry::new();
        let m = Metrics {
            http_requests: r.register_counter(
                "raana_http_requests_total",
                "HTTP requests dispatched (worker front-end or router).",
            ),
            http_errors: r.register_counter(
                "raana_http_errors_total",
                "HTTP error responses written (4xx/5xx, every error path).",
            ),
            queue_wait_us: r.register_histogram(
                "raana_queue_wait_us",
                "Admission-to-KV-lane wait per request, microseconds.",
            ),
            prefill_us: r.register_histogram(
                "raana_prefill_us",
                "Serve-level prefill (admission or window slide), microseconds.",
            ),
            decode_step_us: r.register_histogram(
                "raana_decode_step_us",
                "One batched decode step across active lanes, microseconds.",
            ),
            tokens_generated: r.register_counter(
                "raana_tokens_generated_total",
                "Tokens sampled by the batching server.",
            ),
            completions: r.register_counter(
                "raana_completions_total",
                "Generations run to completion.",
            ),
            cancelled: r.register_counter(
                "raana_cancelled_total",
                "Generations abandoned mid-flight (cancel, disconnect, invalid prompt).",
            ),
            window_slides: r.register_counter(
                "raana_window_slides_total",
                "Full-window re-prefills (context outgrew seq_len).",
            ),
            queue_depth: r.register_gauge(
                "raana_queue_depth",
                "Requests admitted but not yet mapped onto a KV lane.",
            ),
            lanes_active: r.register_gauge(
                "raana_lanes_active",
                "KV lanes currently holding an active request.",
            ),
            native_prefill_us: r.register_histogram(
                "raana_native_prefill_us",
                "NativeModel::prefill body (model work only), microseconds.",
            ),
            native_decode_us: r.register_histogram(
                "raana_native_decode_us",
                "NativeModel::decode_step body (model work only), microseconds.",
            ),
            kvq_attend_us: r.register_histogram(
                "raana_kvq_attend_us",
                "One attention pass over packed KV codes (per layer, per lane), microseconds.",
            ),
            index_query_us: r.register_histogram(
                "raana_index_query_us",
                "Single-node two-phase index query (scan + rerank), microseconds.",
            ),
            index_scan_us: r.register_histogram(
                "raana_index_scan_us",
                "Phase-1 estimated scan over packed codes, microseconds.",
            ),
            index_rerank_us: r.register_histogram(
                "raana_index_rerank_us",
                "Phase-2 exact rerank of scan candidates, microseconds.",
            ),
            wal_append_us: r.register_histogram(
                "raana_wal_append_us",
                "One WAL record append (encode + io append [+ fsync]), microseconds.",
            ),
            wal_seal_us: r.register_histogram(
                "raana_wal_seal_us",
                "One seal: segment writes, manifest commit, WAL pruning, microseconds.",
            ),
            probe_success: r.register_counter(
                "raana_probe_success_total",
                "Successful worker probes / RPC outcomes recorded by fleet health.",
            ),
            probe_failure: r.register_counter(
                "raana_probe_failure_total",
                "Failed worker probes / RPC outcomes recorded by fleet health.",
            ),
            relay_retries: r.register_counter(
                "raana_relay_retries_total",
                "Generate relays retried on another worker after a pre-response failure.",
            ),
            router_hop_us: r.register_histogram(
                "raana_router_hop_us",
                "One router-to-worker generate relay, connect to last byte, microseconds.",
            ),
            registry: r,
        };
        // Pre-existing flat counters join as read-at-render bridges; their
        // increment sites (single relaxed fetch_adds) are untouched.
        m.registry.register_fn_counter(
            "raana_dequant_calls_total",
            "Full-matrix dequantizations (must stay flat on the serving path).",
            crate::rabitq::dequant_calls,
        );
        m.registry.register_fn_counter(
            "raana_name_resolutions_total",
            "Tensor name resolutions (must stay flat during decode).",
            crate::model::name_resolutions,
        );
        m.registry.register_fn_counter(
            "raana_rerank_row_reads_total",
            "Exact rows decoded for index rerank (bounds rerank I/O).",
            crate::index::rerank_row_reads,
        );
        m.registry.register_fn_counter(
            "raana_qgemm_calls_total",
            "Packed-code GEMM invocations on the serving hot path.",
            crate::kernels::qgemm_calls,
        );
        m.registry.register_fn_counter(
            "raana_trace_spans_dropped_total",
            "Spans evicted from the bounded in-memory trace ring.",
            trace::spans_dropped,
        );
        m
    }
}

/// The process-wide [`Metrics`] handle set (constructed on first use).
pub fn metrics() -> &'static Metrics {
    static METRICS: OnceLock<Metrics> = OnceLock::new();
    METRICS.get_or_init(Metrics::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_by_le_semantics() {
        let h = Histogram::default();
        h.observe_us(1); // == first edge: le="1"
        h.observe_us(2); // == second edge
        h.observe_us(3); // first edge > 3 is 5
        h.observe_us(6_000_000); // past the last edge: +Inf
        let c = h.bucket_counts();
        assert_eq!(c[0], 1, "le boundary is inclusive");
        assert_eq!(c[1], 1);
        assert_eq!(c[2], 1);
        assert_eq!(c[LATENCY_BUCKETS_US.len()], 1, "overflow lands in +Inf");
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum_us(), 1 + 2 + 3 + 6_000_000);
    }

    #[test]
    fn bucketize_matches_histogram_placement() {
        let vals = [0u64, 1, 7, 499, 500, 501, 5_000_000, 5_000_001];
        let h = Histogram::default();
        for &v in &vals {
            h.observe_us(v);
        }
        assert_eq!(bucketize_us(vals.iter().copied()), h.bucket_counts());
    }

    #[test]
    fn render_is_sorted_deterministic_and_integer_valued() {
        let r = Registry::new();
        let b = r.register_counter("raana_b_total", "second by name.");
        let _a = r.register_counter("raana_a_total", "first by name.");
        b.add(3);
        let text = r.render();
        let a_pos = text.find("raana_a_total").unwrap();
        let b_pos = text.find("# HELP raana_b_total").unwrap();
        assert!(a_pos < b_pos, "families must render name-sorted");
        assert!(text.contains("raana_b_total 3\n"));
        assert_eq!(text, r.render(), "rendering must be deterministic");
    }

    #[test]
    fn render_histogram_is_cumulative_with_inf_sum_count() {
        let r = Registry::new();
        let h = r.register_histogram("raana_t_us", "t.");
        h.observe_us(1);
        h.observe_us(3);
        h.observe_us(9_000_000);
        let text = r.render();
        assert!(text.contains("raana_t_us_bucket{le=\"1\"} 1\n"));
        assert!(text.contains("raana_t_us_bucket{le=\"5\"} 2\n"), "buckets are cumulative");
        assert!(text.contains("raana_t_us_bucket{le=\"5000000\"} 2\n"));
        assert!(text.contains("raana_t_us_bucket{le=\"+Inf\"} 3\n"));
        assert!(text.contains("raana_t_us_sum 9000004\n"));
        assert!(text.contains("raana_t_us_count 3\n"));
    }

    #[test]
    fn fn_counter_reads_at_render_time() {
        static V: AtomicU64 = AtomicU64::new(0);
        fn read() -> usize {
            V.load(Ordering::Relaxed) as usize
        }
        let r = Registry::new();
        r.register_fn_counter("raana_fnc_total", "bridge.", read);
        V.store(7, Ordering::Relaxed);
        assert!(r.render().contains("raana_fnc_total 7\n"));
        V.store(9, Ordering::Relaxed);
        assert!(r.render().contains("raana_fnc_total 9\n"));
    }

    #[test]
    fn relabel_inserts_first_label_everywhere() {
        let text = "# HELP x h\n# TYPE x counter\nx 3\ny_bucket{le=\"5\"} 2\ny_sum 7\n";
        let got = relabel_exposition(text, "worker", "1");
        assert!(got.contains("x{worker=\"1\"} 3\n"));
        assert!(got.contains("y_bucket{worker=\"1\",le=\"5\"} 2\n"));
        assert!(got.contains("y_sum{worker=\"1\"} 7\n"));
        assert!(got.contains("# HELP x h\n"), "comments pass through");
    }

    #[test]
    fn global_metrics_render_includes_bridged_counters() {
        let text = metrics().registry.render();
        for fam in [
            "raana_dequant_calls_total",
            "raana_name_resolutions_total",
            "raana_rerank_row_reads_total",
            "raana_qgemm_calls_total",
            "raana_trace_spans_dropped_total",
            "raana_decode_step_us_bucket{le=\"+Inf\"}",
        ] {
            assert!(text.contains(fam), "missing family {fam}");
        }
    }
}
