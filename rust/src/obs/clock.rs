//! The `Clock` seam: where observability gets its notion of time.
//!
//! Every duration the metrics registry buckets and every span the tracer
//! records flows through a [`Clock`] rather than calling
//! `Instant::now()` inline — the same dependency-inversion move as the
//! durability layer's `Io` seam (PR 6): production uses [`StdClock`]
//! (the process-wide monotonic clock), while tests construct a
//! [`ManualClock`] and advance it explicitly, so histogram bucket
//! placement and span start/duration values are pinned exactly instead
//! of asserted with slop.
//!
//! Time is a `u64` of **microseconds since an arbitrary epoch** (process
//! start for [`StdClock`], zero for a fresh [`ManualClock`]). Only
//! differences are meaningful; nothing here is wall-clock time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Monotonic microsecond clock. Implementations must be cheap — the hot
/// path reads it around every phase boundary — and never go backwards.
pub trait Clock: Send + Sync {
    /// Microseconds since this clock's epoch.
    fn now_us(&self) -> u64;
}

/// The production clock: `Instant`-based microseconds since the first
/// read anywhere in the process (lazily initialized, so the epoch is
/// shared by every user of [`StdClock`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct StdClock;

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

impl Clock for StdClock {
    fn now_us(&self) -> u64 {
        epoch().elapsed().as_micros() as u64
    }
}

/// A hand-cranked clock for tests: starts at an arbitrary value and
/// moves only when told to. Shared freely across threads.
#[derive(Debug, Default)]
pub struct ManualClock(AtomicU64);

impl ManualClock {
    /// A clock reading `start_us`.
    pub fn new(start_us: u64) -> ManualClock {
        ManualClock(AtomicU64::new(start_us))
    }

    /// Advance by `us` microseconds.
    pub fn advance(&self, us: u64) {
        self.0.fetch_add(us, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_us(&self) -> u64 {
        self.0.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn std_clock_is_monotonic() {
        let c = StdClock;
        let a = c.now_us();
        let b = c.now_us();
        assert!(b >= a);
    }

    #[test]
    fn manual_clock_moves_only_on_advance() {
        let c = ManualClock::new(100);
        assert_eq!(c.now_us(), 100);
        assert_eq!(c.now_us(), 100);
        c.advance(37);
        assert_eq!(c.now_us(), 137);
    }
}
