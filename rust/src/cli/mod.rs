//! Tiny CLI argument parser substrate (no clap in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Comma-separated list option.
    pub fn opt_list(&self, name: &str, default: &str) -> Vec<String> {
        self.opt(name)
            .unwrap_or(default)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().to_string())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse_from(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["train", "--steps", "100", "--lr=0.001", "--verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.opt("steps"), Some("100"));
        assert_eq!(a.opt_f64("lr", 0.0).unwrap(), 0.001);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.opt_usize("n", 7).unwrap(), 7);
        assert_eq!(a.opt_or("mode", "x"), "x");
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["--n", "abc"]);
        assert!(a.opt_usize("n", 0).is_err());
    }

    #[test]
    fn list_option() {
        let a = parse(&["--bits", "2.1,3.1, 4.1"]);
        assert_eq!(a.opt_list("bits", ""), vec!["2.1", "3.1", "4.1"]);
        assert_eq!(a.opt_list("other", "a,b"), vec!["a", "b"]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--fast", "--check"]);
        assert!(a.flag("fast") && a.flag("check"));
        assert!(a.options.is_empty());
    }
}
