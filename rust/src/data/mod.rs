//! Synthetic corpus substrate: byte-level tokenizer + two deterministic
//! text generators standing in for the paper's datasets (see DESIGN.md
//! §Substitutions):
//!
//! * [`synthwiki`] — the wikitext2 analog: headed articles, Zipfian
//!   vocabulary of synthetic words, repeated entities within an article.
//! * [`synthc4`] — the c4 analog: noisier, web-flavored text from a
//!   *different* word distribution (mixed case, URLs, fragments), so
//!   evaluating a synthwiki-trained model on it mirrors the paper's
//!   in-distribution vs broader-distribution pair of tables.
//!
//! Both are pure functions of a seed — every experiment is reproducible.

use crate::rng::Rng;

/// Byte-level tokenizer: tokens are raw bytes (vocab 256, matching the
/// model's embedding table).
pub fn tokenize(text: &str) -> Vec<i32> {
    text.as_bytes().iter().map(|&b| b as i32).collect()
}

pub fn detokenize(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Synthetic word list: `n` pronounceable words from syllables, Zipf-ranked.
fn word_list(n: usize, rng: &mut Rng) -> Vec<String> {
    const ONSETS: [&str; 16] = [
        "b", "ch", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t",
        "th", "v", "w",
    ];
    const NUCLEI: [&str; 8] = ["a", "e", "i", "o", "u", "ai", "ou", "ea"];
    const CODAS: [&str; 8] = ["", "n", "r", "s", "t", "l", "nd", "ck"];
    let mut words = Vec::with_capacity(n);
    let mut seen = std::collections::BTreeSet::new();
    while words.len() < n {
        let syllables = 1 + rng.below(3);
        let mut w = String::new();
        for _ in 0..syllables {
            w.push_str(ONSETS[rng.below(ONSETS.len())]);
            w.push_str(NUCLEI[rng.below(NUCLEI.len())]);
            w.push_str(CODAS[rng.below(CODAS.len())]);
        }
        if seen.insert(w.clone()) {
            words.push(w);
        }
    }
    words
}

/// Zipf cumulative weights over ranks 1..=n (exponent ~1).
fn zipf_cumulative(n: usize) -> Vec<f64> {
    let mut cum = Vec::with_capacity(n);
    let mut acc = 0.0;
    for k in 1..=n {
        acc += 1.0 / k as f64;
        cum.push(acc);
    }
    cum
}

/// wikitext2-analog generator: returns ~`target_bytes` of text.
pub fn synthwiki(target_bytes: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed);
    let vocab = word_list(2000, &mut rng);
    let cum = zipf_cumulative(vocab.len());
    let mut out = String::with_capacity(target_bytes + 256);
    let mut article = 0usize;
    while out.len() < target_bytes {
        article += 1;
        // heading
        let title = format!(
            " = {} {} = \n\n",
            cap(&vocab[rng.sample_cumulative(&cum)]),
            cap(&vocab[rng.sample_cumulative(&cum)])
        );
        out.push_str(&title);
        // articles repeat a couple of "entities" (wiki-like redundancy)
        let ents: Vec<String> = (0..2 + rng.below(3))
            .map(|_| cap(&vocab[rng.sample_cumulative(&cum)]))
            .collect();
        let paragraphs = 2 + rng.below(4);
        for _ in 0..paragraphs {
            let sentences = 3 + rng.below(5);
            for _ in 0..sentences {
                let words = 6 + rng.below(12);
                for wi in 0..words {
                    if wi > 0 {
                        out.push(' ');
                    }
                    if rng.below(8) == 0 {
                        out.push_str(&ents[rng.below(ents.len())]);
                    } else {
                        out.push_str(&vocab[rng.sample_cumulative(&cum)]);
                    }
                    if wi + 1 < words && rng.below(12) == 0 {
                        out.push(',');
                    }
                }
                out.push_str(". ");
            }
            out.push_str("\n\n");
        }
        if article > 100_000 {
            break; // safety against tiny targets
        }
    }
    out.truncate(target_bytes);
    out
}

/// c4-analog generator: noisier web text from a different distribution.
pub fn synthc4(target_bytes: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed ^ 0xC4C4_C4C4);
    let vocab = word_list(3500, &mut rng);
    let cum = zipf_cumulative(vocab.len());
    let mut out = String::with_capacity(target_bytes + 256);
    while out.len() < target_bytes {
        match rng.below(10) {
            0 => {
                // fake URL line
                out.push_str(&format!(
                    "http://www.{}{}.com/{} \n",
                    vocab[rng.sample_cumulative(&cum)],
                    rng.below(100),
                    vocab[rng.sample_cumulative(&cum)]
                ));
            }
            1 => {
                // shouty fragment
                let w = &vocab[rng.sample_cumulative(&cum)];
                out.push_str(&format!("{} - {}! ", w.to_uppercase(), rng.below(2030)));
            }
            _ => {
                let words = 4 + rng.below(18);
                for wi in 0..words {
                    if wi > 0 {
                        out.push(' ');
                    }
                    let w = &vocab[rng.sample_cumulative(&cum)];
                    if rng.below(6) == 0 {
                        out.push_str(&cap(w));
                    } else {
                        out.push_str(w);
                    }
                }
                out.push_str(match rng.below(5) {
                    0 => "? ",
                    1 => "... ",
                    2 => ".\n",
                    _ => ". ",
                });
            }
        }
    }
    out.truncate(target_bytes);
    out
}

fn cap(w: &str) -> String {
    let mut c = w.chars();
    match c.next() {
        Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
        None => String::new(),
    }
}

/// The paper's zero-shot calibration sentence (§4.2), repeated 100 times.
pub const ZERO_SHOT_SENTENCE: &str = "The curious fox leaped over the quiet \
stream, its reflection rippling in the golden afternoon light.";

pub fn zero_shot_text() -> String {
    let mut s = String::with_capacity(ZERO_SHOT_SENTENCE.len() * 100 + 100);
    for _ in 0..100 {
        s.push_str(ZERO_SHOT_SENTENCE);
        s.push(' ');
    }
    s
}

/// A tokenized corpus with train/test splits cut into fixed sequences.
pub struct Corpus {
    pub tokens: Vec<i32>,
    pub seq_len: usize,
    pub n_train: usize,
    pub n_test: usize,
}

impl Corpus {
    /// Split `text` into train/test sequences of `seq_len` tokens
    /// (paper §6: "split the test sets into sequences of length 2048").
    pub fn from_text(text: &str, seq_len: usize, test_frac: f64) -> Corpus {
        let tokens = tokenize(text);
        let n_seq = tokens.len() / seq_len;
        let n_test = ((n_seq as f64 * test_frac).round() as usize).clamp(1, n_seq - 1);
        Corpus { tokens, seq_len, n_train: n_seq - n_test, n_test }
    }

    pub fn train_seq(&self, i: usize) -> &[i32] {
        let i = i % self.n_train.max(1);
        &self.tokens[i * self.seq_len..(i + 1) * self.seq_len]
    }

    pub fn test_seq(&self, i: usize) -> &[i32] {
        assert!(i < self.n_test);
        let off = (self.n_train + i) * self.seq_len;
        &self.tokens[off..off + self.seq_len]
    }

    /// Sample a random training batch of `batch` sequences, flattened.
    pub fn train_batch(&self, batch: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * self.seq_len);
        for _ in 0..batch {
            out.extend_from_slice(self.train_seq(rng.below(self.n_train.max(1))));
        }
        out
    }

    /// Deterministic test batches of `batch` sequences (last one padded by
    /// repeating the final sequence); returns (flattened batch, how many
    /// rows are real).
    pub fn test_batches(&self, batch: usize) -> Vec<(Vec<i32>, usize)> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.n_test {
            let real = (self.n_test - i).min(batch);
            let mut flat = Vec::with_capacity(batch * self.seq_len);
            for k in 0..batch {
                let idx = if k < real { i + k } else { self.n_test - 1 };
                flat.extend_from_slice(self.test_seq(idx));
            }
            out.push((flat, real));
            i += real;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenize_roundtrip_ascii() {
        let s = "Hello, world! 123";
        assert_eq!(detokenize(&tokenize(s)), s);
        assert!(tokenize(s).iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn generators_deterministic() {
        assert_eq!(synthwiki(5000, 7), synthwiki(5000, 7));
        assert_ne!(synthwiki(5000, 7), synthwiki(5000, 8));
        assert_eq!(synthc4(5000, 7), synthc4(5000, 7));
    }

    #[test]
    fn generators_hit_target_size() {
        for n in [1000usize, 50_000] {
            assert_eq!(synthwiki(n, 1).len(), n);
            assert_eq!(synthc4(n, 1).len(), n);
        }
    }

    #[test]
    fn synthwiki_has_wiki_structure() {
        let text = synthwiki(20_000, 3);
        assert!(text.contains(" = "), "headings");
        assert!(text.contains(". "), "sentences");
        assert!(text.contains("\n\n"), "paragraphs");
    }

    #[test]
    fn distributions_differ() {
        // c4-analog should contain URLs; wiki-analog should not
        let wiki = synthwiki(50_000, 5);
        let c4 = synthc4(50_000, 5);
        assert!(!wiki.contains("http://"));
        assert!(c4.contains("http://"));
    }

    #[test]
    fn zero_shot_text_repeats_100x() {
        let z = zero_shot_text();
        assert_eq!(z.matches("curious fox").count(), 100);
    }

    #[test]
    fn corpus_splits() {
        let text = synthwiki(64 * 100, 9);
        let c = Corpus::from_text(&text, 64, 0.2);
        assert_eq!(c.n_train + c.n_test, 100);
        assert_eq!(c.n_test, 20);
        assert_eq!(c.train_seq(0).len(), 64);
        assert_eq!(c.test_seq(19).len(), 64);
    }

    #[test]
    fn train_and_test_do_not_overlap() {
        let text = synthwiki(32 * 10, 11);
        let c = Corpus::from_text(&text, 32, 0.3);
        let train_end = c.n_train * 32;
        // test_seq(0) starts exactly at the train/test boundary
        assert_eq!(c.test_seq(0), &c.tokens[train_end..train_end + 32]);
    }

    #[test]
    fn test_batches_cover_everything_once() {
        let text = synthwiki(16 * 11, 13);
        let c = Corpus::from_text(&text, 16, 0.5); // 5 test seqs (11*0.5 round = 6? check)
        let batches = c.test_batches(4);
        let total_real: usize = batches.iter().map(|(_, r)| r).sum();
        assert_eq!(total_real, c.n_test);
        for (flat, _) in &batches {
            assert_eq!(flat.len(), 4 * 16);
        }
    }

    #[test]
    fn train_batch_shape() {
        let text = synthwiki(32 * 20, 15);
        let c = Corpus::from_text(&text, 32, 0.25);
        let mut rng = Rng::new(1);
        let b = c.train_batch(8, &mut rng);
        assert_eq!(b.len(), 8 * 32);
        assert!(b.iter().all(|&t| (0..256).contains(&t)));
    }
}
