//! `raana` — CLI for the RaanA reproduction.
//!
//! Subcommands:
//!   info                         platform + artifact summary
//!   train    [--model tiny --steps N]
//!   quantize [--model tiny --avg-bits 3.1 --calib few:5|zero ...]
//!   eval     [--model tiny --dataset wiki|c4]
//!   table    --n 1..5            regenerate a paper table
//!   serve    [--model tiny --requests N]   batching-server demo
//!   serve    --http PORT [--max-queue N]   HTTP front-end (drains on stdin EOF)
//!   serve    --kv-bits N                   RaBitQ-compress the KV cache at N bits
//!   serve    --kv-budget BYTES             total KV RAM budget -> lane count
//!                                          (with --kv-bits: uniform plan; alone:
//!                                          per-layer AllocateBits plan)

use anyhow::{bail, Result};

use raana::calib::CalibMode;
use raana::cli::Args;
use raana::experiments::{baseline_quantize, raana_quantize, Baseline, Env};
use raana::model::artifacts_root;
use raana::quant::TrickConfig;
use raana::runtime::Runtime;
use raana::util::Timer;
use raana::{benchlib, info};

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => cmd_info(),
        "train" => cmd_train(&args),
        "quantize" => cmd_quantize(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "table" => cmd_table(&args),
        "help" | _ => {
            println!(
                "raana — RaanA post-training quantization (paper reproduction)\n\
                 usage: raana <info|train|quantize|eval|serve> [--options]\n\
                 see README.md; tables are regenerated via `cargo bench`"
            );
            Ok(())
        }
    }
}

fn cmd_info() -> Result<()> {
    match Runtime::cpu() {
        Ok(rt) => println!(
            "platform: {} ({} devices)",
            rt.client.platform_name(),
            rt.client.device_count()
        ),
        Err(_) => println!("platform: PJRT unavailable — native CPU backend (fused kernels)"),
    }
    let root = artifacts_root();
    println!("artifacts root: {}", root.display());
    for model in ["micro", "tiny", "small"] {
        let dir = root.join(model);
        if dir.join("manifest.json").exists() {
            let m = raana::model::Manifest::load(&dir)?;
            println!(
                "  model {model}: d={} layers={} params={} linears={} ({} quantizable)",
                m.d_model,
                m.n_layers,
                m.total_params(),
                m.linears.len(),
                m.total_linear_params()
            );
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.opt_or("model", "tiny");
    let steps = args.opt_usize("steps", 300)?;
    std::env::set_var("RAANA_TRAIN_STEPS", steps.to_string());
    // Env::load trains when no checkpoint exists; --force retrains.
    let root = artifacts_root();
    let ckpt = root.join(model).join("trained.rkpt");
    if args.flag("force") && ckpt.exists() {
        std::fs::remove_file(&ckpt)?;
    }
    let mut env = Env::load(model)?;
    // --more N: warm-resume N additional steps from the checkpoint
    let more = args.opt_usize("more", 0)?;
    if more > 0 {
        let cfg = raana::train::TrainConfig {
            steps: more,
            lr: args.opt_f64("lr", 1e-3)?,
            warmup: 10,
            ..Default::default()
        };
        raana::train::train(&env.mrt, &mut env.params, &env.wiki, &cfg)?;
        env.params.save(&env.ckpt_path)?;
    }
    let ppl = env.perplexity(&env.params, &env.wiki, 32)?;
    info!("trained model ppl(synthwiki) = {ppl:.3}");
    println!("checkpoint: {}", env.ckpt_path.display());
    Ok(())
}

fn tricks_from_args(args: &Args) -> TrickConfig {
    let mut t = TrickConfig::default();
    if args.flag("no-tricks") {
        t = TrickConfig::none();
    }
    t
}

fn calib_from_args(args: &Args) -> Result<CalibMode> {
    match args.opt_or("calib", "few:5") {
        "zero" => Ok(CalibMode::ZeroShot),
        s if s.starts_with("few:") => Ok(CalibMode::FewShot(s[4..].parse()?)),
        s => bail!("bad --calib '{s}'"),
    }
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let model = args.opt_or("model", "tiny");
    let avg_bits = args.opt_f64("avg-bits", 3.1)?;
    let env = Env::load(model)?;
    let mode = calib_from_args(args)?;
    let tricks = tricks_from_args(args);
    let timer = Timer::start();
    let (qparams, report) =
        raana_quantize(&env, &mode, avg_bits, &(1..=8).collect::<Vec<u8>>(), &tricks, 99, 0)?;
    println!(
        "quantized {} layers to avg {:.3} bits in {:.2}s (calib {:.2}s, alloc {:.3}s, quant {:.2}s)",
        report.layers.len(),
        report.avg_bits,
        timer.secs(),
        report.secs.0,
        report.secs.1,
        report.secs.2
    );
    for l in &report.layers {
        println!(
            "  {:<16} {} bits  avg {:.3}  recon rel err {:.4}",
            l.name, l.bits, l.avg_bits, l.recon_rel_err
        );
    }
    let cap = args.opt_usize("eval-cap", 32)?;
    let ppl_fp = env.perplexity(&env.params, &env.wiki, cap)?;
    let ppl_q = env.perplexity(&qparams, &env.wiki, cap)?;
    println!("ppl fp32 {ppl_fp:.3} -> quantized {ppl_q:.3}");
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.opt_or("model", "tiny");
    let env = Env::load(model)?;
    let corpus = match args.opt_or("dataset", "wiki") {
        "c4" => &env.c4,
        _ => &env.wiki,
    };
    let cap = args.opt_usize("eval-cap", 64)?;
    // optional uniform baseline comparison
    if let Some(method) = args.opt("baseline") {
        let bits = args.opt_usize("bits", 4)? as u8;
        let mode = calib_from_args(args)?;
        let calib = raana::calib::calibrate(&env.mrt, &env.params, &mode, &env.wiki)?;
        let b = match method {
            "rtn" => Baseline::Rtn,
            "gptq" => Baseline::Gptq,
            "awq" => Baseline::Awq,
            "easyquant" => Baseline::EasyQuant,
            _ => bail!("unknown baseline '{method}'"),
        };
        let (qp, avg) = baseline_quantize(&env, &calib, b, bits)?;
        let ppl = env.perplexity(&qp, corpus, cap)?;
        println!("{} @ {:.2} avg bits: ppl {}", b.name(), avg, benchlib::fmt_ppl(ppl));
        return Ok(());
    }
    let ppl = env.perplexity(&env.params, corpus, cap)?;
    println!("fp32 ppl: {}", benchlib::fmt_ppl(ppl));
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    use raana::experiments::tables::{calib_comparison, method_grid, quant_time, Dataset};
    let n = args.opt_usize("n", 1)?;
    let model = args.opt_or("model", "tiny");
    let cap = args.opt_usize("eval-cap", 16)?;
    let table = match n {
        1 => method_grid(&Env::load(model)?, Dataset::SynthWiki, cap)?,
        2 => calib_comparison(&Env::load(model)?, Dataset::SynthWiki, cap)?,
        3 => quant_time(&["micro", model])?,
        4 => method_grid(&Env::load(model)?, Dataset::SynthC4, cap)?,
        5 => calib_comparison(&Env::load(model)?, Dataset::SynthC4, cap)?,
        _ => bail!("--n must be 1..=5 (paper tables)"),
    };
    println!("=== Paper Table {n} (model {model}) ===\n{}", table.render());
    Ok(())
}

/// `--kv-bits N` / `--kv-budget BYTES` → KV storage policy + budget.
fn kv_from_args(args: &Args) -> Result<(raana::kvq::KvqPolicy, usize)> {
    use raana::kvq::KvqPolicy;
    let budget = args.opt_usize("kv-budget", 0)?;
    let policy = match args.opt_usize("kv-bits", 0)? {
        0 if budget > 0 => {
            // budget without an explicit width: let AllocateBits pick
            // per-layer (K, V) bit-widths under the per-lane share
            KvqPolicy::Budget { bit_choices: vec![2, 3, 4, 5, 6, 8] }
        }
        0 => KvqPolicy::DenseF32,
        b if (1..=8).contains(&b) => KvqPolicy::Uniform(b as u8),
        b => bail!("--kv-bits must be in 1..=8, got {b}"),
    };
    Ok((policy, budget))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = args.opt_or("model", "tiny");
    let n_req = args.opt_usize("requests", 16)?;
    let new_tokens = args.opt_usize("tokens", 16)?;
    // Bounded admission queue: HTTP runs default to 64 (backpressure as
    // 429), in-process demo runs stay unbounded as before.
    let (kv, kv_budget_bytes) = kv_from_args(args)?;
    let cfg = raana::serve::ServeConfig {
        max_queue: args.opt_usize("max-queue", if args.opt("http").is_some() { 64 } else { 0 })?,
        kv,
        kv_budget_bytes,
    };

    // Artifact-free path: serve a native-initialized model straight from
    // packed codes (demonstrates the request path without `make artifacts`).
    let have_artifacts = artifacts_root().join(model).join("manifest.json").exists();
    let (server, batch) = if args.flag("native") || !have_artifacts {
        if !have_artifacts {
            info!("artifacts/{model} missing — native packed-serving demo (untrained weights)");
        }
        build_native_demo_server(args, cfg)?
    } else {
        build_artifact_server(args, model, cfg)?
    };
    match args.opt("http") {
        Some(port) => serve_http(server, port, args),
        None => run_requests(server, n_req, new_tokens, batch),
    }
}

/// Quantize the trained `model` and start a packed-code server over it.
fn build_artifact_server(
    args: &Args,
    model: &str,
    cfg: raana::serve::ServeConfig,
) -> Result<(raana::serve::Server, usize)> {
    let env = Env::load(model)?;
    // quantize, keeping the codes bit-packed: the server's fwd_logits
    // computes on them via qgemm, with zero dequantization per forward
    let (packed, report) = raana::experiments::raana_quantize_packed(
        &env,
        &CalibMode::FewShot(5),
        args.opt_f64("avg-bits", 4.1)?,
        &(1..=8).collect::<Vec<u8>>(),
        &TrickConfig::default(),
        7,
        0,
    )?;
    info!(
        "serving packed model at avg {:.2} bits ({} KiB of codes resident)",
        report.avg_bits,
        packed.stored_bits() / 8 / 1024
    );
    let manifest = env.mrt.manifest.clone();
    let batch = manifest.eval_batch;
    let params = env.params.clone();
    drop(env); // the server thread owns its own (native) runtime
    let server = raana::serve::Server::start_native_packed_with(manifest, params, packed, cfg)?;
    Ok((server, batch))
}

/// Synthesize + pack a demo model and start a server over it.
fn build_native_demo_server(
    args: &Args,
    cfg: raana::serve::ServeConfig,
) -> Result<(raana::serve::Server, usize)> {
    let bits_raw = args.opt_usize("bits", 4)?;
    if !(1..=8).contains(&bits_raw) {
        bail!("--bits must be in 1..=8, got {bits_raw}");
    }
    let bits = bits_raw as u8;
    let d = args.opt_usize("d-model", 256)?;
    let layers = args.opt_usize("layers", 4)?;
    let (manifest, params, packed) =
        raana::experiments::native_demo_packed("native-demo", d, layers, bits, 7)?;
    info!(
        "packed {} linears at {bits} bits (avg {:.2} incl. side payloads)",
        manifest.linears.len(),
        packed.avg_bits()
    );
    let batch = manifest.eval_batch;
    let server = raana::serve::Server::start_native_packed_with(manifest, params, packed, cfg)?;
    Ok((server, batch))
}

/// Front the batching server with the HTTP layer until stdin closes, then
/// drain gracefully (SIGTERM-style: stop accepting, finish in-flight
/// work, collect final stats).
fn serve_http(server: raana::serve::Server, port: &str, args: &Args) -> Result<()> {
    let server = std::sync::Arc::new(server);
    let addr = if port.contains(':') { port.to_string() } else { format!("127.0.0.1:{port}") };
    let http = raana::net::HttpServer::bind_with(
        std::sync::Arc::clone(&server),
        &addr,
        raana::net::HttpConfig {
            workers: args.opt_usize("http-workers", 0)?,
            max_new_tokens_cap: args.opt_usize("http-max-tokens", 0)?,
        },
    )?;
    let bound = http.local_addr();
    println!("HTTP serving on http://{bound}  (close stdin / Ctrl-D for graceful drain)");
    println!("  curl -s http://{bound}/healthz");
    println!("  curl -s http://{bound}/v1/stats");
    println!(
        "  curl -s -X POST http://{bound}/v1/generate -d \
         '{{\"prompt\":[84,104,101,32],\"max_new_tokens\":16}}'"
    );
    let mut line = String::new();
    loop {
        line.clear();
        match std::io::stdin().read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
    info!("stdin closed — draining HTTP connections");
    http.shutdown()?;
    let server = std::sync::Arc::try_unwrap(server)
        .map_err(|_| anyhow::anyhow!("HTTP layer still holds the server"))?;
    let stats = server.shutdown()?;
    println!(
        "served {} completions ({} cancelled), {:.1} tok/s, p50 {:.1} ms p95 {:.1} ms",
        stats.completions,
        stats.cancelled,
        stats.throughput_tok_s(),
        stats.p50_latency() * 1e3,
        stats.p95_latency() * 1e3
    );
    Ok(())
}

fn run_requests(
    server: raana::serve::Server,
    n_req: usize,
    new_tokens: usize,
    batch: usize,
) -> Result<()> {
    let mut rxs = Vec::new();
    for i in 0..n_req {
        let prompt = raana::data::tokenize(&format!("The {i} quick brown fox "));
        let (_, rx) = server.submit(prompt, new_tokens, 0.8, i as u64)?;
        rxs.push(rx);
    }
    for rx in rxs {
        let c = rx.recv()?;
        println!(
            "req {:>3}: {:>5.1} ms  '{}'",
            c.id,
            c.latency_secs * 1e3,
            raana::data::detokenize(&c.tokens).escape_debug()
        );
    }
    let stats = server.shutdown()?;
    println!(
        "served {} completions, {:.1} tok/s, occupancy {:.2}, p50 {:.1} ms p95 {:.1} ms \
         ({} prefill tokens, {} decode steps, {} window slides)",
        stats.completions,
        stats.throughput_tok_s(),
        stats.mean_batch_occupancy(batch),
        stats.p50_latency() * 1e3,
        stats.p95_latency() * 1e3,
        stats.prefill_tokens,
        stats.decode_steps,
        stats.window_slides
    );
    Ok(())
}
