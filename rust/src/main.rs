//! `raana` — CLI for the RaanA reproduction.
//!
//! Subcommands:
//!   info                         platform + artifact summary
//!   train    [--model tiny --steps N]
//!   quantize [--model tiny --avg-bits 3.1 --calib few:5|zero ...]
//!   eval     [--model tiny --dataset wiki|c4]
//!   table    --n 1..5            regenerate a paper table
//!   serve    [--model tiny --requests N]   batching-server demo
//!   serve    --http PORT [--max-queue N]   HTTP front-end (drains on stdin EOF)
//!   serve    --kv-bits N                   RaBitQ-compress the KV cache at N bits
//!   serve    --kv-budget BYTES             total KV RAM budget -> lane count
//!                                          (with --kv-bits: uniform plan; alone:
//!                                          per-layer AllocateBits plan)
//!   serve    --http PORT [--index-bits N | --index-budget BYTES] [--no-index]
//!                                          retrieval endpoints (/v1/embed,
//!                                          /v1/collections/...) next to generate
//!   serve    --data-dir PATH [--fsync always|never] [--snapshot-every N]
//!            [--segment-rows N]
//!                                          crash-safe collections: WAL + snapshots
//!                                          under PATH, recovered at startup
//!   serve    --http PORT [--http-read-timeout-ms MS]
//!                                          socket read timeout (0 = default 10s);
//!                                          stalled peers get a typed 408
//!   index    [--bits N | --budget BYTES]   vector-index demo: embed docs, add,
//!            [--docs N --k K --rerank M]   self-retrieve, report recall + bytes
//!   worker   --http PORT [serve flags]     cluster worker: `serve --http` that
//!            [--drain-grace-ms MS]         drains gracefully on stdin EOF —
//!                                          healthz flips to "draining", the router
//!                                          routes around it, in-flight work finishes
//!   router   --workers a:p,b:p[,...]       cluster router: consistent-hash
//!            [--http PORT --shards N]      placement, scatter-gather queries,
//!            [--probe-ms MS --down-after N] fleet health + stats; see
//!            [--connect-timeout-ms MS]     ARCHITECTURE §Cluster
//!            [--rpc-read-timeout-ms MS]
//!
//! Observability flags (any subcommand that serves traffic):
//!   --trace            enable span tracing into the in-memory ring
//!                      (inspect via tests/tools; cheap, bounded)
//!   --trace-log PATH   also append every span as one JSON line to PATH
//!                      (implies --trace); see ARCHITECTURE §Observability
//! Every serving node exposes `GET /metrics` (Prometheus text format);
//! the router's /metrics aggregates all reachable workers' families with
//! a `worker="i"` label.

use anyhow::{bail, Result};

use raana::calib::CalibMode;
use raana::cli::Args;
use raana::experiments::{baseline_quantize, raana_quantize, Baseline, Env};
use raana::model::artifacts_root;
use raana::quant::TrickConfig;
use raana::runtime::Runtime;
use raana::util::Timer;
use raana::{benchlib, info};

fn main() -> Result<()> {
    let args = Args::from_env();
    apply_trace_args(&args)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => cmd_info(),
        "train" => cmd_train(&args),
        "quantize" => cmd_quantize(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args, false),
        "worker" => cmd_serve(&args, true),
        "router" => cmd_router(&args),
        "index" => cmd_index(&args),
        "table" => cmd_table(&args),
        "help" | _ => {
            println!(
                "raana — RaanA post-training quantization (paper reproduction)\n\
                 usage: raana <info|train|quantize|eval|serve|index|worker|router> [--options]\n\
                 see README.md; tables are regenerated via `cargo bench`"
            );
            Ok(())
        }
    }
}

/// Wire `--trace` / `--trace-log PATH` into the process-wide tracer
/// before any subcommand starts serving. `--trace-log` implies `--trace`
/// (the sink enables tracing); `--trace` alone records into the bounded
/// in-memory ring only.
fn apply_trace_args(args: &Args) -> Result<()> {
    if let Some(path) = args.opt("trace-log") {
        raana::obs::trace::tracer()
            .set_jsonl_sink(std::path::Path::new(path))
            .map_err(|e| anyhow::anyhow!("--trace-log {path}: {e}"))?;
        info!("tracing enabled, spans appended to {path}");
    } else if args.flag("trace") {
        raana::obs::trace::tracer().set_enabled(true);
        info!("tracing enabled (in-memory ring only)");
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    match Runtime::cpu() {
        Ok(rt) => println!(
            "platform: {} ({} devices)",
            rt.client.platform_name(),
            rt.client.device_count()
        ),
        Err(_) => println!("platform: PJRT unavailable — native CPU backend (fused kernels)"),
    }
    let root = artifacts_root();
    println!("artifacts root: {}", root.display());
    for model in ["micro", "tiny", "small"] {
        let dir = root.join(model);
        if dir.join("manifest.json").exists() {
            let m = raana::model::Manifest::load(&dir)?;
            println!(
                "  model {model}: d={} layers={} params={} linears={} ({} quantizable)",
                m.d_model,
                m.n_layers,
                m.total_params(),
                m.linears.len(),
                m.total_linear_params()
            );
        }
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.opt_or("model", "tiny");
    let steps = args.opt_usize("steps", 300)?;
    std::env::set_var("RAANA_TRAIN_STEPS", steps.to_string());
    // Env::load trains when no checkpoint exists; --force retrains.
    let root = artifacts_root();
    let ckpt = root.join(model).join("trained.rkpt");
    if args.flag("force") && ckpt.exists() {
        std::fs::remove_file(&ckpt)?;
    }
    let mut env = Env::load(model)?;
    // --more N: warm-resume N additional steps from the checkpoint
    let more = args.opt_usize("more", 0)?;
    if more > 0 {
        let cfg = raana::train::TrainConfig {
            steps: more,
            lr: args.opt_f64("lr", 1e-3)?,
            warmup: 10,
            ..Default::default()
        };
        raana::train::train(&env.mrt, &mut env.params, &env.wiki, &cfg)?;
        env.params.save(&env.ckpt_path)?;
    }
    let ppl = env.perplexity(&env.params, &env.wiki, 32)?;
    info!("trained model ppl(synthwiki) = {ppl:.3}");
    println!("checkpoint: {}", env.ckpt_path.display());
    Ok(())
}

fn tricks_from_args(args: &Args) -> TrickConfig {
    let mut t = TrickConfig::default();
    if args.flag("no-tricks") {
        t = TrickConfig::none();
    }
    t
}

fn calib_from_args(args: &Args) -> Result<CalibMode> {
    match args.opt_or("calib", "few:5") {
        "zero" => Ok(CalibMode::ZeroShot),
        s if s.starts_with("few:") => Ok(CalibMode::FewShot(s[4..].parse()?)),
        s => bail!("bad --calib '{s}'"),
    }
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let model = args.opt_or("model", "tiny");
    let avg_bits = args.opt_f64("avg-bits", 3.1)?;
    let env = Env::load(model)?;
    let mode = calib_from_args(args)?;
    let tricks = tricks_from_args(args);
    let timer = Timer::start();
    let (qparams, report) =
        raana_quantize(&env, &mode, avg_bits, &(1..=8).collect::<Vec<u8>>(), &tricks, 99, 0)?;
    println!(
        "quantized {} layers to avg {:.3} bits in {:.2}s (calib {:.2}s, alloc {:.3}s, quant {:.2}s)",
        report.layers.len(),
        report.avg_bits,
        timer.secs(),
        report.secs.0,
        report.secs.1,
        report.secs.2
    );
    for l in &report.layers {
        println!(
            "  {:<16} {} bits  avg {:.3}  recon rel err {:.4}",
            l.name, l.bits, l.avg_bits, l.recon_rel_err
        );
    }
    let cap = args.opt_usize("eval-cap", 32)?;
    let ppl_fp = env.perplexity(&env.params, &env.wiki, cap)?;
    let ppl_q = env.perplexity(&qparams, &env.wiki, cap)?;
    println!("ppl fp32 {ppl_fp:.3} -> quantized {ppl_q:.3}");
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.opt_or("model", "tiny");
    let env = Env::load(model)?;
    let corpus = match args.opt_or("dataset", "wiki") {
        "c4" => &env.c4,
        _ => &env.wiki,
    };
    let cap = args.opt_usize("eval-cap", 64)?;
    // optional uniform baseline comparison
    if let Some(method) = args.opt("baseline") {
        let bits = args.opt_usize("bits", 4)? as u8;
        let mode = calib_from_args(args)?;
        let calib = raana::calib::calibrate(&env.mrt, &env.params, &mode, &env.wiki)?;
        let b = match method {
            "rtn" => Baseline::Rtn,
            "gptq" => Baseline::Gptq,
            "awq" => Baseline::Awq,
            "easyquant" => Baseline::EasyQuant,
            _ => bail!("unknown baseline '{method}'"),
        };
        let (qp, avg) = baseline_quantize(&env, &calib, b, bits)?;
        let ppl = env.perplexity(&qp, corpus, cap)?;
        println!("{} @ {:.2} avg bits: ppl {}", b.name(), avg, benchlib::fmt_ppl(ppl));
        return Ok(());
    }
    let ppl = env.perplexity(&env.params, corpus, cap)?;
    println!("fp32 ppl: {}", benchlib::fmt_ppl(ppl));
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    use raana::experiments::tables::{calib_comparison, method_grid, quant_time, Dataset};
    let n = args.opt_usize("n", 1)?;
    let model = args.opt_or("model", "tiny");
    let cap = args.opt_usize("eval-cap", 16)?;
    let table = match n {
        1 => method_grid(&Env::load(model)?, Dataset::SynthWiki, cap)?,
        2 => calib_comparison(&Env::load(model)?, Dataset::SynthWiki, cap)?,
        3 => quant_time(&["micro", model])?,
        4 => method_grid(&Env::load(model)?, Dataset::SynthC4, cap)?,
        5 => calib_comparison(&Env::load(model)?, Dataset::SynthC4, cap)?,
        _ => bail!("--n must be 1..=5 (paper tables)"),
    };
    println!("=== Paper Table {n} (model {model}) ===\n{}", table.render());
    Ok(())
}

/// Shared bits/budget → [`raana::index::IndexConfig`] construction for
/// the `serve --http` flags (`--index-bits`/`--index-budget`) and the
/// `index` demo's (`--bits`/`--budget`). A budget without an explicit
/// width lets AllocateBits pick per-collection widths under it, weighted
/// by measured recall sensitivity.
fn index_cfg(bits: usize, budget: usize, flag: &str) -> Result<raana::index::IndexConfig> {
    use raana::index::{IndexConfig, IndexPolicy};
    let policy = match bits {
        0 if budget > 0 => IndexPolicy::Budget { bit_choices: vec![2, 3, 4, 5, 6, 8] },
        0 => IndexPolicy::Uniform(8),
        b if (1..=8).contains(&b) => IndexPolicy::Uniform(b as u8),
        b => bail!("--{flag} must be in 1..=8, got {b}"),
    };
    Ok(IndexConfig { policy, budget_bytes: budget, ..Default::default() })
}

/// `--index-bits N` / `--index-budget BYTES` → index config (serve path).
fn index_cfg_from_args(args: &Args) -> Result<raana::index::IndexConfig> {
    index_cfg(
        args.opt_usize("index-bits", 0)?,
        args.opt_usize("index-budget", 0)?,
        "index-bits",
    )
}

/// `--data-dir PATH [--fsync always|never] [--snapshot-every N]
/// [--segment-rows N]` → durability config. `None` without `--data-dir`
/// (ephemeral store, the pre-durability behavior). fsync defaults to
/// `always` — an acked add survives power loss; `--fsync never` trades
/// that for ingest speed (recovery still tolerates the resulting torn
/// tails). `--snapshot-every` counts *rows* acknowledged since the last
/// seal (a bulk add of 300 rows crosses a cadence of 256 immediately);
/// `--segment-rows` additionally seals as soon as any one collection's
/// mutable head reaches that many rows, bounding both WAL replay and
/// per-seal cost. Either can be 0 to disable that trigger.
fn durability_from_args(args: &Args) -> Result<Option<raana::index::durability::DurabilityConfig>> {
    use raana::index::durability::{DurabilityConfig, FsyncPolicy};
    let Some(dir) = args.opt("data-dir") else {
        return Ok(None);
    };
    let fsync = match args.opt_or("fsync", "always") {
        "always" => FsyncPolicy::Always,
        "never" => FsyncPolicy::Never,
        s => bail!("--fsync must be 'always' or 'never', got '{s}'"),
    };
    Ok(Some(DurabilityConfig {
        data_dir: std::path::PathBuf::from(dir),
        fsync,
        snapshot_every: args.opt_usize("snapshot-every", 256)?,
        segment_rows: args.opt_usize("segment-rows", 4096)?,
    }))
}

/// `--kv-bits N` / `--kv-budget BYTES` → KV storage policy + budget.
fn kv_from_args(args: &Args) -> Result<(raana::kvq::KvqPolicy, usize)> {
    use raana::kvq::KvqPolicy;
    let budget = args.opt_usize("kv-budget", 0)?;
    let policy = match args.opt_usize("kv-bits", 0)? {
        0 if budget > 0 => {
            // budget without an explicit width: let AllocateBits pick
            // per-layer (K, V) bit-widths under the per-lane share
            KvqPolicy::Budget { bit_choices: vec![2, 3, 4, 5, 6, 8] }
        }
        0 => KvqPolicy::DenseF32,
        b if (1..=8).contains(&b) => KvqPolicy::Uniform(b as u8),
        b => bail!("--kv-bits must be in 1..=8, got {b}"),
    };
    Ok((policy, budget))
}

/// `raana serve` and `raana worker` — a worker is a `serve --http` node
/// that publishes a drain signal on stdin EOF (see [`serve_http`]).
fn cmd_serve(args: &Args, worker_mode: bool) -> Result<()> {
    let model = args.opt_or("model", "tiny");
    let n_req = args.opt_usize("requests", 16)?;
    let new_tokens = args.opt_usize("tokens", 16)?;
    // A worker is HTTP-only: default to an ephemeral port when --http is
    // absent (the bound address is printed).
    let http_opt: Option<String> = args
        .opt("http")
        .map(str::to_string)
        .or_else(|| worker_mode.then(|| "0".to_string()));
    // Bounded admission queue: HTTP runs default to 64 (backpressure as
    // 429), in-process demo runs stay unbounded as before.
    let (kv, kv_budget_bytes) = kv_from_args(args)?;
    let cfg = raana::serve::ServeConfig {
        max_queue: args.opt_usize("max-queue", if http_opt.is_some() { 64 } else { 0 })?,
        kv,
        kv_budget_bytes,
    };

    // Index serving rides along on the HTTP front-end unless opted out:
    // the same manifest/params/packed triple backs the embed path.
    let want_index = http_opt.is_some() && !args.flag("no-index");

    // Artifact-free path: serve a native-initialized model straight from
    // packed codes (demonstrates the request path without `make artifacts`).
    let have_artifacts = artifacts_root().join(model).join("manifest.json").exists();
    let (server, batch, index) = if args.flag("native") || !have_artifacts {
        if !have_artifacts {
            info!("artifacts/{model} missing — native packed-serving demo (untrained weights)");
        }
        build_native_demo_server(args, cfg, want_index)?
    } else {
        build_artifact_server(args, model, cfg, want_index)?
    };
    match http_opt {
        Some(port) => serve_http(server, index, &port, args, worker_mode),
        None => run_requests(server, n_req, new_tokens, batch),
    }
}

/// `raana router` — front a set of running workers (see
/// `rust/src/cluster/`): consistent-hash placement, scatter-gather
/// queries, generate load-balancing, fleet health and stats.
fn cmd_router(args: &Args) -> Result<()> {
    use raana::cluster::{Router, RouterConfig, DEFAULT_DOWN_AFTER};
    let workers: Vec<String> = args
        .opt("workers")
        .unwrap_or("")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if workers.is_empty() {
        bail!("--workers host:port[,host:port...] is required (start them with `raana worker`)");
    }
    let port = args.opt_or("http", "0");
    let addr = if port.contains(':') { port.to_string() } else { format!("127.0.0.1:{port}") };
    let mut client = raana::net::ClientConfig::timeout_ms(raana::cluster::DEFAULT_RPC_TIMEOUT_MS);
    let connect_ms = args.opt_usize("connect-timeout-ms", 0)? as u64;
    if connect_ms > 0 {
        client.connect_timeout = Some(std::time::Duration::from_millis(connect_ms));
    }
    let read_ms = args.opt_usize("rpc-read-timeout-ms", 0)? as u64;
    if read_ms > 0 {
        client.read_timeout = Some(std::time::Duration::from_millis(read_ms));
    }
    let n_workers = workers.len();
    let router = Router::bind(
        &addr,
        RouterConfig {
            workers,
            shards: args.opt_usize("shards", 0)?,
            http_workers: args.opt_usize("http-workers", 0)?,
            probe_interval_ms: args.opt_usize("probe-ms", 0)? as u64,
            down_after: args.opt_usize("down-after", DEFAULT_DOWN_AFTER as usize)? as u32,
            client,
            read_timeout_ms: args.opt_usize("http-read-timeout-ms", 0)? as u64,
        },
    )?;
    let bound = router.local_addr();
    println!(
        "router on http://{bound} fronting {n_workers} workers  \
         (close stdin / Ctrl-D for graceful drain)"
    );
    println!("  curl -s http://{bound}/healthz");
    println!("  curl -s http://{bound}/v1/stats");
    let mut line = String::new();
    loop {
        line.clear();
        match std::io::stdin().read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
    info!("stdin closed — draining router connections");
    router.shutdown()
}

/// Build the optional index server from clones of the serving triple
/// (the generate batcher owns the originals; the embed path duplicates
/// the weights — acceptable at these model sizes, documented in
/// ARCHITECTURE §Retrieval).
fn maybe_index_server(
    args: &Args,
    want_index: bool,
    manifest: &raana::model::Manifest,
    params: &raana::model::ModelParams,
    packed: &raana::runtime::PackedLayers,
) -> Result<Option<raana::serve::index::IndexServer>> {
    if !want_index {
        return Ok(None);
    }
    let durability = durability_from_args(args)?;
    let ix = raana::serve::index::IndexServer::with_embedder(
        index_cfg_from_args(args)?,
        durability,
        manifest.clone(),
        params.clone(),
        Some(packed.clone()),
    )?;
    if let Some(rep) = ix.recovery() {
        info!(
            "index recovery: {} rows restored ({} from sealed segments, {} replayed), \
             {} records dropped, {} duplicates skipped",
            rep.recovered_rows(),
            rep.snapshot_rows,
            rep.replayed_rows,
            rep.dropped_records,
            rep.duplicate_records
        );
    }
    Ok(Some(ix))
}

/// Quantize the trained `model` and start a packed-code server over it.
fn build_artifact_server(
    args: &Args,
    model: &str,
    cfg: raana::serve::ServeConfig,
    want_index: bool,
) -> Result<(raana::serve::Server, usize, Option<raana::serve::index::IndexServer>)> {
    let env = Env::load(model)?;
    // quantize, keeping the codes bit-packed: the server's fwd_logits
    // computes on them via qgemm, with zero dequantization per forward
    let (packed, report) = raana::experiments::raana_quantize_packed(
        &env,
        &CalibMode::FewShot(5),
        args.opt_f64("avg-bits", 4.1)?,
        &(1..=8).collect::<Vec<u8>>(),
        &TrickConfig::default(),
        7,
        0,
    )?;
    info!(
        "serving packed model at avg {:.2} bits ({} KiB of codes resident)",
        report.avg_bits,
        packed.stored_bits() / 8 / 1024
    );
    let manifest = env.mrt.manifest.clone();
    let batch = manifest.eval_batch;
    let params = env.params.clone();
    drop(env); // the server thread owns its own (native) runtime
    let index = maybe_index_server(args, want_index, &manifest, &params, &packed)?;
    let server = raana::serve::Server::start_native_packed_with(manifest, params, packed, cfg)?;
    Ok((server, batch, index))
}

/// Synthesize + pack a demo model and start a server over it.
fn build_native_demo_server(
    args: &Args,
    cfg: raana::serve::ServeConfig,
    want_index: bool,
) -> Result<(raana::serve::Server, usize, Option<raana::serve::index::IndexServer>)> {
    let bits_raw = args.opt_usize("bits", 4)?;
    if !(1..=8).contains(&bits_raw) {
        bail!("--bits must be in 1..=8, got {bits_raw}");
    }
    let bits = bits_raw as u8;
    let d = args.opt_usize("d-model", 256)?;
    let layers = args.opt_usize("layers", 4)?;
    let (manifest, params, packed) =
        raana::experiments::native_demo_packed("native-demo", d, layers, bits, 7)?;
    info!(
        "packed {} linears at {bits} bits (avg {:.2} incl. side payloads)",
        manifest.linears.len(),
        packed.avg_bits()
    );
    let batch = manifest.eval_batch;
    let index = maybe_index_server(args, want_index, &manifest, &params, &packed)?;
    let server = raana::serve::Server::start_native_packed_with(manifest, params, packed, cfg)?;
    Ok((server, batch, index))
}

/// Front the batching server with the HTTP layer until stdin closes, then
/// drain gracefully (SIGTERM-style: stop accepting, finish in-flight
/// work, collect final stats).
///
/// In `worker_mode` (the `raana worker` subcommand) stdin EOF first
/// flips the healthz drain signal and holds the node fully serving for
/// `--drain-grace-ms` (default 1000): the cluster router observes
/// `"state":"draining"` on its next probe and stops sending *new*
/// generate traffic, while requests already in flight — and scatter-
/// gather reads, which need this node's shards — complete normally.
/// Only then does the listener close. That ordering is what makes a
/// drain lose no requests.
fn serve_http(
    server: raana::serve::Server,
    index: Option<raana::serve::index::IndexServer>,
    port: &str,
    args: &Args,
    worker_mode: bool,
) -> Result<()> {
    let server = std::sync::Arc::new(server);
    let index = index.map(std::sync::Arc::new);
    let addr = if port.contains(':') { port.to_string() } else { format!("127.0.0.1:{port}") };
    let drain = worker_mode
        .then(|| std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false)));
    let http = raana::net::HttpServer::bind_with_index(
        std::sync::Arc::clone(&server),
        index.clone(),
        &addr,
        raana::net::HttpConfig {
            workers: args.opt_usize("http-workers", 0)?,
            max_new_tokens_cap: args.opt_usize("http-max-tokens", 0)?,
            read_timeout_ms: args.opt_usize("http-read-timeout-ms", 0)? as u64,
            drain: drain.clone(),
        },
    )?;
    // Background compactor (durable stores only): merges small sealed
    // segments and retires stale-width files while serving; every pass
    // commits atomically, so stopping it mid-flight is always safe.
    let compactor = index
        .as_ref()
        .filter(|ix| ix.stats().durable)
        .map(|ix| ix.start_compactor(std::time::Duration::from_secs(30)));
    let bound = http.local_addr();
    println!("HTTP serving on http://{bound}  (close stdin / Ctrl-D for graceful drain)");
    println!("  curl -s http://{bound}/healthz");
    println!("  curl -s http://{bound}/v1/stats");
    println!(
        "  curl -s -X POST http://{bound}/v1/generate -d \
         '{{\"prompt\":[84,104,101,32],\"max_new_tokens\":16}}'"
    );
    if index.is_some() {
        println!("  curl -s -X POST http://{bound}/v1/embed -d '{{\"text\":\"hello\"}}'");
        println!(
            "  curl -s -X POST http://{bound}/v1/collections/docs/add -d \
             '{{\"texts\":[\"first doc\",\"second doc\"]}}'"
        );
        println!(
            "  curl -s -X POST http://{bound}/v1/collections/docs/query -d \
             '{{\"text\":\"first\",\"k\":2}}'"
        );
        println!("  curl -s http://{bound}/v1/collections");
    }
    let mut line = String::new();
    loop {
        line.clear();
        match std::io::stdin().read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
    if let Some(d) = &drain {
        d.store(true, std::sync::atomic::Ordering::SeqCst);
        let grace = args.opt_usize("drain-grace-ms", 1000)? as u64;
        info!("stdin closed — draining (healthz now answers \"draining\", {grace} ms grace)");
        std::thread::sleep(std::time::Duration::from_millis(grace));
    }
    info!("stdin closed — draining HTTP connections");
    http.shutdown()?;
    if let Some(c) = compactor {
        c.stop();
    }
    let server = std::sync::Arc::try_unwrap(server)
        .map_err(|_| anyhow::anyhow!("HTTP layer still holds the server"))?;
    let stats = server.shutdown()?;
    println!(
        "served {} completions ({} cancelled), {:.1} tok/s, p50 {:.1} ms p95 {:.1} ms",
        stats.completions,
        stats.cancelled,
        stats.throughput_tok_s(),
        stats.p50_latency() * 1e3,
        stats.p95_latency() * 1e3
    );
    if let Some(ix) = &index {
        let s = ix.stats();
        if s.durable {
            // orderly shutdown: seal every head into a segment so the
            // next start recovers from the manifest without replaying a
            // long WAL tail
            ix.seal_now()?;
        }
        println!(
            "index: {} collections, {} rows, {} embeds, {} queries, {} B scan payload",
            s.collections, s.rows, s.embeds, s.queries, s.code_bytes
        );
    }
    Ok(())
}

/// `raana index` — artifact-free retrieval demo: synthesize + pack a demo
/// model, embed a small document set, self-retrieve every document, and
/// report recall plus the scan-payload economics.
fn cmd_index(args: &Args) -> Result<()> {
    use raana::serve::index::IndexServer;
    let d = args.opt_usize("d-model", 128)?;
    let layers = args.opt_usize("layers", 2)?;
    let n_docs = args.opt_usize("docs", 24)?.max(2);
    let k = args.opt_usize("k", 5)?.max(1);
    let rerank = args.opt_usize("rerank", raana::index::DEFAULT_RERANK_FACTOR)?.max(1);
    let cfg = index_cfg(args.opt_usize("bits", 0)?, args.opt_usize("budget", 0)?, "bits")?;
    let (manifest, params, packed) =
        raana::experiments::native_demo_packed("index-demo", d, layers, 4, 7)?;
    info!(
        "embedding with a packed demo model: d={d}, {layers} layers, {} linears on codes",
        manifest.linears.len()
    );
    let ix = IndexServer::with_embedder(cfg, None, manifest, params, Some(packed))?;
    let dim = ix.embed_dim().expect("embedder attached");

    // synthesize distinct "documents" from the synthetic corpus
    let corpus = raana::data::synthwiki(1 << 14, 11);
    let words: Vec<&str> = corpus.split_whitespace().collect();
    let docs: Vec<String> = (0..n_docs)
        .map(|i| {
            let w0 = (i * 13) % words.len().saturating_sub(9).max(1);
            format!("doc {i}: {}", words[w0..(w0 + 8).min(words.len())].join(" "))
        })
        .collect();
    for doc in &docs {
        let emb = ix.embed(&raana::data::tokenize(doc))?;
        ix.add("demo", &emb, dim)?;
    }

    // self-retrieval: every document must come back as its own top hit
    let mut hits_at_1 = 0usize;
    let mut t = benchlib::Table::new(&["query doc", "top-1 id", "score", "top-k ids"]);
    for (i, doc) in docs.iter().enumerate() {
        let q = ix.embed(&raana::data::tokenize(doc))?;
        let hits = ix.query("demo", &q, k, rerank)?;
        if hits.first().map(|h| h.id) == Some(i) {
            hits_at_1 += 1;
        }
        if i < 8 {
            t.row(vec![
                format!("{i}"),
                hits.first().map(|h| h.id.to_string()).unwrap_or_default(),
                hits.first().map(|h| format!("{:.4}", h.score)).unwrap_or_default(),
                hits.iter().map(|h| h.id.to_string()).collect::<Vec<_>>().join(","),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "self-retrieval recall@1: {hits_at_1}/{} (two-phase: coded scan + exact rerank x{rerank})",
        docs.len()
    );
    let mut t = benchlib::Table::new(&["collection", "rows", "dim", "bits", "B/row (scan)", "f32 B/row"]);
    for c in ix.collections() {
        t.row(vec![
            c.name.clone(),
            c.rows.to_string(),
            c.dim.to_string(),
            c.bits.to_string(),
            c.bytes_per_row.to_string(),
            (4 * c.dim).to_string(),
        ]);
    }
    println!("{}", t.render());
    let s = ix.stats();
    println!(
        "{} embeds, {} queries, {} B scan payload total",
        s.embeds, s.queries, s.code_bytes
    );
    Ok(())
}

fn run_requests(
    server: raana::serve::Server,
    n_req: usize,
    new_tokens: usize,
    batch: usize,
) -> Result<()> {
    let mut rxs = Vec::new();
    for i in 0..n_req {
        let prompt = raana::data::tokenize(&format!("The {i} quick brown fox "));
        let (_, rx) = server.submit(prompt, new_tokens, 0.8, i as u64)?;
        rxs.push(rx);
    }
    for rx in rxs {
        let c = rx.recv()?;
        println!(
            "req {:>3}: {:>5.1} ms  '{}'",
            c.id,
            c.latency_secs * 1e3,
            raana::data::detokenize(&c.tokens).escape_debug()
        );
    }
    let stats = server.shutdown()?;
    println!(
        "served {} completions, {:.1} tok/s, occupancy {:.2}, p50 {:.1} ms p95 {:.1} ms \
         ({} prefill tokens, {} decode steps, {} window slides)",
        stats.completions,
        stats.throughput_tok_s(),
        stats.mean_batch_occupancy(batch),
        stats.p50_latency() * 1e3,
        stats.p95_latency() * 1e3,
        stats.prefill_tokens,
        stats.decode_steps,
        stats.window_slides
    );
    Ok(())
}
