//! Quantized KV-cache subsystem: RaBitQ-compressed K/V storage with
//! attend-over-codes and per-layer bit allocation (ISSUE 4).
//!
//! At serving scale the KV cache, not the weights, caps concurrent lanes
//! per byte of RAM: the weights are shared across lanes, but every lane
//! owns `2 * n_layers * capacity * d_model` floats of K/V window. This
//! module applies the paper's own machinery to that stream:
//!
//! * **Storage** ([`QuantizedKvStore`]) — each K and V row's per-head
//!   segment is RHT-rotated ([`crate::hadamard::PracticalRht`] over
//!   `head_dim`, shared Rademacher signs) and grid-quantized with
//!   [`crate::rabitq::quantize_column_into`] at [`ScaleMode::MaxAbs`]
//!   (one pass — quantization sits on the per-token hot path, where the
//!   extended scale search would cost ~8x for marginal gain). Codes are
//!   bit-packed into one shared buffer per layer; the only f32 side
//!   payload is one least-squares rescale per (row, head).
//! * **Compute** — attention never reconstructs the cache:
//!   [`crate::kernels::attend_cached_q`] estimates scores from K codes
//!   (Algorithm 3 per cached row) and mixes V codes in rotated space.
//! * **Bit plan** ([`KvqPlan`], [`KvqPolicy`]) — K and V get separate
//!   per-layer bit-widths, chosen by the paper's AllocateBits DP
//!   ([`crate::allocate`]) under a per-lane byte budget, driven by
//!   [`KvSensitivity`] estimates (attention logits are more bit-sensitive
//!   than value mixing, so K sensitivities carry [`K_LOGIT_WEIGHT`]).
//!
//! The accuracy contract is **bounded drift**, not bit-exactness: per the
//! RaBitQ bound the attention error decays ~`2^-bits`, property-tested as
//! a monotone 2/4/8-bit quality ladder (EXPERIMENTS.md §KV compression)
//! and pinned by the `kvq_attend` golden vectors. What *is* exact: the
//! quantize→pack path is deterministic, and every attend reduces in a
//! batch-size-independent order, so quantized decode steps reproduce a
//! quantized re-prefill of the same context bit-for-bit.
#![deny(missing_docs)]

use anyhow::Result;

use crate::allocate::AllocProblem;
use crate::hadamard::PracticalRht;
use crate::kernels::{self, AttendQScratch, QuantView};
use crate::model::{Manifest, ModelParams};
use crate::rabitq::{quantize_column_into, ScaleMode};
use crate::rng::Rng;
use crate::runtime::native::{NativeModel, PackedLayers};

/// Multiplier applied to K-row sensitivities when no measured
/// [`KvSensitivity`] is supplied (and the default ratio inside
/// [`estimate_kv_sensitivity`]'s alphas): quantization error on K perturbs
/// attention *logits*, which the softmax amplifies into weight shifts
/// across the whole window, while V error enters the output linearly — so
/// K deserves more bits at equal measured magnitude.
pub const K_LOGIT_WEIGHT: f64 = 4.0;

/// Default seed for the cache's Rademacher rotation signs. Any fixed seed
/// works (the rotation only needs to be shared between store and attend);
/// a constant keeps serving runs reproducible.
pub const DEFAULT_ROT_SEED: u64 = 0x6b76_5157;

// ------------------------------------------------------------------ errors

/// Typed configuration errors for the quantized KV cache — surfaced at
/// `Server` construction (config validation) instead of as a runtime
/// panic/death inside the batcher.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KvqError {
    /// A requested KV bit-width outside 1..=8.
    BadBits(u8),
    /// The byte budget cannot fit even one lane at the cheapest allowed
    /// plan; `min_lane_bytes` is the smallest admissible per-lane size.
    BudgetTooSmall {
        /// The offending budget, in bytes.
        budget_bytes: usize,
        /// Smallest per-lane footprint any admissible plan can reach.
        min_lane_bytes: usize,
    },
    /// Shape/arity mismatch (plan length vs layers, head divisibility, …).
    Shape(String),
}

impl std::fmt::Display for KvqError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvqError::BadBits(b) => write!(f, "KV bit-width {b} outside 1..=8"),
            KvqError::BudgetTooSmall { budget_bytes, min_lane_bytes } => write!(
                f,
                "KV budget of {budget_bytes} bytes cannot fit one lane \
                 (minimum {min_lane_bytes} bytes per lane)"
            ),
            KvqError::Shape(msg) => write!(f, "KV cache shape error: {msg}"),
        }
    }
}

impl std::error::Error for KvqError {}

impl From<KvqError> for anyhow::Error {
    fn from(e: KvqError) -> anyhow::Error {
        anyhow::Error::msg(e.to_string())
    }
}

// ------------------------------------------------------------------- plan

/// Per-layer KV bit plan: `bits[layer] = (k_bits, v_bits)`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KvqPlan {
    /// One `(K bits, V bits)` pair per transformer layer.
    pub bits: Vec<(u8, u8)>,
}

impl KvqPlan {
    /// Same bit-width everywhere (the `serve --kv-bits N` plan).
    pub fn uniform(n_layers: usize, bits: u8) -> Result<KvqPlan, KvqError> {
        if !(1..=8).contains(&bits) {
            return Err(KvqError::BadBits(bits));
        }
        Ok(KvqPlan { bits: vec![(bits, bits); n_layers] })
    }

    /// Reject malformed plans (empty, or any width outside 1..=8).
    pub fn validate(&self) -> Result<(), KvqError> {
        if self.bits.is_empty() {
            return Err(KvqError::Shape("empty bit plan".into()));
        }
        for &(kb, vb) in &self.bits {
            for b in [kb, vb] {
                if !(1..=8).contains(&b) {
                    return Err(KvqError::BadBits(b));
                }
            }
        }
        Ok(())
    }

    /// Mean stored bits per cached K/V element (codes only).
    pub fn avg_bits(&self) -> f64 {
        if self.bits.is_empty() {
            return 0.0;
        }
        let total: usize = self.bits.iter().map(|&(k, v)| k as usize + v as usize).sum();
        total as f64 / (2 * self.bits.len()) as f64
    }

    /// Exact per-lane footprint in bytes: per layer, the packed K and V
    /// code payloads for `capacity` rows plus the two f32 rescale tables
    /// (one per (row, head) for each of K and V).
    pub fn bytes_per_lane(&self, capacity: usize, d_model: usize, n_heads: usize) -> usize {
        let mut total = 0usize;
        for &(kb, vb) in &self.bits {
            total += (capacity * d_model * kb as usize).div_ceil(8);
            total += (capacity * d_model * vb as usize).div_ceil(8);
            total += 2 * capacity * n_heads * 4; // r payloads
        }
        total
    }

    /// AllocateBits over the KV stream: pick per-layer (K, V) bit-widths
    /// minimizing `Σ α 2^-b` such that one lane fits `lane_budget_bytes`.
    ///
    /// The DP (paper Alg. 4, GCD-reduced) sees `2 * n_layers` items — each
    /// layer's K stream and V stream separately, every one sized
    /// `capacity * d_model` codes — with the fixed rescale payload
    /// subtracted from the budget up front. Without a measured
    /// [`KvSensitivity`] the alphas default to [`K_LOGIT_WEIGHT`] : 1.
    pub fn solve_for_budget(
        n_layers: usize,
        capacity: usize,
        d_model: usize,
        n_heads: usize,
        lane_budget_bytes: usize,
        bit_choices: &[u8],
        sens: Option<&KvSensitivity>,
    ) -> Result<KvqPlan, KvqError> {
        if bit_choices.is_empty() {
            return Err(KvqError::Shape("empty KV bit-choice set".into()));
        }
        if let Some(&b) = bit_choices.iter().find(|&&b| !(1..=8).contains(&b)) {
            return Err(KvqError::BadBits(b));
        }
        if let Some(s) = sens {
            if s.alpha_k.len() != n_layers || s.alpha_v.len() != n_layers {
                return Err(KvqError::Shape(format!(
                    "sensitivity arity {}/{} != {n_layers} layers",
                    s.alpha_k.len(),
                    s.alpha_v.len()
                )));
            }
        }
        let min_b = *bit_choices.iter().min().unwrap();
        let min_lane = KvqPlan::uniform(n_layers, min_b)
            .expect("min_b validated")
            .bytes_per_lane(capacity, d_model, n_heads);
        if lane_budget_bytes < min_lane {
            return Err(KvqError::BudgetTooSmall {
                budget_bytes: lane_budget_bytes,
                min_lane_bytes: min_lane,
            });
        }
        let overhead_bytes = 2 * n_layers * capacity * n_heads * 4;
        let budget_bits = (lane_budget_bytes - overhead_bytes) as u64 * 8;
        let mut alphas = Vec::with_capacity(2 * n_layers);
        for l in 0..n_layers {
            match sens {
                Some(s) => {
                    alphas.push(s.alpha_k[l]);
                    alphas.push(s.alpha_v[l]);
                }
                None => {
                    alphas.push(K_LOGIT_WEIGHT);
                    alphas.push(1.0);
                }
            }
        }
        let problem = AllocProblem {
            alphas,
            m: vec![capacity * d_model; 2 * n_layers],
            bit_choices: bit_choices.to_vec(),
            budget: budget_bits,
        };
        let sol = problem
            .solve()
            .map_err(|e| KvqError::Shape(format!("AllocateBits failed: {e}")))?;
        let bits: Vec<(u8, u8)> =
            (0..n_layers).map(|l| (sol.bits[2 * l], sol.bits[2 * l + 1])).collect();
        let plan = KvqPlan { bits };
        // per-stream byte rounding can overshoot the bit budget by < 1
        // byte per stream; anything beyond that is a solver bug
        debug_assert!(
            plan.bytes_per_lane(capacity, d_model, n_heads)
                <= lane_budget_bytes + 2 * n_layers,
            "solved plan exceeds the lane budget"
        );
        Ok(plan)
    }
}

/// Per-lane footprint of the dense f32 cache (the baseline the
/// lanes-per-byte win is measured against): `2 * n_layers * capacity *
/// d_model` floats.
pub fn dense_bytes_per_lane(n_layers: usize, capacity: usize, d_model: usize) -> usize {
    2 * n_layers * capacity * d_model * 4
}

// ------------------------------------------------------------------ policy

/// How a serving lane pool stores its KV rows — the
/// [`crate::serve::ServeConfig`] knob behind `serve --kv-bits N` /
/// `--kv-budget BYTES`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KvqPolicy {
    /// Dense f32 rows: bit-identical decoding, 32 bits per element.
    DenseF32,
    /// Every layer's K and V quantized at one bit-width (1..=8).
    Uniform(u8),
    /// Per-layer (K, V) bit-widths solved by AllocateBits under the
    /// per-lane byte budget the server derives from its total
    /// `kv_budget_bytes`, weighted by measured [`KvSensitivity`].
    Budget {
        /// Candidate bit-widths for the DP (e.g. `[2, 4, 8]`).
        bit_choices: Vec<u8>,
    },
}

impl Default for KvqPolicy {
    fn default() -> Self {
        KvqPolicy::DenseF32
    }
}

impl KvqPolicy {
    /// Resolve the policy to a bit plan (`None` = keep dense f32 rows).
    ///
    /// `lane_budget_bytes` is required by [`KvqPolicy::Budget`] (it is the
    /// per-lane byte cap the DP solves under) and ignored otherwise;
    /// `sens` sharpens the Budget alphas when available.
    pub fn plan(
        &self,
        n_layers: usize,
        capacity: usize,
        d_model: usize,
        n_heads: usize,
        lane_budget_bytes: Option<usize>,
        sens: Option<&KvSensitivity>,
    ) -> Result<Option<KvqPlan>, KvqError> {
        match self {
            KvqPolicy::DenseF32 => Ok(None),
            KvqPolicy::Uniform(bits) => Ok(Some(KvqPlan::uniform(n_layers, *bits)?)),
            KvqPolicy::Budget { bit_choices } => {
                let budget = lane_budget_bytes.ok_or_else(|| {
                    KvqError::Shape(
                        "Budget KV policy needs a kv_budget_bytes to derive lane budgets".into(),
                    )
                })?;
                Ok(Some(KvqPlan::solve_for_budget(
                    n_layers,
                    capacity,
                    d_model,
                    n_heads,
                    budget,
                    bit_choices,
                    sens,
                )?))
            }
        }
    }
}

// ------------------------------------------------------------- sensitivity

/// Per-layer KV quantization sensitivities, AllocateBits-style: `alpha *
/// 2^-bits` models the layer's contribution to attention error.
#[derive(Clone, Debug)]
pub struct KvSensitivity {
    /// K-stream sensitivity per layer (logit path).
    pub alpha_k: Vec<f64>,
    /// V-stream sensitivity per layer (mixing path).
    pub alpha_v: Vec<f64>,
}

impl KvSensitivity {
    /// Flat default when no calibration forward is available: every layer
    /// equal, K weighted [`K_LOGIT_WEIGHT`]x over V.
    pub fn uniform(n_layers: usize) -> KvSensitivity {
        KvSensitivity {
            alpha_k: vec![K_LOGIT_WEIGHT; n_layers],
            alpha_v: vec![1.0; n_layers],
        }
    }
}

/// Measure per-layer KV sensitivities with one short calibration prefill:
/// run `sample` (truncated to the model window) through a dense 1-slot
/// cache, then read each layer's stored K/V rows and take mean squared row
/// norms — the magnitude entering the estimator's error bound (`|err| ∝
/// ||q|| ||k|| 2^-b`). K alphas carry [`K_LOGIT_WEIGHT`] on top, for the
/// softmax amplification of logit error.
pub fn estimate_kv_sensitivity(
    model: &NativeModel,
    m: &Manifest,
    params: &ModelParams,
    packed: Option<&PackedLayers>,
    sample: &[i32],
    threads: usize,
) -> Result<KvSensitivity> {
    anyhow::ensure!(!sample.is_empty(), "sensitivity sample must be non-empty");
    let take = sample.len().min(model.seq_len);
    let mut cache = model.kv_cache(1);
    model.prefill(m, params, packed, &sample[..take], &mut cache, 0, threads)?;
    let d = model.d_model;
    let mut alpha_k = Vec::with_capacity(model.n_layers);
    let mut alpha_v = Vec::with_capacity(model.n_layers);
    for layer in 0..model.n_layers {
        let (krows, vrows) = cache.window(layer, 0, take);
        let msn = |rows: &[f32]| -> f64 {
            let total: f64 = rows.iter().map(|&x| (x as f64) * (x as f64)).sum();
            total / take as f64
        };
        alpha_k.push(K_LOGIT_WEIGHT * msn(krows));
        alpha_v.push(msn(vrows));
    }
    Ok(KvSensitivity { alpha_k, alpha_v })
}

// ----------------------------------------------------------------- storage

/// Bit-packed K/V storage for one [`crate::runtime::KvCache`]: every row's
/// per-head segment lives as RaBitQ codes plus one f32 rescale, quantized
/// at store time and consumed by [`crate::kernels::attend_cached_q`]
/// without ever materializing f32 rows.
///
/// Layout per layer (bit-widths come from the [`KvqPlan`]): one packed
/// code buffer of `slots * capacity * d_model` elements for K and one for
/// V, plus rescale tables of `slots * capacity * n_heads` f32s. Rows are
/// addressed as `(slot * capacity + pos) * d_model`, mirroring the dense
/// cache so slot recycling works identically (stores overwrite in place —
/// the packer clears a row's bits before setting them).
#[derive(Clone)]
pub struct QuantizedKvStore {
    n_layers: usize,
    slots: usize,
    capacity: usize,
    d_model: usize,
    n_heads: usize,
    head_dim: usize,
    plan: KvqPlan,
    rot: PracticalRht,
    k_codes: Vec<Vec<u8>>,
    v_codes: Vec<Vec<u8>>,
    k_r: Vec<Vec<f32>>,
    v_r: Vec<Vec<f32>>,
    /// Store-path scratch: one rotated head segment.
    seg: Vec<f32>,
    /// Store-path scratch: one head segment's fresh codes.
    codes_buf: Vec<u8>,
}

impl std::fmt::Debug for QuantizedKvStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "QuantizedKvStore(layers={} slots={} capacity={} d={} heads={} avg_bits={:.2})",
            self.n_layers,
            self.slots,
            self.capacity,
            self.d_model,
            self.n_heads,
            self.plan.avg_bits()
        )
    }
}

/// Write `values` into a shared packed buffer starting at element index
/// `start`, clearing each element's bits first (slots are recycled, so a
/// row must overwrite whatever codes it lands on). Shared with
/// [`crate::index`], whose collections append rows into the same
/// LSB-first layout.
pub(crate) fn set_codes(data: &mut [u8], bits: u8, start: usize, values: &[u8]) {
    let bits = bits as usize;
    for (i, &v) in values.iter().enumerate() {
        let bit0 = (start + i) * bits;
        let byte0 = bit0 / 8;
        let off = bit0 % 8;
        let mask = ((1u16 << bits) - 1) << off;
        let w = (v as u16) << off;
        data[byte0] = (data[byte0] & !(mask as u8)) | (w & 0xFF) as u8;
        if off + bits > 8 {
            data[byte0 + 1] = (data[byte0 + 1] & !((mask >> 8) as u8)) | (w >> 8) as u8;
        }
    }
}

impl QuantizedKvStore {
    /// Allocate an all-empty quantized store. Fails on plan/shape
    /// mismatches (typed — this is the config-validation surface).
    pub fn new(
        n_layers: usize,
        slots: usize,
        capacity: usize,
        d_model: usize,
        n_heads: usize,
        plan: KvqPlan,
        rot_seed: u64,
    ) -> Result<QuantizedKvStore, KvqError> {
        plan.validate()?;
        if plan.bits.len() != n_layers {
            return Err(KvqError::Shape(format!(
                "bit plan covers {} layers, cache has {n_layers}",
                plan.bits.len()
            )));
        }
        if n_heads == 0 || d_model % n_heads != 0 {
            return Err(KvqError::Shape(format!(
                "d_model {d_model} not divisible by n_heads {n_heads}"
            )));
        }
        if n_layers == 0 || slots == 0 || capacity == 0 {
            return Err(KvqError::Shape("cache dimensions must be >= 1".into()));
        }
        let head_dim = d_model / n_heads;
        let elems = slots * capacity * d_model;
        let buf = |bits: u8| vec![0u8; (elems * bits as usize).div_ceil(8)];
        let mut rng = Rng::new(rot_seed);
        let rot = PracticalRht::sample(head_dim, &mut rng);
        Ok(QuantizedKvStore {
            n_layers,
            slots,
            capacity,
            d_model,
            n_heads,
            head_dim,
            k_codes: plan.bits.iter().map(|&(kb, _)| buf(kb)).collect(),
            v_codes: plan.bits.iter().map(|&(_, vb)| buf(vb)).collect(),
            k_r: vec![vec![0f32; slots * capacity * n_heads]; n_layers],
            v_r: vec![vec![0f32; slots * capacity * n_heads]; n_layers],
            plan,
            rot,
            seg: vec![0f32; head_dim],
            codes_buf: Vec::with_capacity(head_dim),
        })
    }

    /// Heads per row (must match the model this cache serves).
    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    /// The per-layer bit plan this store was allocated with.
    pub fn plan(&self) -> &KvqPlan {
        &self.plan
    }

    /// Per-lane footprint in bytes (codes + rescales for one slot).
    pub fn bytes_per_lane(&self) -> usize {
        self.plan.bytes_per_lane(self.capacity, self.d_model, self.n_heads)
    }

    /// Total buffer footprint in bytes across all slots.
    pub fn mem_bytes(&self) -> usize {
        let codes: usize = self
            .k_codes
            .iter()
            .chain(&self.v_codes)
            .map(|b| b.len())
            .sum();
        let rs: usize = self.k_r.iter().chain(&self.v_r).map(|r| r.len() * 4).sum();
        codes + rs
    }

    /// Quantize + pack one K row and one V row at `pos` of `(layer,
    /// slot)`: per head, rotate the segment, grid-quantize it
    /// ([`ScaleMode::MaxAbs`]), write codes in place and record the
    /// rescale. Deterministic in the inputs — re-storing the same row
    /// reproduces identical codes.
    pub fn store_row(&mut self, layer: usize, slot: usize, pos: usize, k: &[f32], v: &[f32]) {
        debug_assert!(layer < self.n_layers && slot < self.slots && pos < self.capacity);
        debug_assert!(k.len() == self.d_model && v.len() == self.d_model);
        let hd = self.head_dim;
        let (kb, vb) = self.plan.bits[layer];
        let base = (slot * self.capacity + pos) * self.d_model;
        let rbase = (slot * self.capacity + pos) * self.n_heads;
        let mut seg = std::mem::take(&mut self.seg);
        let mut codes = std::mem::take(&mut self.codes_buf);
        for h in 0..self.n_heads {
            seg.clear();
            seg.extend_from_slice(&k[h * hd..(h + 1) * hd]);
            self.rot.apply(&mut seg);
            let r = quantize_column_into(&seg, kb, ScaleMode::MaxAbs, &mut codes);
            self.k_r[layer][rbase + h] = r;
            set_codes(&mut self.k_codes[layer], kb, base + h * hd, &codes);

            seg.clear();
            seg.extend_from_slice(&v[h * hd..(h + 1) * hd]);
            self.rot.apply(&mut seg);
            let r = quantize_column_into(&seg, vb, ScaleMode::MaxAbs, &mut codes);
            self.v_r[layer][rbase + h] = r;
            set_codes(&mut self.v_codes[layer], vb, base + h * hd, &codes);
        }
        self.seg = seg;
        self.codes_buf = codes;
    }

    /// Fresh [`AttendQScratch`] sized for this store's widest window.
    pub fn scratch(&self) -> AttendQScratch {
        AttendQScratch::new(self.d_model, self.n_heads, self.capacity)
    }

    /// Single-query attention over the first `ctx` cached rows of
    /// `(layer, slot)`, straight from codes (accumulates into `out`; pass
    /// it zeroed — the [`crate::kernels::attend_cached`] contract).
    pub fn attend(
        &self,
        layer: usize,
        slot: usize,
        ctx: usize,
        q: &[f32],
        scratch: &mut AttendQScratch,
        out: &mut [f32],
    ) {
        debug_assert!(layer < self.n_layers && slot < self.slots && ctx <= self.capacity);
        // phase timing only: the clock reads bracket the kernel and feed
        // a histogram/span — nothing here touches the computation, which
        // is what keeps traced decode bit-identical to untraced
        let t0 = crate::obs::trace::tracer().now_us();
        let (kb, vb) = self.plan.bits[layer];
        let start = slot * self.capacity * self.d_model;
        let rstart = slot * self.capacity * self.n_heads;
        let rlen = ctx * self.n_heads;
        kernels::attend_cached_q(
            q,
            QuantView {
                data: &self.k_codes[layer],
                bits: kb,
                start,
                r: &self.k_r[layer][rstart..rstart + rlen],
            },
            QuantView {
                data: &self.v_codes[layer],
                bits: vb,
                start,
                r: &self.v_r[layer][rstart..rstart + rlen],
            },
            ctx,
            self.n_heads,
            self.head_dim,
            &self.rot,
            scratch,
            out,
        );
        let dur = crate::obs::trace::tracer().now_us().saturating_sub(t0);
        crate::obs::metrics().kvq_attend_us.observe_us(dur);
        crate::obs::trace::record_ambient("kvq_attend", t0, dur, layer as i64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::attend_cached;

    #[test]
    fn plan_uniform_and_accounting() {
        let p = KvqPlan::uniform(3, 4).unwrap();
        assert_eq!(p.bits, vec![(4, 4); 3]);
        assert_eq!(p.avg_bits(), 4.0);
        // capacity 16, d 32, heads 2: per layer 2*ceil(16*32*4/8) + 2*16*2*4
        let per_layer = 2 * (16 * 32 * 4usize).div_ceil(8) + 2 * 16 * 2 * 4;
        assert_eq!(p.bytes_per_lane(16, 32, 2), 3 * per_layer);
        assert_eq!(KvqPlan::uniform(2, 0).unwrap_err(), KvqError::BadBits(0));
        assert_eq!(KvqPlan::uniform(2, 9).unwrap_err(), KvqError::BadBits(9));
        // f32 baseline the ratio is measured against
        assert_eq!(dense_bytes_per_lane(3, 16, 32), 3 * 2 * 16 * 32 * 4);
    }

    #[test]
    fn plan_quantized_beats_dense_per_lane() {
        // the whole point: >= 2x lanes per byte at 4-bit vs f32
        let (layers, cap, d, heads) = (4usize, 128usize, 256usize, 4usize);
        let dense = dense_bytes_per_lane(layers, cap, d);
        let q4 = KvqPlan::uniform(layers, 4).unwrap().bytes_per_lane(cap, d, heads);
        assert!(dense >= 2 * q4, "4-bit lane {q4} must be <= half of dense {dense}");
    }

    #[test]
    fn solve_for_budget_respects_budget_and_sensitivity() {
        let (layers, cap, d, heads) = (4usize, 16usize, 64usize, 4usize);
        // strongly K-sensitive layer 0, strongly V-sensitive layer 3
        let sens = KvSensitivity {
            alpha_k: vec![50.0, 1.0, 1.0, 1.0],
            alpha_v: vec![1.0, 1.0, 1.0, 50.0],
        };
        let budget = KvqPlan::uniform(layers, 4).unwrap().bytes_per_lane(cap, d, heads);
        let plan = KvqPlan::solve_for_budget(
            layers, cap, d, heads, budget, &[2, 4, 8], Some(&sens),
        )
        .unwrap();
        assert_eq!(plan.bits.len(), layers);
        assert!(plan.bytes_per_lane(cap, d, heads) <= budget);
        // sensitive streams get more bits than their quiet counterparts
        assert!(plan.bits[0].0 > plan.bits[1].0, "{:?}", plan.bits);
        assert!(plan.bits[3].1 > plan.bits[2].1, "{:?}", plan.bits);
    }

    #[test]
    fn solve_for_budget_default_alphas_favor_k() {
        let (layers, cap, d, heads) = (2usize, 16usize, 64usize, 2usize);
        let budget = KvqPlan::uniform(layers, 4).unwrap().bytes_per_lane(cap, d, heads);
        let plan =
            KvqPlan::solve_for_budget(layers, cap, d, heads, budget, &[2, 4, 8], None).unwrap();
        for &(kb, vb) in &plan.bits {
            assert!(kb >= vb, "K must not get fewer bits than V by default: {:?}", plan.bits);
        }
    }

    #[test]
    fn budget_too_small_is_typed() {
        let err = KvqPlan::solve_for_budget(2, 16, 64, 2, 64, &[2, 4, 8], None).unwrap_err();
        match err {
            KvqError::BudgetTooSmall { budget_bytes, min_lane_bytes } => {
                assert_eq!(budget_bytes, 64);
                assert!(min_lane_bytes > 64);
            }
            other => panic!("expected BudgetTooSmall, got {other:?}"),
        }
        assert_eq!(
            KvqPlan::solve_for_budget(2, 16, 64, 2, 1 << 20, &[9], None).unwrap_err(),
            KvqError::BadBits(9)
        );
    }

    #[test]
    fn store_rejects_bad_shapes() {
        let plan = KvqPlan::uniform(2, 4).unwrap();
        assert!(matches!(
            QuantizedKvStore::new(3, 1, 4, 8, 2, plan.clone(), 1),
            Err(KvqError::Shape(_))
        ));
        assert!(matches!(
            QuantizedKvStore::new(2, 1, 4, 9, 2, plan.clone(), 1),
            Err(KvqError::Shape(_))
        ));
        assert!(QuantizedKvStore::new(2, 1, 4, 8, 2, plan, 1).is_ok());
    }

    #[test]
    fn store_attend_tracks_dense_attention() {
        // 8-bit quantized attend over stored rows stays near the dense
        // kernel's answer; the drift shrinks monotonically with bits
        let (layers, slots, cap, d, heads) = (2usize, 2usize, 8usize, 32usize, 2usize);
        let mut rng = Rng::new(42);
        let ctx = 6usize;
        let q: Vec<f32> = rng.gaussian_vec(d);
        let krows: Vec<f32> = rng.gaussian_vec(ctx * d);
        let vrows: Vec<f32> = rng.gaussian_vec(ctx * d);
        let mut scores = vec![0f32; ctx];
        let mut exact = vec![0f32; d];
        attend_cached(&q, &krows, &vrows, ctx, heads, d / heads, &mut scores, &mut exact);
        let norm: f64 = exact.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();

        let mut prev = f64::INFINITY;
        for bits in [2u8, 4, 8] {
            let plan = KvqPlan::uniform(layers, bits).unwrap();
            let mut store =
                QuantizedKvStore::new(layers, slots, cap, d, heads, plan, DEFAULT_ROT_SEED)
                    .unwrap();
            for pos in 0..ctx {
                store.store_row(1, 1, pos, &krows[pos * d..(pos + 1) * d],
                                &vrows[pos * d..(pos + 1) * d]);
            }
            let mut scratch = store.scratch();
            let mut out = vec![0f32; d];
            store.attend(1, 1, ctx, &q, &mut scratch, &mut out);
            let err: f64 = out
                .iter()
                .zip(&exact)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
                / norm;
            assert!(err < prev, "bits={bits}: {err} !< {prev}");
            prev = err;
        }
        assert!(prev < 0.06, "8-bit drift too large: {prev}");
    }

    #[test]
    fn slot_recycling_overwrites_codes_exactly() {
        // store row A, overwrite with row B, overwrite with A again: the
        // attend output must be bit-identical to the first A store (the
        // packer must clear recycled bits, incl. non-byte-aligned widths)
        let (d, heads) = (24usize, 2usize);
        let mut rng = Rng::new(77);
        let a_k = rng.gaussian_vec(d);
        let a_v = rng.gaussian_vec(d);
        let b_k = rng.gaussian_vec(d);
        let b_v = rng.gaussian_vec(d);
        let q = rng.gaussian_vec(d);
        for bits in [3u8, 4, 5, 8] {
            let plan = KvqPlan::uniform(1, bits).unwrap();
            let mut store = QuantizedKvStore::new(1, 1, 4, d, heads, plan, 9).unwrap();
            let mut scratch = store.scratch();
            store.store_row(0, 0, 0, &a_k, &a_v);
            let mut first = vec![0f32; d];
            store.attend(0, 0, 1, &q, &mut scratch, &mut first);
            store.store_row(0, 0, 0, &b_k, &b_v);
            store.store_row(0, 0, 0, &a_k, &a_v);
            let mut again = vec![0f32; d];
            store.attend(0, 0, 1, &q, &mut scratch, &mut again);
            assert_eq!(first, again, "bits={bits}: recycled slot must overwrite cleanly");
        }
    }

    #[test]
    fn sensitivity_estimation_is_positive_and_k_weighted() {
        use crate::model::synthetic_manifest;
        use crate::runtime::native::native_init;
        let m = synthetic_manifest("kvq-sens", 32, 2, 2, 64, 16, 256, 1);
        let model = NativeModel::new(&m).unwrap();
        let params = native_init(&m, 3);
        let sample: Vec<i32> = (0..12).map(|i| (i * 7 % 256) as i32).collect();
        let sens = estimate_kv_sensitivity(&model, &m, &params, None, &sample, 1).unwrap();
        assert_eq!(sens.alpha_k.len(), 2);
        assert_eq!(sens.alpha_v.len(), 2);
        for l in 0..2 {
            assert!(sens.alpha_k[l].is_finite() && sens.alpha_k[l] > 0.0);
            assert!(sens.alpha_v[l].is_finite() && sens.alpha_v[l] > 0.0);
        }
    }
}
