//! Typed run configuration, loadable from JSON files / CLI overrides.

use std::path::Path;

use anyhow::{Context, Result};

use crate::json;
use crate::quant::TrickConfig;
use crate::rabitq::ScaleMode;

/// Top-level configuration for quantization runs.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Model name (artifacts/<model>/).
    pub model: String,
    /// Target average bits per quantizable parameter (incl. overheads).
    pub avg_bits: f64,
    /// Candidate bit-widths B for AllocateBits.
    pub bit_choices: Vec<u8>,
    /// Calibration: "few:<n>" or "zero".
    pub calib: String,
    pub tricks: TrickConfig,
    pub seed: u64,
    pub threads: usize,
    /// Max test sequences for perplexity (0 = all).
    pub eval_cap: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "tiny".into(),
            avg_bits: 3.1,
            bit_choices: (1..=8).collect(),
            calib: "few:5".into(),
            tricks: TrickConfig::default(),
            seed: 1234,
            threads: crate::threadpool::default_threads(),
            eval_cap: 64,
        }
    }
}

impl RunConfig {
    /// Parse a JSON config file; missing keys keep defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&text)
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = json::parse(text)?;
        let mut cfg = RunConfig::default();
        if let Some(m) = v.get("model").and_then(|x| x.as_str()) {
            cfg.model = m.to_string();
        }
        if let Some(b) = v.get("avg_bits").and_then(|x| x.as_f64()) {
            cfg.avg_bits = b;
        }
        if let Some(bits) = v.get("bit_choices").and_then(|x| x.as_arr()) {
            cfg.bit_choices = bits
                .iter()
                .filter_map(|x| x.as_f64())
                .map(|b| b as u8)
                .collect();
        }
        if let Some(c) = v.get("calib").and_then(|x| x.as_str()) {
            cfg.calib = c.to_string();
        }
        if let Some(s) = v.get("seed").and_then(|x| x.as_f64()) {
            cfg.seed = s as u64;
        }
        if let Some(t) = v.get("threads").and_then(|x| x.as_f64()) {
            cfg.threads = t as usize;
        }
        if let Some(e) = v.get("eval_cap").and_then(|x| x.as_f64()) {
            cfg.eval_cap = e as usize;
        }
        if let Some(t) = v.get("tricks") {
            if let Some(c) = t.get("centralization").and_then(|x| x.as_bool()) {
                cfg.tricks.centralization = c;
            }
            if let Some(f) = t.get("col_outlier_frac").and_then(|x| x.as_f64()) {
                cfg.tricks.col_outlier_frac = f;
            }
            if let Some(n) = t.get("scale_search").and_then(|x| x.as_f64()) {
                cfg.tricks.scale_mode = if n as usize == 0 {
                    ScaleMode::MaxAbs
                } else {
                    ScaleMode::Search(n as usize)
                };
            }
        }
        Ok(cfg)
    }

    /// Parse the calibration spec string.
    pub fn calib_mode(&self) -> Result<crate::calib::CalibMode> {
        if self.calib == "zero" {
            Ok(crate::calib::CalibMode::ZeroShot)
        } else if let Some(n) = self.calib.strip_prefix("few:") {
            Ok(crate::calib::CalibMode::FewShot(n.parse()?))
        } else {
            anyhow::bail!("calib must be 'zero' or 'few:<n>', got '{}'", self.calib)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults() {
        let c = RunConfig::default();
        assert_eq!(c.model, "tiny");
        assert_eq!(c.bit_choices, (1..=8).collect::<Vec<u8>>());
        assert!(matches!(
            c.calib_mode().unwrap(),
            crate::calib::CalibMode::FewShot(5)
        ));
    }

    #[test]
    fn from_json_overrides() {
        let c = RunConfig::from_json(
            r#"{"model":"small","avg_bits":2.3,"bit_choices":[2,3,4],
                "calib":"zero","seed":7,
                "tricks":{"centralization":false,"col_outlier_frac":0.01,
                          "scale_search":0}}"#,
        )
        .unwrap();
        assert_eq!(c.model, "small");
        assert_eq!(c.avg_bits, 2.3);
        assert_eq!(c.bit_choices, vec![2, 3, 4]);
        assert!(matches!(c.calib_mode().unwrap(), crate::calib::CalibMode::ZeroShot));
        assert!(!c.tricks.centralization);
        assert_eq!(c.tricks.scale_mode, ScaleMode::MaxAbs);
    }

    #[test]
    fn bad_calib_spec_errors() {
        let mut c = RunConfig::default();
        c.calib = "sometimes".into();
        assert!(c.calib_mode().is_err());
        c.calib = "few:x".into();
        assert!(c.calib_mode().is_err());
    }
}
