//! Deterministic PRNG substrate (the environment vendors no `rand` crate).
//!
//! `SplitMix64` seeds `Xoshiro256**`; Gaussian sampling via Box–Muller and
//! Rademacher sampling for the RHT diagonal (paper Alg. 2). All generators
//! are seedable and reproducible across runs — every experiment in
//! EXPERIMENTS.md records its seed.

/// SplitMix64 — used to expand a single u64 seed into a full state.
#[derive(Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the main generator (fast, high quality, tiny state).
#[derive(Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller sample.
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 top bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free is overkill here; modulo bias is
        // negligible for n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Rademacher sample: +1.0 or -1.0 with equal probability.
    #[inline]
    pub fn rademacher(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Vector of Rademacher +-1 samples (the RHT diagonal D).
    pub fn rademacher_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rademacher()).collect()
    }

    /// Vector of standard normal f32 samples.
    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gaussian() as f32).collect()
    }

    /// Sample from a discrete distribution given cumulative weights.
    pub fn sample_cumulative(&mut self, cum: &[f64]) -> usize {
        let total = *cum.last().expect("empty cumulative");
        let x = self.next_f64() * total;
        match cum.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
            Ok(i) => (i + 1).min(cum.len() - 1),
            Err(i) => i.min(cum.len() - 1),
        }
    }

    /// Fork a child generator (for parallel workers) — distinct stream.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(3);
        let m: f64 = (0..50_000).map(|_| r.next_f64()).sum::<f64>() / 50_000.0;
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn rademacher_balanced() {
        let mut r = Rng::new(5);
        let v = r.rademacher_vec(100_000);
        let pos = v.iter().filter(|&&x| x > 0.0).count();
        assert!(v.iter().all(|&x| x == 1.0 || x == -1.0));
        assert!((pos as f64 / 100_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_in_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn forked_streams_distinct() {
        let mut r = Rng::new(1);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn sample_cumulative_respects_weights() {
        let mut r = Rng::new(13);
        let cum = [1.0, 1.0, 2.0]; // item1 has zero mass
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.sample_cumulative(&cum)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[0] > 4_000 && counts[2] > 4_000);
    }
}
