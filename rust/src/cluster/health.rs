//! Fleet health: the per-worker failure state machine.
//!
//! ```text
//!            probe/RPC failure                ≥ down_after failures
//!  Healthy ─────────────────────▶ Suspect ──────────────────────▶ Down
//!     ▲                             │                              │
//!     └────────── success ──────────┴────────── success ───────────┘
//!
//!  any state ── healthz says "draining" ──▶ Draining ── "ok" ──▶ Healthy
//! ```
//!
//! * **Healthy** — full rotation: takes new generate traffic and
//!   scatter-gather work.
//! * **Suspect** — one or more consecutive failures, not yet condemned:
//!   out of the *generate* rotation (cheap to avoid) but still queried
//!   in scatter-gather, because its shards' rows exist nowhere else and
//!   a single dropped probe shouldn't degrade query results.
//! * **Down** — `down_after` consecutive failures: out of everything;
//!   scatter-gather over its shards reports `degraded` instead of
//!   waiting out timeouts. Probes continue — one success re-admits.
//! * **Draining** — the worker *itself* announced shutdown via
//!   `healthz` `"state":"draining"`: no new generate traffic, but
//!   in-flight work and scatter-gather still complete (that is what
//!   makes a drain lose no requests).
//!
//! Transitions are driven by both the background prober and passively by
//! RPC outcomes, so a worker that dies mid-request is condemned without
//! waiting for the next probe tick.

use std::sync::Mutex;

/// Default consecutive-failure threshold for Suspect → Down.
pub const DEFAULT_DOWN_AFTER: u32 = 2;

/// One worker's rotation state (see module docs for the machine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerState {
    /// In full rotation.
    Healthy,
    /// Failing but not yet condemned; generate avoids it, scatter keeps it.
    Suspect,
    /// Condemned: excluded everywhere until a probe succeeds.
    Down,
    /// Self-announced shutdown: finishes what it has, gets nothing new.
    Draining,
}

impl WorkerState {
    /// Wire name for `/v1/stats`.
    pub fn name(self) -> &'static str {
        match self {
            WorkerState::Healthy => "healthy",
            WorkerState::Suspect => "suspect",
            WorkerState::Down => "down",
            WorkerState::Draining => "draining",
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Slot {
    state: WorkerState,
    fails: u32,
}

/// Shared health table for a fixed worker set. All methods take `&self`;
/// the table is a single mutex because updates are a few words and the
/// readers (routing decisions) copy out.
#[derive(Debug)]
pub struct FleetHealth {
    slots: Mutex<Vec<Slot>>,
    down_after: u32,
}

impl FleetHealth {
    /// A table of `n` workers, all initially [`WorkerState::Healthy`]
    /// (optimistic: the first failed probe demotes immediately).
    pub fn new(n: usize, down_after: u32) -> FleetHealth {
        FleetHealth {
            slots: Mutex::new(vec![Slot { state: WorkerState::Healthy, fails: 0 }; n]),
            down_after: down_after.max(1),
        }
    }

    /// A successful probe or RPC: back to full rotation from any state.
    pub fn record_success(&self, w: usize) {
        crate::obs::metrics().probe_success.inc();
        let mut s = self.slots.lock().unwrap();
        if let Some(slot) = s.get_mut(w) {
            slot.state = WorkerState::Healthy;
            slot.fails = 0;
        }
    }

    /// The worker's healthz answered `"draining"`. Resets the failure
    /// count — the worker is alive, just leaving.
    pub fn record_draining(&self, w: usize) {
        let mut s = self.slots.lock().unwrap();
        if let Some(slot) = s.get_mut(w) {
            slot.state = WorkerState::Draining;
            slot.fails = 0;
        }
    }

    /// A failed probe or RPC: Healthy/Draining → Suspect, and Suspect →
    /// Down once `down_after` consecutive failures accumulate.
    pub fn record_failure(&self, w: usize) {
        crate::obs::metrics().probe_failure.inc();
        let mut s = self.slots.lock().unwrap();
        if let Some(slot) = s.get_mut(w) {
            slot.fails = slot.fails.saturating_add(1);
            slot.state =
                if slot.fails >= self.down_after { WorkerState::Down } else { WorkerState::Suspect };
        }
    }

    /// Current state of worker `w`.
    pub fn state(&self, w: usize) -> WorkerState {
        self.slots.lock().unwrap().get(w).map_or(WorkerState::Down, |s| s.state)
    }

    /// Copy of every worker's state, index-aligned with the worker list.
    pub fn snapshot(&self) -> Vec<WorkerState> {
        self.slots.lock().unwrap().iter().map(|s| s.state).collect()
    }

    /// Workers eligible for **new** generate traffic (Healthy only).
    pub fn generate_targets(&self) -> Vec<usize> {
        self.snapshot()
            .into_iter()
            .enumerate()
            .filter(|&(_, st)| st == WorkerState::Healthy)
            .map(|(i, _)| i)
            .collect()
    }

    /// Whether worker `w` should be asked at all in scatter-gather
    /// (everything but Down — see module docs).
    pub fn scatter_eligible(&self, w: usize) -> bool {
        self.state(w) != WorkerState::Down
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_ladder_and_readmission() {
        let h = FleetHealth::new(2, 2);
        assert_eq!(h.state(0), WorkerState::Healthy);
        h.record_failure(0);
        assert_eq!(h.state(0), WorkerState::Suspect);
        assert!(h.scatter_eligible(0), "one failure must not drop shards from queries");
        assert_eq!(h.generate_targets(), vec![1], "suspect leaves the generate rotation");
        h.record_failure(0);
        assert_eq!(h.state(0), WorkerState::Down);
        assert!(!h.scatter_eligible(0));
        h.record_success(0);
        assert_eq!(h.state(0), WorkerState::Healthy, "one success re-admits");
        assert_eq!(h.generate_targets(), vec![0, 1]);
    }

    #[test]
    fn draining_blocks_generate_keeps_scatter() {
        let h = FleetHealth::new(2, 2);
        h.record_draining(1);
        assert_eq!(h.state(1), WorkerState::Draining);
        assert_eq!(h.generate_targets(), vec![0]);
        assert!(h.scatter_eligible(1), "draining workers still answer queries");
        // drain cancelled (process kept running): next ok probe restores
        h.record_success(1);
        assert_eq!(h.state(1), WorkerState::Healthy);
    }

    #[test]
    fn out_of_range_is_down() {
        let h = FleetHealth::new(1, 2);
        assert_eq!(h.state(7), WorkerState::Down);
        h.record_failure(7); // no-op, must not panic
    }
}
