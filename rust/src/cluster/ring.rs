//! Consistent-hash ring: collections → workers.
//!
//! Each worker contributes [`VNODES`] virtual points hashed from its
//! *address* (not its list position), so placement survives reordering
//! of the `--workers` flag and, in the classic consistent-hashing way,
//! adding a worker only moves ~`1/n` of collections. A collection's
//! shard set is found by hashing its name onto the ring and walking
//! clockwise, collecting **distinct** workers — shard `s` of the
//! collection is the `s`-th distinct worker encountered, so shard order
//! (and therefore the round-robin row partition in
//! [`super::merge`]) is itself deterministic.
//!
//! Hash is FNV-1a 64 — the same primitive `index/` uses to derive
//! per-collection rotation streams; no cryptographic strength needed,
//! just stable dispersion that two router processes reproduce.

/// Virtual points per worker. 32 keeps the max/min load ratio across
/// workers small at single-digit worker counts without making ring
/// construction or lookup measurable.
pub const VNODES: usize = 32;

/// FNV-1a 64-bit over `bytes`.
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// An immutable consistent-hash ring over a fixed worker set. Workers
/// are addressed by their index into the list the ring was built from.
#[derive(Debug, Clone)]
pub struct Ring {
    /// (point, worker index), sorted by point.
    points: Vec<(u64, usize)>,
    workers: usize,
}

impl Ring {
    /// Build the ring from worker addresses (one vnode set per worker).
    pub fn new(worker_addrs: &[String]) -> Ring {
        let mut points = Vec::with_capacity(worker_addrs.len() * VNODES);
        for (w, addr) in worker_addrs.iter().enumerate() {
            for v in 0..VNODES {
                points.push((fnv1a(format!("{addr}#{v}").as_bytes()), w));
            }
        }
        // ties (hash collisions across addresses) break by worker index
        // so the ring is a pure function of the address list
        points.sort();
        Ring { points, workers: worker_addrs.len() }
    }

    /// Worker count the ring was built over.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The `n_shards` distinct workers owning collection `name`, in
    /// shard order (shard 0 first). `n_shards` is clamped to the worker
    /// count; an empty ring yields an empty set.
    pub fn shards_for(&self, name: &str, n_shards: usize) -> Vec<usize> {
        let want = n_shards.clamp(1, self.workers.max(1));
        if self.points.is_empty() {
            return Vec::new();
        }
        let h = fnv1a(name.as_bytes());
        let start = self.points.partition_point(|&(p, _)| p < h);
        let mut out = Vec::with_capacity(want);
        for i in 0..self.points.len() {
            let (_, w) = self.points[(start + i) % self.points.len()];
            if !out.contains(&w) {
                out.push(w);
                if out.len() == want {
                    break;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("127.0.0.1:{}", 9000 + i)).collect()
    }

    #[test]
    fn placement_is_deterministic_and_distinct() {
        let ring = Ring::new(&addrs(4));
        for name in ["a", "docs", "embeddings", "zz-top"] {
            let s1 = ring.shards_for(name, 3);
            let s2 = Ring::new(&addrs(4)).shards_for(name, 3);
            assert_eq!(s1, s2, "same inputs must place identically");
            assert_eq!(s1.len(), 3);
            let mut uniq = s1.clone();
            uniq.sort();
            uniq.dedup();
            assert_eq!(uniq.len(), 3, "shards must land on distinct workers");
        }
    }

    #[test]
    fn shard_count_clamps_to_workers() {
        let ring = Ring::new(&addrs(2));
        assert_eq!(ring.shards_for("c", 5).len(), 2);
        assert_eq!(ring.shards_for("c", 0).len(), 1);
        assert!(Ring::new(&[]).shards_for("c", 3).is_empty());
    }

    #[test]
    fn collections_spread_across_workers() {
        // with vnodes, 64 collections over 4 workers should touch every
        // worker as a primary at least once
        let ring = Ring::new(&addrs(4));
        let mut primaries = [0usize; 4];
        for i in 0..64 {
            primaries[ring.shards_for(&format!("c{i}"), 1)[0]] += 1;
        }
        assert!(primaries.iter().all(|&c| c > 0), "primary spread: {primaries:?}");
    }

    #[test]
    fn adding_a_worker_moves_little() {
        let before = Ring::new(&addrs(4));
        let after = Ring::new(&addrs(5));
        let moved = (0..200)
            .filter(|i| {
                let n = format!("c{i}");
                before.shards_for(&n, 1) != after.shards_for(&n, 1)
            })
            .count();
        // expectation is 1/5 = 40 of 200; allow generous slack, the point
        // is "far from rehash-everything"
        assert!(moved < 100, "moved {moved}/200 primaries on +1 worker");
    }
}
