//! The router process: one thin HTTP front-end over N workers.
//!
//! The router owns no model and no rows — it owns *placement* (the
//! [`Ring`]), *health* (the [`FleetHealth`] table, fed by a background
//! prober and passively by every RPC outcome), and the *merge* (the pure
//! functions in [`super::merge`]). Every worker is a complete single-node
//! deployment (`raana serve`), reached over the same HTTP/1.1 + JSON
//! surface clients use — the cluster RPC *is* the public API, so there is
//! no second protocol to harden.
//!
//! Request handling:
//!
//! * `POST /v1/generate` — round-robin over Healthy workers, raw byte
//!   relay. Retries the next worker **only when the chosen worker
//!   produced zero response bytes** (connect failure, or death before
//!   the first byte): once a byte has been relayed the request may have
//!   side effects, so re-sending could duplicate work — a mid-stream
//!   death closes the connection instead. All candidates dead ⇒ **503 +
//!   `Retry-After`**.
//! * `POST /v1/collections/{name}/add` — splits the batch round-robin by
//!   global row id across the collection's shards and appends each slice
//!   with `expect_first_id`, making retries idempotent (a **409** on a
//!   retry proves the earlier attempt landed — it is counted as
//!   success). A batch that lands on only some shards is kept as
//!   *pending*: the client sees **503 + `Retry-After`**, queries mask the
//!   partial rows (see below), and the next add/retry first completes the
//!   pending slices before accepting new rows — no silent partial state.
//! * `POST /v1/collections/{name}/query` — two-phase scatter-gather:
//!   `scan` every live shard for estimated candidates (`take` computed
//!   from the **global** row count, bumped per shard by any
//!   pending-but-applied rows), select the global candidate set, `rerank`
//!   the winners on their owning shards, merge exact scores. Bit-identical
//!   to a single node holding the same rows (see [`super::merge`]).
//!   Unreachable shards degrade explicitly: `"degraded": true` +
//!   `"failed_shards"`, never a hang or a silent subset; all shards
//!   unreachable ⇒ **503 + `Retry-After`**.
//! * `GET /v1/stats` — fleet view: per-worker state and queue depth,
//!   summed counters, and percentiles computed **once** over the
//!   concatenated per-worker latency windows (averaging per-worker p95s
//!   would be mathematically wrong).
//! * `GET /healthz`, `GET /v1/collections` — router-local, no RPC.
//!
//! Every RPC uses [`ClientConfig`] connect/read deadlines, so a wedged
//! worker costs a bounded timeout, never a hung router thread.

use std::collections::{BTreeMap, BTreeSet};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::index::{SearchHit, DEFAULT_RERANK_FACTOR};
use crate::json::{self, Value};
use crate::net::{
    header, hits_json, http_request_retry_with, http_request_with, parse_f32_array, read_request,
    respond, respond_error, respond_method_not_allowed, respond_text, ClientConfig,
};
use crate::obs::{self, trace};
use crate::threadpool::{default_threads, Pool};
use crate::util;

use super::health::{FleetHealth, WorkerState, DEFAULT_DOWN_AFTER};
use super::merge;
use super::ring::Ring;

/// Default per-RPC connect/read deadline (see [`RouterConfig::client`]).
pub const DEFAULT_RPC_TIMEOUT_MS: u64 = 2000;

/// Default health-probe cadence.
pub const DEFAULT_PROBE_INTERVAL_MS: u64 = 250;

/// Most detached overflow responders alive at once (mirrors the worker
/// front-end's bound).
const OVERFLOW_MAX: usize = 32;

/// Socket write timeout towards clients and workers.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Read timeout towards a worker while relaying a generation. Much
/// longer than [`RouterConfig::client`]'s RPC deadline: a long prefill
/// legitimately produces no bytes for a while, and a worker that *dies*
/// is detected by the failed read, not the timeout. This bound only
/// catches a truly wedged worker.
const GENERATE_READ_TIMEOUT: Duration = Duration::from_secs(120);

/// Router construction options (see [`Router::bind`]).
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Worker addresses (`host:port`), index-aligned with every
    /// per-worker table. Must be non-empty.
    pub workers: Vec<String>,
    /// Shards per collection; `0` (default) and anything larger clamp to
    /// the worker count. `1` places each collection wholly on one worker.
    pub shards: usize,
    /// Connection-handler pool size for the router's own listener
    /// (`0` = [`default_threads`], min 4).
    pub http_workers: usize,
    /// Health-probe cadence in milliseconds (`0` =
    /// [`DEFAULT_PROBE_INTERVAL_MS`]).
    pub probe_interval_ms: u64,
    /// Consecutive failures before a worker is condemned Down
    /// (see [`FleetHealth`]).
    pub down_after: u32,
    /// Connect/read deadlines for every worker RPC and probe.
    pub client: ClientConfig,
    /// Read timeout for the router's *own* clients in milliseconds
    /// (`0` = 10 s), the same slow-loris guard the worker front-end has.
    pub read_timeout_ms: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            workers: Vec::new(),
            shards: 0,
            http_workers: 0,
            probe_interval_ms: 0,
            down_after: DEFAULT_DOWN_AFTER,
            client: ClientConfig::timeout_ms(DEFAULT_RPC_TIMEOUT_MS),
            read_timeout_ms: 0,
        }
    }
}

/// A batch accepted from a client but not yet acked by every shard.
#[derive(Debug)]
struct PendingAdd {
    /// Global id of the batch's first row.
    first_gid: usize,
    /// Rows in the batch.
    count: usize,
    /// Per-shard flat row slices (shard-local append order).
    slices: Vec<Vec<f32>>,
    /// Which shards have acked their slice (200 or 409-on-retry).
    applied: Vec<bool>,
}

/// Routing entry for one collection.
#[derive(Debug)]
struct CollectionRoute {
    /// Worker index per shard; `shards[s]` owns every global row with
    /// `gid % shards.len() == s`.
    shards: Vec<usize>,
    dim: usize,
    /// Rows acked by **all** shards — the only rows queries may surface.
    rows: usize,
    pending: Option<PendingAdd>,
}

struct RouterState {
    cfg: RouterConfig,
    ring: Ring,
    health: FleetHealth,
    routes: Mutex<BTreeMap<String, CollectionRoute>>,
    rr: AtomicUsize,
}

impl RouterState {
    fn n_shards(&self) -> usize {
        let w = self.cfg.workers.len();
        if self.cfg.shards == 0 { w } else { self.cfg.shards.min(w) }
    }

    fn addr(&self, w: usize) -> &str {
        &self.cfg.workers[w]
    }
}

/// Handle for a running router front-end (modeled on
/// [`crate::net::HttpServer`]): bind, serve, graceful [`Router::shutdown`]).
pub struct Router {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
    prober: Option<thread::JoinHandle<()>>,
    overflow: Arc<AtomicUsize>,
}

impl Router {
    /// Bind `addr` (port `0` for ephemeral) and start routing over
    /// `cfg.workers`.
    pub fn bind(addr: &str, cfg: RouterConfig) -> Result<Router> {
        if cfg.workers.is_empty() {
            bail!("router needs at least one worker address");
        }
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding router listener on {addr}"))?;
        let local = listener.local_addr().context("resolving bound address")?;
        listener.set_nonblocking(true).context("setting listener non-blocking")?;

        let state = Arc::new(RouterState {
            ring: Ring::new(&cfg.workers),
            health: FleetHealth::new(cfg.workers.len(), cfg.down_after),
            routes: Mutex::new(BTreeMap::new()),
            rr: AtomicUsize::new(0),
            cfg,
        });
        let stop = Arc::new(AtomicBool::new(false));
        let overflow = Arc::new(AtomicUsize::new(0));

        // Background prober: drives Healthy/Suspect/Down/Draining from
        // each worker's /healthz. Polls the stop flag in small steps so
        // shutdown never waits out a full probe interval.
        let prober = {
            let state = Arc::clone(&state);
            let stop = Arc::clone(&stop);
            thread::spawn(move || {
                let interval = match state.cfg.probe_interval_ms {
                    0 => DEFAULT_PROBE_INTERVAL_MS,
                    ms => ms,
                };
                while !stop.load(Ordering::SeqCst) {
                    for w in 0..state.cfg.workers.len() {
                        probe_worker(&state, w);
                    }
                    let mut slept = 0u64;
                    while slept < interval && !stop.load(Ordering::SeqCst) {
                        let step = (interval - slept).min(20);
                        thread::sleep(Duration::from_millis(step));
                        slept += step;
                    }
                }
            })
        };

        let accept = {
            let state = Arc::clone(&state);
            let stop = Arc::clone(&stop);
            let overflow = Arc::clone(&overflow);
            thread::spawn(move || {
                let workers =
                    if state.cfg.http_workers == 0 { default_threads().max(4) } else { state.cfg.http_workers };
                let pool = Pool::new(workers);
                let active = Arc::new(AtomicUsize::new(0));
                loop {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok((conn, _peer)) => {
                            if active.load(Ordering::SeqCst) < workers {
                                active.fetch_add(1, Ordering::SeqCst);
                                let st = Arc::clone(&state);
                                let act = Arc::clone(&active);
                                pool.submit(move || {
                                    handle_router_connection(&st, conn, false);
                                    act.fetch_sub(1, Ordering::SeqCst);
                                });
                            } else if overflow.load(Ordering::SeqCst) < OVERFLOW_MAX {
                                // bounded detached responders keep healthz
                                // live and refuse the rest with a real 503
                                overflow.fetch_add(1, Ordering::SeqCst);
                                let st = Arc::clone(&state);
                                let ovf = Arc::clone(&overflow);
                                thread::spawn(move || {
                                    handle_router_connection(&st, conn, true);
                                    drop(st);
                                    ovf.fetch_sub(1, Ordering::SeqCst);
                                });
                            } else {
                                drop(conn);
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => thread::sleep(Duration::from_millis(5)),
                    }
                }
                drop(pool); // joins workers: the graceful drain
            })
        };

        Ok(Router { addr: local, stop, accept: Some(accept), prober: Some(prober), overflow })
    }

    /// The actually-bound address.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful drain: stop accepting, finish in-flight connections,
    /// stop the prober, return.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        let mut out = Ok(());
        if let Some(h) = self.accept.take() {
            if h.join().is_err() {
                out = Err(anyhow!("router accept loop panicked"));
            }
        }
        if let Some(h) = self.prober.take() {
            if h.join().is_err() {
                out = Err(anyhow!("router prober panicked"));
            }
        }
        self.drain_overflow();
        out
    }

    fn drain_overflow(&self) {
        for _ in 0..6000 {
            if self.overflow.load(Ordering::SeqCst) == 0 {
                return;
            }
            thread::sleep(Duration::from_millis(10));
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.prober.take() {
            let _ = h.join();
        }
        self.drain_overflow();
    }
}

// ------------------------------------------------------------------ probing

fn probe_worker(state: &RouterState, w: usize) {
    match http_request_with(state.addr(w), "GET", "/healthz", None, state.cfg.client) {
        Ok(r) if r.status == 200 => {
            let draining = r
                .json()
                .ok()
                .and_then(|v| v.get("state").and_then(|s| s.as_str().map(str::to_string)))
                .is_some_and(|s| s == "draining");
            if draining {
                state.health.record_draining(w);
            } else {
                state.health.record_success(w);
            }
        }
        _ => state.health.record_failure(w),
    }
}

// --------------------------------------------------------------- dispatch

fn handle_router_connection(state: &RouterState, mut stream: TcpStream, overflow: bool) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    let read_timeout = match state.cfg.read_timeout_ms {
        0 => Duration::from_secs(10),
        ms => Duration::from_millis(ms),
    };
    let _ = stream.set_read_timeout(Some(read_timeout));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let req = match read_request(&stream) {
        Ok(r) => r,
        Err(e) => {
            // unparseable request: echo the inbound id when the head
            // parsed (read_request installed it), else mint one so the
            // error echo is still correlatable — same rule as the worker
            if trace::current_rid().is_none() {
                trace::set_current_rid(Some(trace::mint_rid()));
            }
            let _ = respond_error(&mut stream, e.status, &e.msg);
            trace::set_current_rid(None);
            return;
        }
    };
    // one id per client request, installed for the whole dispatch: the
    // in-crate HTTP client forwards it on every router→worker RPC below,
    // and every response writer echoes it back to the client
    trace::set_current_rid(Some(trace::admit_rid(header(&req.headers, "x-request-id"))));
    obs::metrics().http_requests.inc();
    let method = req.method.as_str();
    match req.path.as_str() {
        "/healthz" => match method {
            "GET" => handle_router_healthz(state, &mut stream),
            _ => {
                let _ = respond_method_not_allowed(&mut stream, method, "GET");
            }
        },
        // fleet scrape: aggregation is a bounded scatter (deadlined
        // RPCs), so like /healthz it stays live under overflow
        "/metrics" => match method {
            "GET" => handle_fleet_metrics(state, &mut stream),
            _ => {
                let _ = respond_method_not_allowed(&mut stream, method, "GET");
            }
        },
        "/v1/stats" => match method {
            "GET" if overflow => {
                let _ =
                    respond_error(&mut stream, 503, "all router workers busy, retry later");
            }
            "GET" => handle_fleet_stats(state, &mut stream),
            _ => {
                let _ = respond_method_not_allowed(&mut stream, method, "GET");
            }
        },
        "/v1/generate" => match method {
            "POST" if overflow => {
                let _ =
                    respond_error(&mut stream, 503, "all router workers busy, retry later");
            }
            "POST" => handle_cluster_generate(state, &mut stream, &req.body),
            _ => {
                let _ = respond_method_not_allowed(&mut stream, method, "POST");
            }
        },
        "/v1/collections" => match method {
            "GET" => handle_cluster_collections(state, &mut stream),
            _ => {
                let _ = respond_method_not_allowed(&mut stream, method, "GET");
            }
        },
        p if p.starts_with("/v1/collections/") => {
            let rest = &p["/v1/collections/".len()..];
            match (rest.split_once('/'), method) {
                (Some((_, "add" | "query")), "POST") if overflow => {
                    let _ = respond_error(
                        &mut stream,
                        503,
                        "all router workers busy, retry later",
                    );
                }
                (Some((name, "add")), "POST") => {
                    handle_cluster_add(state, name, &mut stream, &req.body)
                }
                (Some((name, "query")), "POST") => {
                    handle_cluster_query(state, name, &mut stream, &req.body)
                }
                (Some((_, "add" | "query")), m) => {
                    let _ = respond_method_not_allowed(&mut stream, m, "POST");
                }
                _ => {
                    let _ = respond_error(&mut stream, 404, &format!("no endpoint {p}"));
                }
            }
        }
        p => {
            let _ = respond_error(&mut stream, 404, &format!("no endpoint {p}"));
        }
    }
    trace::set_current_rid(None);
}

fn handle_router_healthz(state: &RouterState, stream: &mut TcpStream) {
    let states = state.health.snapshot();
    let healthy = states.iter().filter(|&&s| s == WorkerState::Healthy).count();
    let body = json::obj(vec![
        ("ok", Value::Bool(true)),
        ("role", json::s("router")),
        ("workers", json::num(states.len() as f64)),
        ("workers_healthy", json::num(healthy as f64)),
    ]);
    let _ = respond(stream, 200, "OK", &body.to_json());
}

// ---------------------------------------------------------------- generate

enum RelayOutcome {
    /// Full (or mid-stream-truncated) response relayed; connection done.
    Done,
    /// Worker produced zero response bytes — safe to try another worker.
    PreResponse,
}

fn handle_cluster_generate(state: &RouterState, stream: &mut TcpStream, body: &[u8]) {
    let targets = state.health.generate_targets();
    if targets.is_empty() {
        let _ = respond_error(stream, 503, "no healthy workers in rotation");
        return;
    }
    let start = state.rr.fetch_add(1, Ordering::SeqCst);
    for i in 0..targets.len() {
        let w = targets[(start + i) % targets.len()];
        let t0 = trace::tracer().now_us();
        let outcome = relay_generate(state, w, stream, body);
        let dur = trace::tracer().now_us().saturating_sub(t0);
        obs::metrics().router_hop_us.observe_us(dur);
        trace::record_ambient("router_hop", t0, dur, w as i64);
        match outcome {
            RelayOutcome::Done => {
                state.health.record_success(w);
                return;
            }
            RelayOutcome::PreResponse => {
                // zero bytes reached the client, so the loop retries the
                // next worker with the same request (and the same id)
                obs::metrics().relay_retries.inc();
                state.health.record_failure(w);
            }
        }
    }
    let _ = respond_error(
        stream,
        503,
        "every healthy worker failed before responding, retry later",
    );
}

/// Raw byte relay: forward the request, then copy response bytes through
/// verbatim (status line, headers, chunked framing and all — both sides
/// speak `Connection: close`, so EOF is the terminator). Returns
/// [`RelayOutcome::PreResponse`] only while nothing has been written to
/// the client, which is the retry-safety invariant.
fn relay_generate(state: &RouterState, w: usize, client: &mut TcpStream, body: &[u8]) -> RelayOutcome {
    let addr = state.addr(w);
    let upstream = match state.cfg.client.connect_timeout {
        Some(t) => addr
            .to_socket_addrs()
            .ok()
            .and_then(|mut it| it.next())
            .and_then(|sa| TcpStream::connect_timeout(&sa, t).ok()),
        None => TcpStream::connect(addr).ok(),
    };
    let Some(mut upstream) = upstream else {
        return RelayOutcome::PreResponse;
    };
    let _ = upstream.set_nodelay(true);
    let _ = upstream.set_read_timeout(Some(GENERATE_READ_TIMEOUT));
    let _ = upstream.set_write_timeout(Some(WRITE_TIMEOUT));
    // forward the client's request id so the worker's spans and response
    // carry it (the relay copies bytes verbatim, so the worker's echoed
    // X-Request-Id header is what the client ultimately sees)
    let rid_line = match trace::current_rid() {
        Some(rid) => format!("X-Request-Id: {rid}\r\n"),
        None => String::new(),
    };
    let head = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\
         {rid_line}Connection: close\r\n\r\n",
        body.len()
    );
    if upstream.write_all(head.as_bytes()).and_then(|()| upstream.write_all(body)).is_err() {
        return RelayOutcome::PreResponse;
    }
    let _ = upstream.flush();
    let mut buf = [0u8; 16 * 1024];
    let mut sent_any = false;
    loop {
        match upstream.read(&mut buf) {
            Ok(0) => {
                if !sent_any {
                    return RelayOutcome::PreResponse; // died before first byte
                }
                let _ = client.flush();
                return RelayOutcome::Done;
            }
            Ok(n) => {
                if client.write_all(&buf[..n]).is_err() {
                    return RelayOutcome::Done; // client gone; nothing to retry
                }
                // flush per read so streamed tokens reach the client live
                let _ = client.flush();
                sent_any = true;
            }
            Err(_) => {
                if !sent_any {
                    return RelayOutcome::PreResponse;
                }
                return RelayOutcome::Done; // mid-stream death: close, never resend
            }
        }
    }
}

// --------------------------------------------------------------------- add

/// Parse `{"vectors": [[f32...], ...]}` into a flat row-major batch.
fn parse_vectors_body(body: &[u8]) -> Result<(Vec<f32>, usize)> {
    let text = std::str::from_utf8(body).map_err(|_| anyhow!("body is not UTF-8"))?;
    let v = json::parse(text).map_err(|e| anyhow!("invalid JSON body: {e}"))?;
    if v.get("texts").is_some() || v.get("tokens").is_some() {
        bail!("the cluster router accepts 'vectors' only — embed client-side or at a worker");
    }
    let rows = v
        .get("vectors")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow!("body must carry 'vectors': [[f32...], ...]"))?;
    if rows.is_empty() {
        bail!("'vectors' must be non-empty");
    }
    let mut flat = Vec::new();
    let mut d = 0usize;
    for (i, row) in rows.iter().enumerate() {
        let r = parse_f32_array(row, "vectors[..]")?;
        if i == 0 {
            d = r.len();
            if d == 0 {
                bail!("'vectors' rows must be non-empty");
            }
        } else if r.len() != d {
            bail!("'vectors' rows must share one dimension (row 0 has {d}, row {i} has {})", r.len());
        }
        flat.extend_from_slice(&r);
    }
    Ok((flat, d))
}

fn rows_json(slice: &[f32], d: usize) -> Value {
    json::arr(
        slice
            .chunks_exact(d)
            .map(|row| json::arr(row.iter().map(|&x| json::num(x as f64)).collect()))
            .collect(),
    )
}

enum PendingOutcome {
    /// Every shard acked; `route.rows` has advanced.
    Done,
    /// Some shard still unreachable; pending kept, client should retry.
    Incomplete,
    /// A shard refused permanently (4xx/507) before anything was applied
    /// anywhere; pending dropped, relay the refusal.
    Refused(u16, String),
}

/// Push a route's pending batch to every shard that has not acked it,
/// with `expect_first_id` making the push idempotent (409 ⇒ an earlier
/// attempt already landed ⇒ success).
fn complete_pending(state: &RouterState, name: &str, route: &mut CollectionRoute) -> PendingOutcome {
    let n_shards = route.shards.len();
    let dim = route.dim;
    let mut refusal: Option<(u16, String)> = None;
    {
        let Some(p) = route.pending.as_mut() else {
            return PendingOutcome::Done;
        };
        for s in 0..n_shards {
            if p.applied[s] {
                continue;
            }
            if p.slices[s].is_empty() {
                p.applied[s] = true;
                continue;
            }
            let w = route.shards[s];
            let expect = merge::shard_rows(s, n_shards, p.first_gid);
            let body = json::obj(vec![
                ("vectors", rows_json(&p.slices[s], dim)),
                ("expect_first_id", json::num(expect as f64)),
            ])
            .to_json();
            let path = format!("/v1/collections/{name}/add");
            match http_request_retry_with(
                state.addr(w),
                "POST",
                &path,
                Some(&body),
                2,
                state.cfg.client,
            ) {
                // 409 = the slice is already there (an earlier attempt or
                // a transport-level retry landed): exactly-once achieved
                Ok(r) if r.status == 200 || r.status == 409 => {
                    p.applied[s] = true;
                    state.health.record_success(w);
                }
                Ok(r) if (400..500).contains(&r.status) || r.status == 507 => {
                    // permanent refusal (bad dim, byte budget, ...): if no
                    // shard holds any of the batch yet, drop it and relay;
                    // otherwise keep pending so the state stays explicit
                    if !p.applied.iter().any(|&a| a) {
                        let msg = r
                            .json()
                            .ok()
                            .and_then(|v| {
                                v.get("error").and_then(|e| e.as_str().map(str::to_string))
                            })
                            .unwrap_or_else(|| {
                                format!("worker {} refused the add", state.addr(w))
                            });
                        refusal = Some((r.status, msg));
                        break;
                    }
                    state.health.record_failure(w);
                }
                _ => state.health.record_failure(w),
            }
        }
    }
    if let Some((status, msg)) = refusal {
        route.pending = None;
        return PendingOutcome::Refused(status, msg);
    }
    let done = route.pending.as_ref().is_some_and(|p| p.applied.iter().all(|&a| a));
    if done {
        let p = route.pending.take().unwrap();
        route.rows = p.first_gid + p.count;
        PendingOutcome::Done
    } else {
        PendingOutcome::Incomplete
    }
}

fn handle_cluster_add(state: &RouterState, name: &str, stream: &mut TcpStream, body: &[u8]) {
    let (flat, d) = match parse_vectors_body(body) {
        Ok(x) => x,
        Err(e) => {
            let _ = respond_error(stream, 400, &e.to_string());
            return;
        }
    };
    let mut routes = state.routes.lock().unwrap();
    let route = routes.entry(name.to_string()).or_insert_with(|| CollectionRoute {
        shards: state.ring.shards_for(name, state.n_shards()),
        dim: d,
        rows: 0,
        pending: None,
    });
    if route.dim != d {
        let _ = respond_error(
            stream,
            400,
            &format!("dimension mismatch on '{name}': collection is {}, rows are {d}", route.dim),
        );
        return;
    }
    // an earlier partially-applied batch must land before new rows may
    // take their global ids
    match complete_pending(state, name, route) {
        PendingOutcome::Done => {}
        PendingOutcome::Incomplete => {
            let _ = respond_error(
                stream,
                503,
                "a previous batch is still partially applied; retry later",
            );
            return;
        }
        PendingOutcome::Refused(status, msg) => {
            let _ = respond_error(stream, status, &msg);
            return;
        }
    }
    let first_gid = route.rows;
    let count = flat.len() / d;
    let n_shards = route.shards.len();
    route.pending = Some(PendingAdd {
        first_gid,
        count,
        slices: merge::split_rows(&flat, d, n_shards, first_gid),
        applied: vec![false; n_shards],
    });
    match complete_pending(state, name, route) {
        PendingOutcome::Done => {
            let ids = (first_gid..first_gid + count).map(|g| json::num(g as f64)).collect();
            let body = json::obj(vec![
                ("collection", json::s(name)),
                ("ids", json::arr(ids)),
                ("count", json::num(count as f64)),
            ]);
            let _ = respond(stream, 200, "OK", &body.to_json());
        }
        PendingOutcome::Incomplete => {
            let _ = respond_error(
                stream,
                503,
                "batch applied on some shards only; rows are masked until a retry completes it",
            );
        }
        PendingOutcome::Refused(status, msg) => {
            let _ = respond_error(stream, status, &msg);
        }
    }
}

// ------------------------------------------------------------------- query

struct QuerySnapshot {
    shards: Vec<usize>,
    dim: usize,
    rows: usize,
    /// Pending rows already sitting on shard `s` above the acked
    /// watermark (its scan `take` is bumped by this so masked rows can
    /// never crowd acked candidates out of the budget).
    extra: Vec<usize>,
}

fn query_snapshot(state: &RouterState, name: &str) -> Option<QuerySnapshot> {
    let routes = state.routes.lock().unwrap();
    let route = routes.get(name)?;
    let n_shards = route.shards.len();
    let mut extra = vec![0usize; n_shards];
    if let Some(p) = &route.pending {
        for s in 0..n_shards {
            if p.applied[s] {
                extra[s] = p.slices[s].len() / route.dim.max(1);
            }
        }
    }
    Some(QuerySnapshot { shards: route.shards.clone(), dim: route.dim, rows: route.rows, extra })
}

fn parse_query_body(body: &[u8]) -> Result<(Vec<f32>, usize, usize)> {
    let text = std::str::from_utf8(body).map_err(|_| anyhow!("body is not UTF-8"))?;
    let v = json::parse(text).map_err(|e| anyhow!("invalid JSON body: {e}"))?;
    let q = parse_f32_array(
        v.get("vector").ok_or_else(|| anyhow!("body must carry 'vector' (the router does not embed)"))?,
        "vector",
    )?;
    let k = match v.get("k") {
        None => 10,
        Some(x) => x
            .as_f64()
            .filter(|f| f.fract() == 0.0 && (1.0..=1e9).contains(f))
            .map(|f| f as usize)
            .ok_or_else(|| anyhow!("'k' must be an integer in 1..=1e9"))?,
    };
    let rf = match v.get("rerank_factor") {
        None => DEFAULT_RERANK_FACTOR,
        Some(x) => x
            .as_f64()
            .filter(|f| f.fract() == 0.0 && (1.0..=1e9).contains(f))
            .map(|f| f as usize)
            .ok_or_else(|| anyhow!("'rerank_factor' must be an integer in 1..=1e9"))?,
    };
    Ok((q, k, rf))
}

/// Parse a worker's `{"id", "score"}` hit list (scan `candidates` or
/// rerank `results`).
fn parse_hits(v: &Value, key: &str) -> Option<Vec<SearchHit>> {
    let arr = v.get(key)?.as_arr()?;
    let mut out = Vec::with_capacity(arr.len());
    for h in arr {
        let id = h.get("id")?.as_f64()?;
        let score = h.get("score")?.as_f64()?;
        if id.fract() != 0.0 || id < 0.0 {
            return None;
        }
        out.push(SearchHit { id: id as usize, score: score as f32 });
    }
    Some(out)
}

fn handle_cluster_query(state: &RouterState, name: &str, stream: &mut TcpStream, body: &[u8]) {
    let (q, k, rf) = match parse_query_body(body) {
        Ok(x) => x,
        Err(e) => {
            let _ = respond_error(stream, 400, &e.to_string());
            return;
        }
    };
    let Some(snap) = query_snapshot(state, name) else {
        let _ = respond_error(stream, 404, &format!("no collection '{name}' in the cluster"));
        return;
    };
    if q.len() != snap.dim {
        let _ = respond_error(
            stream,
            400,
            &format!("dimension mismatch on '{name}': collection is {}, query is {}", snap.dim, q.len()),
        );
        return;
    }
    let n_shards = snap.shards.len();
    let n = snap.rows;
    let take = merge::global_take(k, rf, n);
    let failed: Mutex<Vec<usize>> = Mutex::new(Vec::new());
    if n == 0 {
        let _ = respond(stream, 200, "OK", &query_response(name, &[], false, &[]).to_json());
        return;
    }

    // phase 1: scatter the estimated scan to every shard that holds rows
    let q_json = json::arr(q.iter().map(|&x| json::num(x as f64)).collect()).to_json();
    let gathered: Mutex<Vec<(usize, Vec<SearchHit>)>> = Mutex::new(Vec::new());
    let rid = trace::current_rid();
    thread::scope(|sc| {
        for s in 0..n_shards {
            if merge::shard_rows(s, n_shards, n) == 0 {
                continue; // no acked rows here: nothing to scan, not a failure
            }
            let w = snap.shards[s];
            if !state.health.scatter_eligible(w) {
                failed.lock().unwrap().push(s);
                continue;
            }
            // the scan budget: the global `take`, plus this shard's
            // masked pending rows so they cannot crowd out acked rows
            let scan_take = take + snap.extra[s];
            let gathered = &gathered;
            let failed = &failed;
            let q_json = &q_json;
            let rid = rid.clone();
            sc.spawn(move || {
                // thread-locals don't inherit: re-install the request id so
                // the shard RPC carries the client's X-Request-Id
                trace::set_current_rid(rid);
                let body = format!("{{\"vector\":{q_json},\"take\":{scan_take}}}");
                let path = format!("/v1/collections/{name}/scan");
                match http_request_with(state.addr(w), "POST", &path, Some(&body), state.cfg.client)
                {
                    Ok(r) if r.status == 200 => {
                        match r.json().ok().and_then(|v| parse_hits(&v, "candidates")) {
                            Some(hits) => {
                                state.health.record_success(w);
                                gathered.lock().unwrap().push((s, hits));
                            }
                            None => failed.lock().unwrap().push(s),
                        }
                    }
                    Ok(r) => {
                        if r.status >= 500 {
                            state.health.record_failure(w);
                        }
                        failed.lock().unwrap().push(s);
                    }
                    Err(_) => {
                        state.health.record_failure(w);
                        failed.lock().unwrap().push(s);
                    }
                }
            });
        }
    });
    let gathered = gathered.into_inner().unwrap();
    if gathered.is_empty() {
        let _ = respond_error(stream, 503, "no shard of the collection is reachable, retry later");
        return;
    }
    let candidates = merge::select_candidates(&gathered, n_shards, take, n);

    // phase 2: exact rerank of the selected rows on their owning shards
    let mut by_shard: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for c in &candidates {
        by_shard.entry(merge::shard_of(c.id, n_shards)).or_default().push(c.id);
    }
    let exact: Mutex<Vec<SearchHit>> = Mutex::new(Vec::new());
    thread::scope(|sc| {
        for (&s, gids) in &by_shard {
            let w = snap.shards[s];
            let exact = &exact;
            let failed = &failed;
            let q_json = &q_json;
            let rid = rid.clone();
            sc.spawn(move || {
                trace::set_current_rid(rid);
                let ids: Vec<String> =
                    gids.iter().map(|&g| merge::local_of(g, n_shards).to_string()).collect();
                let body = format!("{{\"vector\":{q_json},\"ids\":[{}]}}", ids.join(","));
                let path = format!("/v1/collections/{name}/rerank");
                match http_request_with(state.addr(w), "POST", &path, Some(&body), state.cfg.client)
                {
                    Ok(r) if r.status == 200 => {
                        match r.json().ok().and_then(|v| parse_hits(&v, "results")) {
                            Some(hits) if hits.len() == gids.len() => {
                                state.health.record_success(w);
                                let mut ex = exact.lock().unwrap();
                                // results come back in input order: zip to
                                // recover the global ids
                                for (g, h) in gids.iter().zip(hits) {
                                    ex.push(SearchHit { id: *g, score: h.score });
                                }
                            }
                            _ => failed.lock().unwrap().push(s),
                        }
                    }
                    Ok(r) => {
                        if r.status >= 500 {
                            state.health.record_failure(w);
                        }
                        failed.lock().unwrap().push(s);
                    }
                    Err(_) => {
                        state.health.record_failure(w);
                        failed.lock().unwrap().push(s);
                    }
                }
            });
        }
    });
    let exact = exact.into_inner().unwrap();
    let mut failed = failed.into_inner().unwrap();
    failed.sort_unstable();
    failed.dedup();
    if exact.is_empty() && !candidates.is_empty() {
        let _ = respond_error(stream, 503, "no shard of the collection is reachable, retry later");
        return;
    }
    let hits = merge::merge_hits(exact, k);
    let degraded = !failed.is_empty();
    let _ = respond(stream, 200, "OK", &query_response(name, &hits, degraded, &failed).to_json());
}

fn query_response(name: &str, hits: &[SearchHit], degraded: bool, failed: &[usize]) -> Value {
    json::obj(vec![
        ("collection", json::s(name)),
        ("results", hits_json(hits)),
        // explicit, always present: a silent partial result is the one
        // failure mode this response shape forbids
        ("degraded", Value::Bool(degraded)),
        ("failed_shards", json::arr(failed.iter().map(|&s| json::num(s as f64)).collect())),
    ])
}

// ----------------------------------------------------------------- metrics

/// Fleet `GET /metrics`: the router's own registry first, then each
/// reachable worker's exposition with a `worker="<i>"` label injected
/// into every sample line ([`obs::relabel_exposition`]) and repeated
/// `# HELP`/`# TYPE` lines suppressed. No values are parsed or
/// combined — relabeled histogram `_bucket` lines stay element-wise
/// summable downstream, which is the whole point of shipping buckets
/// instead of percentiles (see [`handle_fleet_stats`]).
fn handle_fleet_metrics(state: &RouterState, stream: &mut TcpStream) {
    let states = state.health.snapshot();
    let n = states.len();
    let rid = trace::current_rid();
    let per: Mutex<Vec<(usize, Option<String>)>> = Mutex::new(Vec::new());
    thread::scope(|sc| {
        for w in 0..n {
            if states[w] == WorkerState::Down {
                per.lock().unwrap().push((w, None));
                continue; // don't wait out timeouts on condemned workers
            }
            let per = &per;
            let rid = rid.clone();
            sc.spawn(move || {
                // scoped threads don't inherit the thread-local id;
                // re-install it so each scrape RPC carries the scrape's id
                trace::set_current_rid(rid);
                let got =
                    http_request_with(state.addr(w), "GET", "/metrics", None, state.cfg.client)
                        .ok()
                        .filter(|r| r.status == 200)
                        .and_then(|r| String::from_utf8(r.body).ok());
                per.lock().unwrap().push((w, got));
            });
        }
    });
    let mut per = per.into_inner().unwrap();
    per.sort_by_key(|&(w, _)| w);

    let mut out = obs::metrics().registry.render();
    // one HELP/TYPE per family across the whole concatenation, keyed
    // "(comment kind):(family name)"; the router's own render seeds the set
    let mut seen: BTreeSet<String> = out
        .lines()
        .filter_map(comment_key)
        .collect();
    for (w, text) in &per {
        let Some(text) = text else { continue };
        let labeled = obs::relabel_exposition(text, "worker", &w.to_string());
        for line in labeled.lines() {
            if let Some(key) = comment_key(line) {
                if !seen.insert(key) {
                    continue;
                }
            }
            out.push_str(line);
            out.push('\n');
        }
    }
    let _ = respond_text(stream, 200, "OK", &out);
}

/// `"HELP:name"` / `"TYPE:name"` for a `# HELP`/`# TYPE` line, `None`
/// for sample lines.
fn comment_key(line: &str) -> Option<String> {
    let rest = line.strip_prefix("# ")?;
    let mut it = rest.split_whitespace();
    let kind = it.next()?;
    let name = it.next()?;
    Some(format!("{kind}:{name}"))
}

// ------------------------------------------------------------------- stats

/// Fleet `GET /v1/stats`.
///
/// **Latency-window invariant** (mirrors `net::stats_json`): fleet
/// percentiles are computed exactly once, over the concatenation of the
/// per-worker raw windows — never by combining per-worker percentiles.
/// For dashboards that need to re-aggregate further, the response also
/// carries the *summable* forms: each worker's `latency_buckets`
/// (non-cumulative counts over the shared `latency_bucket_le_us` edges)
/// and their element-wise fleet sum `latency_bucket_counts`. Buckets
/// may be summed freely; percentiles may not.
fn handle_fleet_stats(state: &RouterState, stream: &mut TcpStream) {
    let states = state.health.snapshot();
    let n = states.len();
    let rid = trace::current_rid();
    let per: Mutex<Vec<(usize, Option<Value>)>> = Mutex::new(Vec::new());
    thread::scope(|sc| {
        for w in 0..n {
            if states[w] == WorkerState::Down {
                per.lock().unwrap().push((w, None));
                continue; // don't wait out timeouts on condemned workers
            }
            let per = &per;
            let rid = rid.clone();
            sc.spawn(move || {
                trace::set_current_rid(rid);
                let got = http_request_with(state.addr(w), "GET", "/v1/stats", None, state.cfg.client)
                    .ok()
                    .filter(|r| r.status == 200)
                    .and_then(|r| r.json().ok());
                per.lock().unwrap().push((w, got));
            });
        }
    });
    let mut per = per.into_inner().unwrap();
    per.sort_by_key(|&(w, _)| w);

    let mut completions = 0.0f64;
    let mut tokens = 0.0f64;
    let mut queue_depth = 0.0f64;
    let mut latencies: Vec<f64> = Vec::new();
    let mut fleet_buckets = vec![0u64; obs::LATENCY_BUCKETS_US.len() + 1];
    let mut per_worker = Vec::with_capacity(n);
    for (w, stats) in &per {
        let mut fields = vec![
            ("addr", json::s(state.addr(*w))),
            ("state", json::s(states[*w].name())),
            ("reachable", Value::Bool(stats.is_some())),
        ];
        if let Some(v) = stats {
            let num = |key: &str| v.get(key).and_then(Value::as_f64).unwrap_or(0.0);
            completions += num("completions");
            tokens += num("tokens_generated");
            let qd = num("queue_depth");
            queue_depth += qd;
            fields.push(("queue_depth", json::num(qd)));
            fields.push(("completions", json::num(num("completions"))));
            if let Some(window) = v.get("latencies_secs").and_then(Value::as_arr) {
                latencies.extend(window.iter().filter_map(Value::as_f64));
            }
            // pass each worker's bucket counts through verbatim AND sum
            // them — buckets are the one latency form that aggregates by
            // plain addition (see this fn's rustdoc)
            if let Some(counts) = v.get("latency_bucket_counts").and_then(Value::as_arr) {
                let counts: Vec<f64> = counts.iter().filter_map(Value::as_f64).collect();
                for (acc, &c) in fleet_buckets.iter_mut().zip(&counts) {
                    *acc += c as u64;
                }
                fields.push((
                    "latency_buckets",
                    json::arr(counts.into_iter().map(json::num).collect()),
                ));
            }
        }
        per_worker.push(json::obj(fields));
    }
    let healthy = states.iter().filter(|&&s| s == WorkerState::Healthy).count();
    // percentiles over the CONCATENATED windows, computed exactly once —
    // a mean of per-worker p95s is not the fleet p95
    let body = json::obj(vec![
        ("workers", json::num(n as f64)),
        ("workers_healthy", json::num(healthy as f64)),
        ("completions", json::num(completions)),
        ("tokens_generated", json::num(tokens)),
        ("queue_depth", json::num(queue_depth)),
        ("latency_samples", json::num(latencies.len() as f64)),
        ("p50_latency_secs", json::num(util::percentile(&latencies, 50.0))),
        ("p95_latency_secs", json::num(util::percentile(&latencies, 95.0))),
        (
            "latency_bucket_le_us",
            json::arr(obs::LATENCY_BUCKETS_US.iter().map(|&e| json::num(e as f64)).collect()),
        ),
        (
            "latency_bucket_counts",
            json::arr(fleet_buckets.into_iter().map(|c| json::num(c as f64)).collect()),
        ),
        ("per_worker", json::arr(per_worker)),
    ]);
    let _ = respond(stream, 200, "OK", &body.to_json());
}

fn handle_cluster_collections(state: &RouterState, stream: &mut TcpStream) {
    let routes = state.routes.lock().unwrap();
    let collections = json::arr(
        routes
            .iter()
            .map(|(name, r)| {
                json::obj(vec![
                    ("name", json::s(name)),
                    ("rows", json::num(r.rows as f64)),
                    ("dim", json::num(r.dim as f64)),
                    ("shards", json::arr(r.shards.iter().map(|&w| json::num(w as f64)).collect())),
                    (
                        "workers",
                        json::arr(r.shards.iter().map(|&w| json::s(state.addr(w))).collect()),
                    ),
                    ("pending", Value::Bool(r.pending.is_some())),
                ])
            })
            .collect(),
    );
    let body = json::obj(vec![("collections", collections)]);
    let _ = respond(stream, 200, "OK", &body.to_json());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_safe() {
        let cfg = RouterConfig::default();
        assert!(cfg.client.connect_timeout.is_some(), "RPCs must never hang on connect");
        assert!(cfg.client.read_timeout.is_some(), "RPCs must never hang on read");
        assert!(Router::bind("127.0.0.1:0", cfg).is_err(), "no workers must refuse to bind");
    }

    #[test]
    fn parse_vectors_body_validates() {
        let ok = parse_vectors_body(br#"{"vectors": [[1.0, 2.0], [3.0, 4.0]]}"#).unwrap();
        assert_eq!(ok, (vec![1.0, 2.0, 3.0, 4.0], 2));
        assert!(parse_vectors_body(br#"{"vectors": []}"#).is_err());
        assert!(parse_vectors_body(br#"{"vectors": [[1.0], [1.0, 2.0]]}"#).is_err());
        assert!(parse_vectors_body(br#"{"texts": ["a"]}"#).is_err(), "router cannot embed");
        assert!(parse_vectors_body(b"nonsense").is_err());
    }

    #[test]
    fn parse_query_body_defaults_and_bounds() {
        let (q, k, rf) = parse_query_body(br#"{"vector": [0.5, 1.5]}"#).unwrap();
        assert_eq!((q, k, rf), (vec![0.5, 1.5], 10, DEFAULT_RERANK_FACTOR));
        let (_, k, rf) =
            parse_query_body(br#"{"vector": [1.0], "k": 3, "rerank_factor": 7}"#).unwrap();
        assert_eq!((k, rf), (3, 7));
        assert!(parse_query_body(br#"{"vector": [1.0], "k": 0}"#).is_err());
        assert!(parse_query_body(br#"{"k": 3}"#).is_err(), "vector is required");
    }

    #[test]
    fn parse_hits_round_trips_scores() {
        let v = json::parse(r#"{"candidates": [{"id": 3, "score": 0.25}, {"id": 0, "score": -1.5}]}"#)
            .unwrap();
        let hits = parse_hits(&v, "candidates").unwrap();
        assert_eq!(hits.len(), 2);
        assert_eq!((hits[0].id, hits[0].score), (3, 0.25));
        assert_eq!((hits[1].id, hits[1].score), (0, -1.5));
        let bad = json::parse(r#"{"candidates": [{"id": -1, "score": 0.0}]}"#).unwrap();
        assert!(parse_hits(&bad, "candidates").is_none());
    }
}
