//! Pure shard arithmetic and the deterministic scatter-gather merge.
//!
//! Everything here is a total function of its arguments — no sockets, no
//! locks — so the bit-identical-to-single-node contract can be pinned by
//! unit tests and golden vectors without standing up a cluster.
//!
//! # The partition
//!
//! Rows are assigned to shards **round-robin by global id**: row `g`
//! lives on shard `g % S` at local position `g / S`. Two properties make
//! the distributed query exact:
//!
//! 1. *Local order is a restriction of global order.* Within one shard,
//!    ascending local position is ascending global id, so a shard's
//!    (score desc, local id asc) ordering maps to (score desc, global id
//!    asc) after translation — the exact tie-break
//!    [`crate::index::top_indices`] uses.
//! 2. *Rank argument.* A single node picks the global top-`take` rows by
//!    estimated score. Each of those rows ranks at most `take`-th on its
//!    own shard (removing rows can only improve a row's rank), so asking
//!    every shard for its local top-`take` candidates — with `take`
//!    computed from the **global** row count — is guaranteed to surface
//!    the full single-node candidate set. [`select_candidates`] then
//!    re-selects the global top-`take` with the same comparator, which
//!    discards exactly the rows a single node would never have reranked.
//!
//! Phase two reranks the selected rows with exact scores on their owning
//! shards and [`merge_hits`] reproduces `Collection::query`'s final
//! (score desc, id asc) sort. Same rows, same rotation seed, same
//! comparators ⇒ byte-identical results.

use crate::index::SearchHit;

/// Shard owning global row `gid` under `n_shards`-way round-robin.
pub fn shard_of(gid: usize, n_shards: usize) -> usize {
    gid % n_shards.max(1)
}

/// Local position of global row `gid` on its owning shard.
pub fn local_of(gid: usize, n_shards: usize) -> usize {
    gid / n_shards.max(1)
}

/// Global id of local row `local` on shard `shard`.
pub fn global_of(shard: usize, local: usize, n_shards: usize) -> usize {
    local * n_shards.max(1) + shard
}

/// Rows held by `shard` when `n` rows total have been appended —
/// equivalently, the local row count *before* global row `n` lands, i.e.
/// the `expect_first_id` a router sends with shard `shard`'s slice of a
/// batch whose first global id is `n`.
pub fn shard_rows(shard: usize, n_shards: usize, n: usize) -> usize {
    let s = n_shards.max(1);
    n / s + usize::from(shard < n % s)
}

/// The candidate budget a single node would use: `rerank_factor.max(1) *
/// k`, capped at the global row count `n`. Mirrors
/// [`crate::index::Collection::query`]'s `take` exactly — the cluster
/// must compute it from the *global* `n`, never a shard-local count.
pub fn global_take(k: usize, rerank_factor: usize, n: usize) -> usize {
    rerank_factor.max(1).saturating_mul(k).min(n)
}

/// Split a flat row-major batch into per-shard flat slices under the
/// round-robin partition, given the global id of the batch's first row.
/// Returned `slices[s]` holds shard `s`'s rows in ascending global-id
/// order — which is exactly append order on that shard.
pub fn split_rows(flat: &[f32], d: usize, n_shards: usize, first_gid: usize) -> Vec<Vec<f32>> {
    let s = n_shards.max(1);
    let mut slices = vec![Vec::new(); s];
    for (i, row) in flat.chunks_exact(d).enumerate() {
        slices[shard_of(first_gid + i, s)].extend_from_slice(row);
    }
    slices
}

/// Phase-one gather: translate each shard's estimated-score candidates
/// to global ids and re-select the global top-`take` by (estimated score
/// desc, global id asc) — the same comparator as
/// [`crate::index::top_indices`]. `per_shard[s]` is shard `s`'s local
/// candidate list (local ids); entries whose global id is `>=
/// acked_rows` are dropped first, so rows from a partially applied batch
/// can never leak into results.
pub fn select_candidates(
    per_shard: &[(usize, Vec<SearchHit>)],
    n_shards: usize,
    take: usize,
    acked_rows: usize,
) -> Vec<SearchHit> {
    let mut all: Vec<SearchHit> = Vec::new();
    for &(shard, ref hits) in per_shard {
        for h in hits {
            let gid = global_of(shard, h.id, n_shards);
            if gid < acked_rows {
                all.push(SearchHit { id: gid, score: h.score });
            }
        }
    }
    all.sort_by(|a, b| {
        b.score
            .partial_cmp(&a.score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    all.truncate(take);
    all
}

/// Phase-two gather: merge exact-score hits (already translated to
/// global ids) into the final top-`k`, sorted (score desc, id asc) —
/// the same final sort as [`crate::index::Collection::query`].
pub fn merge_hits(mut hits: Vec<SearchHit>, k: usize) -> Vec<SearchHit> {
    hits.sort_by(|a, b| {
        b.score
            .partial_cmp(&a.score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    hits.truncate(k);
    hits
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_round_trips() {
        for s in 1..5usize {
            for g in 0..40usize {
                assert_eq!(global_of(shard_of(g, s), local_of(g, s), s), g);
            }
            // shard_rows counts exactly the gids below n on each shard
            for n in 0..40usize {
                for sh in 0..s {
                    let count = (0..n).filter(|&g| shard_of(g, s) == sh).count();
                    assert_eq!(shard_rows(sh, s, n), count, "shard {sh} of {s}, n={n}");
                }
            }
        }
    }

    #[test]
    fn split_preserves_rows_and_order() {
        let d = 2;
        let flat: Vec<f32> = (0..10).map(|x| x as f32).collect(); // 5 rows
        let slices = split_rows(&flat, d, 3, 4); // gids 4..9
        // gid 4 -> shard 1, 5 -> 2, 6 -> 0, 7 -> 1, 8 -> 2
        assert_eq!(slices[0], vec![4.0, 5.0]); // row for gid 6
        assert_eq!(slices[1], vec![0.0, 1.0, 6.0, 7.0]); // gids 4, 7
        assert_eq!(slices[2], vec![2.0, 3.0, 8.0, 9.0]); // gids 5, 8
    }

    #[test]
    fn global_take_mirrors_single_node() {
        assert_eq!(global_take(10, 4, 1000), 40);
        assert_eq!(global_take(10, 0, 1000), 10); // factor clamps to 1
        assert_eq!(global_take(10, 4, 25), 25); // capped at n
    }

    #[test]
    fn select_candidates_orders_filters_and_truncates() {
        // two shards, S = 2: shard 0 holds even gids, shard 1 odd
        let per_shard = vec![
            (0usize, vec![SearchHit { id: 0, score: 3.0 }, SearchHit { id: 1, score: 1.0 }]),
            (1usize, vec![SearchHit { id: 0, score: 3.0 }, SearchHit { id: 1, score: 2.0 }]),
        ];
        // gids: shard0 local0 -> 0 (3.0), local1 -> 2 (1.0);
        //       shard1 local0 -> 1 (3.0), local1 -> 3 (2.0)
        let sel = select_candidates(&per_shard, 2, 3, usize::MAX);
        let got: Vec<(usize, f32)> = sel.iter().map(|h| (h.id, h.score)).collect();
        // tie at 3.0 breaks by ascending gid: 0 before 1
        assert_eq!(got, vec![(0, 3.0), (1, 3.0), (3, 2.0)]);
        // acked watermark drops pending rows before selection
        let sel = select_candidates(&per_shard, 2, 3, 2);
        let got: Vec<usize> = sel.iter().map(|h| h.id).collect();
        assert_eq!(got, vec![0, 1]);
    }

    #[test]
    fn merge_matches_query_final_sort() {
        let hits = vec![
            SearchHit { id: 7, score: 0.5 },
            SearchHit { id: 2, score: 0.9 },
            SearchHit { id: 5, score: 0.9 },
            SearchHit { id: 1, score: 0.1 },
        ];
        let m = merge_hits(hits, 3);
        let got: Vec<usize> = m.iter().map(|h| h.id).collect();
        assert_eq!(got, vec![2, 5, 7]); // 0.9-tie breaks by id asc
    }
}
