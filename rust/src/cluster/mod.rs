//! Horizontal scale-out: a sharded router/worker cluster.
//!
//! The single-node deployment (`raana serve`) is one batcher + one
//! vector store behind one HTTP front-end — cheap per node thanks to
//! RaanA's calibration-light quantization, but a hard ceiling on
//! concurrent load. This module turns capacity into a flag: `N` worker
//! processes (each an unmodified single node) behind a thin **router**
//! that owns placement, health, and merging — and nothing else.
//!
//! ```text
//!                        ┌────────────────────┐
//!            clients ──▶ │       router       │  raana router
//!                        │  ring · health ·   │
//!                        │  scatter-gather    │
//!                        └──┬──────┬──────┬───┘
//!                     HTTP/JSON (the public API is the RPC)
//!                        ┌──▼──┐ ┌─▼───┐ ┌▼────┐
//!                        │ w0  │ │ w1  │ │ w2  │   raana worker
//!                        │batch│ │batch│ │batch│
//!                        │index│ │index│ │index│
//!                        └─────┘ └─────┘ └─────┘
//! ```
//!
//! * [`ring`] — consistent hashing: which workers hold a collection's
//!   shards. Stable under worker-list reordering; adding a worker moves
//!   ~1/n of placements.
//! * [`merge`] — the pure round-robin row partition and the two-phase
//!   scatter-gather merge, bit-identical to a single node holding the
//!   same rows (rank-argument proof in its module docs).
//! * [`health`] — the Healthy/Suspect/Down/Draining state machine that
//!   takes workers out of rotation on bounded failures and re-admits
//!   them on the first successful probe.
//! * [`router`] — the process: HTTP front-end, background prober,
//!   generate load-balancing, sharded add with `expect_first_id`
//!   exactly-once retries, scatter-gather query with explicit
//!   degradation, fleet-wide stats.
//!
//! The determinism contract extends the single-node one: same rows,
//! same store seed, same query ⇒ the router's merged top-k equals the
//! single node's bit-for-bit, regardless of shard count — pinned by
//! `rust/tests/cluster.rs`, the numpy mirror
//! `python/tests/test_cluster.py`, and the `cluster_merge.json` golden
//! vectors.

pub mod health;
pub mod merge;
pub mod ring;
pub mod router;

pub use health::{FleetHealth, WorkerState, DEFAULT_DOWN_AFTER};
pub use ring::Ring;
pub use router::{Router, RouterConfig, DEFAULT_PROBE_INTERVAL_MS, DEFAULT_RPC_TIMEOUT_MS};
