//! Native CPU backend: the GPT-style forward pass (python/compile/model.py)
//! implemented directly over the fused kernels, plus the packed-weight
//! serving state.
//!
//! Two weight representations drive the same forward:
//!
//! * **dense** — full-precision parameters out of [`ModelParams`], linear
//!   layers via [`crate::kernels::gemm`];
//! * **packed** ([`PackedLayers`]) — every registered linear held as a
//!   RaBitQ-H [`QuantizedLinear`] (bit-packed codes + RHT signs + outlier
//!   rows), applied via [`crate::kernels::qgemm`] with **zero full-matrix
//!   dequantization per forward** — the request path computes on codes.
//!
//! The architecture mirrors `python/compile/model.py` exactly (pre-LN
//! blocks, causal attention, tanh-approximate GELU, weight-tied nothing,
//! fp lm_head), so when the PJRT artifacts are available the two backends
//! are interchangeable; when they are not (offline vendor stub), this is
//! the serving path.
//!
//! Generation runs incrementally: [`NativeModel::prefill`] executes a
//! prompt once at positions `0..L` and deposits every layer's K/V rows
//! into a [`KvCache`] slot; [`NativeModel::decode_step`] then extends any
//! batch of slots by one token each, attending over the cached rows via
//! [`crate::kernels::attend_cached`] instead of recomputing the window.
//! Every kernel on the path reduces each output row in a batch-size-
//! independent order, so prefill + decode steps reproduce the
//! full-recompute logits ([`NativeModel::last_logits_ctx`]) **bit for
//! bit** — property-tested in `rust/tests/integration.rs`. When a slot's
//! window fills, callers slide it by re-prefilling the last `capacity`
//! tokens (absolute position embeddings invalidate shifted K/V rows, so
//! this is the only recompute left on the path).

use anyhow::Result;

use crate::kernels;
use crate::kvq::{KvqError, KvqPlan, QuantizedKvStore};
use crate::model::{Manifest, ModelParams};
use crate::quant::{LayerCalib, QuantizedLinear, TrickConfig};
use crate::rng::Rng;
use crate::tensor::Matrix;

/// Validated model dimensions for the native forward, plus every
/// parameter and linear index the forward ever touches, resolved **once**
/// at construction. The per-step path performs zero name-based lookups
/// and zero string formatting — enforced by the
/// [`crate::model::name_resolutions`] counter (regression test in
/// `rust/tests/integration.rs`).
#[derive(Clone, Debug)]
pub struct NativeModel {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub vocab: usize,
    idx: ForwardIdx,
}

/// Construction-time-resolved tensor/linear indices (see [`NativeModel`]).
/// Tensor indices address `ModelParams::tensors`, valid because tensors
/// are stored in manifest order (`ModelParams` docs); linear indices
/// address `Manifest::linears` == `PackedLayers::layers`.
#[derive(Clone, Debug)]
struct ForwardIdx {
    tok_emb: usize,
    pos_emb: usize,
    ln_f_scale: usize,
    ln_f_bias: usize,
    lm_head: usize,
    /// Manifest param count — the cheap per-call layout guard.
    n_params: usize,
    blocks: Vec<BlockIdx>,
}

/// One transformer block's resolved indices.
#[derive(Clone, Debug)]
struct BlockIdx {
    ln1_scale: usize,
    ln1_bias: usize,
    ln2_scale: usize,
    ln2_bias: usize,
    wq: LinearIdx,
    wk: LinearIdx,
    wv: LinearIdx,
    wo: LinearIdx,
    fc1: LinearIdx,
    fc2: LinearIdx,
}

/// One registered linear, fully resolved: registry slot + weight/bias
/// tensor indices + shape.
#[derive(Clone, Debug)]
struct LinearIdx {
    /// Index into `Manifest::linears` (== the packed layer slot).
    lin: usize,
    /// Weight tensor index into `ModelParams::tensors`.
    param: usize,
    /// Bias tensor index into `ModelParams::tensors`.
    bias: usize,
    /// Input dim.
    d: usize,
    /// Output dim.
    c: usize,
}

impl NativeModel {
    pub fn new(m: &Manifest) -> Result<Self> {
        anyhow::ensure!(m.n_heads > 0 && m.d_model % m.n_heads == 0, "d_model % n_heads != 0");
        anyhow::ensure!(m.seq_len >= 2, "seq_len must be >= 2");
        // Resolve every name the forward will ever need, here and never
        // again: these are the only (counted) string scans on the native
        // path after construction.
        let resolve_linear = |name: &str| -> Result<LinearIdx> {
            let k = m.linear_index(name)?;
            let lin = &m.linears[k];
            Ok(LinearIdx {
                lin: k,
                param: m.param_index(&lin.param)?,
                bias: m.param_index(&lin.bias)?,
                d: lin.d,
                c: lin.c,
            })
        };
        let mut blocks = Vec::with_capacity(m.n_layers);
        for layer in 0..m.n_layers {
            let pre = format!("blk{layer}.");
            blocks.push(BlockIdx {
                ln1_scale: m.param_index(&format!("{pre}ln1.scale"))?,
                ln1_bias: m.param_index(&format!("{pre}ln1.bias"))?,
                ln2_scale: m.param_index(&format!("{pre}ln2.scale"))?,
                ln2_bias: m.param_index(&format!("{pre}ln2.bias"))?,
                wq: resolve_linear(&format!("{pre}attn.wq"))?,
                wk: resolve_linear(&format!("{pre}attn.wk"))?,
                wv: resolve_linear(&format!("{pre}attn.wv"))?,
                wo: resolve_linear(&format!("{pre}attn.wo"))?,
                fc1: resolve_linear(&format!("{pre}mlp.fc1"))?,
                fc2: resolve_linear(&format!("{pre}mlp.fc2"))?,
            });
        }
        let idx = ForwardIdx {
            tok_emb: m.param_index("tok_emb")?,
            pos_emb: m.param_index("pos_emb")?,
            ln_f_scale: m.param_index("ln_f.scale")?,
            ln_f_bias: m.param_index("ln_f.bias")?,
            lm_head: m.param_index("lm_head")?,
            n_params: m.params.len(),
            blocks,
        };
        Ok(NativeModel {
            d_model: m.d_model,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            head_dim: m.d_model / m.n_heads,
            d_ff: m.d_ff,
            seq_len: m.seq_len,
            vocab: m.vocab,
            idx,
        })
    }

    /// Indexed access assumes `params` is in manifest order — the only
    /// order [`ModelParams`] is ever built in (`zeros` / `from_tensors`
    /// clone the manifest's spec table; the `.rkpt` format round-trips
    /// it). One arity check per call guards gross mismatches; debug
    /// builds verify the resolved anchors by name.
    fn check_params(&self, params: &ModelParams) -> Result<()> {
        anyhow::ensure!(
            params.tensors.len() == self.idx.n_params,
            "params/manifest arity mismatch: {} tensors, manifest has {}",
            params.tensors.len(),
            self.idx.n_params
        );
        debug_assert_eq!(params.specs[self.idx.tok_emb].name, "tok_emb");
        debug_assert_eq!(params.specs[self.idx.lm_head].name, "lm_head");
        Ok(())
    }

    /// Last-position logits, (B, vocab) row-major. `tokens` is any whole
    /// number of sequences (B*S); the artifact path's fixed eval_batch
    /// does not bind here.
    pub fn last_logits(
        &self,
        m: &Manifest,
        params: &ModelParams,
        packed: Option<&PackedLayers>,
        tokens: &[i32],
        threads: usize,
    ) -> Result<Vec<f32>> {
        let hid = self.forward_hidden(m, params, packed, tokens, threads, None)?;
        let s = self.seq_len;
        let b = hid.rows / s;
        let rows: Vec<usize> = (0..b).map(|bi| bi * s + s - 1).collect();
        self.project_rows(params, &hid, &rows, threads)
    }

    /// Last-position logits `(vocab,)` for ONE variable-length context:
    /// `tokens` is a single unpadded sequence of length `1..=seq_len`
    /// embedded at positions `0..len`. This is the full-recompute
    /// reference that the KV-cached path ([`NativeModel::prefill`] +
    /// [`NativeModel::decode_step`]) is tested bit-identical against, and
    /// what recompute serving runs once per generated token.
    pub fn last_logits_ctx(
        &self,
        m: &Manifest,
        params: &ModelParams,
        packed: Option<&PackedLayers>,
        tokens: &[i32],
        threads: usize,
    ) -> Result<Vec<f32>> {
        let l = tokens.len();
        anyhow::ensure!(
            l >= 1 && l <= self.seq_len,
            "context length {l} not in 1..={}",
            self.seq_len
        );
        let hid = self.forward_hidden_seq(m, params, packed, tokens, l, threads, None, None)?;
        self.project_rows(params, &hid, &[l - 1], threads)
    }

    /// Embed ONE variable-length token sequence: run it through the full
    /// forward at positions `0..len`, mean-pool the final hidden states
    /// over the sequence, and L2-normalize — the representation the
    /// retrieval subsystem ([`crate::index`]) stores and searches.
    ///
    /// With packed weights attached the forward computes on RaBitQ codes
    /// (same zero-dequantization path as generation). Deterministic in
    /// the thread count; an all-zero pooled vector (degenerate) is
    /// returned unnormalized rather than dividing by zero.
    pub fn embed(
        &self,
        m: &Manifest,
        params: &ModelParams,
        packed: Option<&PackedLayers>,
        tokens: &[i32],
        threads: usize,
    ) -> Result<Vec<f32>> {
        let l = tokens.len();
        anyhow::ensure!(
            l >= 1 && l <= self.seq_len,
            "embed context length {l} not in 1..={}",
            self.seq_len
        );
        let hid = self.forward_hidden_seq(m, params, packed, tokens, l, threads, None, None)?;
        let d = self.d_model;
        // mean-pool in f64 so the pooled vector is independent of how the
        // forward batched its rows
        let mut acc = vec![0f64; d];
        for i in 0..l {
            for (a, &h) in acc.iter_mut().zip(hid.row(i)) {
                *a += h as f64;
            }
        }
        let inv = 1.0 / l as f64;
        let norm: f64 = acc.iter().map(|&x| (x * inv) * (x * inv)).sum::<f64>().sqrt();
        let scale = if norm > 0.0 { inv / norm } else { inv };
        Ok(acc.iter().map(|&x| (x * scale) as f32).collect())
    }

    /// Gather `rows` of the final hidden states and project them through
    /// the fp lm_head; returns `(rows.len() * vocab)` row-major logits.
    fn project_rows(
        &self,
        params: &ModelParams,
        hid: &Matrix,
        rows: &[usize],
        threads: usize,
    ) -> Result<Vec<f32>> {
        let (d, v) = (self.d_model, self.vocab);
        let lm = &params.tensors[self.idx.lm_head];
        let mut last = Matrix::zeros(rows.len(), d);
        for (i, &r) in rows.iter().enumerate() {
            last.row_mut(i).copy_from_slice(hid.row(r));
        }
        let mut out = Matrix::zeros(rows.len(), v);
        kernels::gemm(rows.len(), d, v, &last.data, lm, &mut out.data, threads);
        Ok(out.data)
    }

    /// Per-token next-token NLL, (B, S-1) row-major — matches the
    /// `fwd_loss` artifact's output layout.
    pub fn token_nll(
        &self,
        m: &Manifest,
        params: &ModelParams,
        packed: Option<&PackedLayers>,
        tokens: &[i32],
        threads: usize,
    ) -> Result<Vec<f32>> {
        let hid = self.forward_hidden(m, params, packed, tokens, threads, None)?;
        let (s, d, v) = (self.seq_len, self.d_model, self.vocab);
        let b = hid.rows / s;
        let lm = &params.tensors[self.idx.lm_head];
        let mut logits = Matrix::zeros(b * s, v);
        kernels::gemm(b * s, d, v, &hid.data, lm, &mut logits.data, threads);
        let mut nll = Vec::with_capacity(b * (s - 1));
        for bi in 0..b {
            for t in 0..s - 1 {
                let row = logits.row(bi * s + t);
                let tgt = tokens[bi * s + t + 1] as usize;
                let maxl = row.iter().fold(f32::NEG_INFINITY, |mx, &x| mx.max(x));
                let lse = maxl
                    + row
                        .iter()
                        .map(|&x| ((x - maxl) as f64).exp())
                        .sum::<f64>()
                        .ln() as f32;
                nll.push(lse - row[tgt]);
            }
        }
        Ok(nll)
    }

    /// Run a forward capturing each registered linear layer's input
    /// statistics (calibration without the PJRT `calib_capture` artifact).
    /// Stats are reduced in place per capture point — no activation matrix
    /// is retained. Returns per-layer stats in manifest linear order.
    pub fn capture_layer_stats(
        &self,
        m: &Manifest,
        params: &ModelParams,
        tokens: &[i32],
        threads: usize,
    ) -> Result<Vec<LayerCalib>> {
        let mut captures: Vec<LayerCalib> = Vec::with_capacity(m.linears.len());
        let _ = self.forward_hidden(m, params, None, tokens, threads, Some(&mut captures))?;
        anyhow::ensure!(captures.len() == m.linears.len(), "capture arity");
        Ok(captures)
    }

    /// Full forward through every block and the final LayerNorm at the
    /// model's fixed window (`seq_len`); returns the (B*S, d_model) hidden
    /// states ready for the lm_head projection.
    fn forward_hidden(
        &self,
        m: &Manifest,
        params: &ModelParams,
        packed: Option<&PackedLayers>,
        tokens: &[i32],
        threads: usize,
        capture: Option<&mut Vec<LayerCalib>>,
    ) -> Result<Matrix> {
        self.forward_hidden_seq(m, params, packed, tokens, self.seq_len, threads, capture, None)
    }

    /// [`NativeModel::forward_hidden`] generalized to a caller-chosen
    /// sequence length `s <= seq_len` (positions `0..s`). When `cache` is
    /// set (prefill), the batch must be a single sequence and every
    /// layer's K/V rows are stored into that cache slot as they are
    /// computed; the stored values are exactly the rows the in-forward
    /// attention consumes, which is what makes later cached decode steps
    /// bit-identical to recompute.
    #[allow(clippy::too_many_arguments)]
    fn forward_hidden_seq(
        &self,
        m: &Manifest,
        params: &ModelParams,
        packed: Option<&PackedLayers>,
        tokens: &[i32],
        s: usize,
        threads: usize,
        mut capture: Option<&mut Vec<LayerCalib>>,
        mut cache: Option<(&mut KvCache, usize)>,
    ) -> Result<Matrix> {
        let d = self.d_model;
        anyhow::ensure!(
            s >= 1 && s <= self.seq_len,
            "sequence length {s} not in 1..={}",
            self.seq_len
        );
        anyhow::ensure!(
            !tokens.is_empty() && tokens.len() % s == 0,
            "token batch must be a whole number of length-{s} sequences"
        );
        let b = tokens.len() / s;
        if let Some(p) = packed {
            anyhow::ensure!(p.layers.len() == m.linears.len(), "packed layer arity");
        }
        if let Some((kv, slot)) = cache.as_ref() {
            anyhow::ensure!(b == 1, "cache prefill takes a single sequence");
            anyhow::ensure!(*slot < kv.slots(), "cache slot {slot} out of range");
            anyhow::ensure!(s <= kv.capacity(), "sequence exceeds cache capacity");
        }
        self.check_params(params)?;

        // embeddings (construction-resolved indices: no name lookups here
        // or anywhere below — see `ForwardIdx`)
        let tok_emb = &params.tensors[self.idx.tok_emb];
        let pos_emb = &params.tensors[self.idx.pos_emb];
        let mut h = Matrix::zeros(b * s, d);
        for bi in 0..b {
            for si in 0..s {
                let t = tokens[bi * s + si];
                anyhow::ensure!(
                    t >= 0 && (t as usize) < self.vocab,
                    "token {t} out of vocab range"
                );
                let te = &tok_emb[(t as usize) * d..(t as usize + 1) * d];
                let pe = &pos_emb[si * d..(si + 1) * d];
                let row = h.row_mut(bi * s + si);
                for ((o, &a), &p) in row.iter_mut().zip(te).zip(pe) {
                    *o = a + p;
                }
            }
        }

        // quantized caches attend over their just-stored codes, so the
        // prefill borrows the cache's recycled code-path scratch
        let mut kv_scratch = match cache.as_mut() {
            Some((kv, _)) if kv.is_quantized() => Some(kv.take_scratch()),
            _ => None,
        };

        for layer in 0..self.n_layers {
            let blk = &self.idx.blocks[layer];

            // attention sub-block (pre-LN)
            let x = layer_norm(
                &h,
                &params.tensors[blk.ln1_scale],
                &params.tensors[blk.ln1_bias],
            );
            let lin = |li: &LinearIdx, inp: &Matrix, cap: Option<&mut Vec<LayerCalib>>| {
                self.linear(params, packed, li, inp, threads, cap)
            };
            let q = lin(&blk.wq, &x, capture.as_deref_mut())?;
            let k = lin(&blk.wk, &x, capture.as_deref_mut())?;
            let v = lin(&blk.wv, &x, capture.as_deref_mut())?;
            if let Some((kv, slot)) = cache.as_mut() {
                for si in 0..s {
                    kv.store(layer, *slot, si, k.row(si), v.row(si));
                }
            }
            // A quantized cache's prefill attends over the codes it just
            // stored — each query position sees exactly the representation
            // later decode steps will see, which is what makes quantized
            // decode bit-identical to a quantized re-prefill. Dense caches
            // keep the bit-exact in-forward f32 path.
            let att = match cache.as_mut() {
                Some((kv, slot)) if kv.is_quantized() => {
                    let scratch = kv_scratch.as_mut().expect("quantized prefill scratch");
                    let mut o = Matrix::zeros(s, d);
                    for si in 0..s {
                        kv.attend(
                            layer,
                            *slot,
                            si + 1,
                            q.row(si),
                            self.n_heads,
                            self.head_dim,
                            scratch,
                            o.row_mut(si),
                        );
                    }
                    o
                }
                _ => self.attention(&q, &k, &v, s),
            };
            let proj = lin(&blk.wo, &att, capture.as_deref_mut())?;
            h.add_assign(&proj);

            // MLP sub-block (pre-LN)
            let x = layer_norm(
                &h,
                &params.tensors[blk.ln2_scale],
                &params.tensors[blk.ln2_bias],
            );
            let mut y = lin(&blk.fc1, &x, capture.as_deref_mut())?;
            for v in y.data.iter_mut() {
                *v = gelu(*v);
            }
            let y = lin(&blk.fc2, &y, capture.as_deref_mut())?;
            h.add_assign(&y);
        }

        if let (Some(s), Some((kv, _))) = (kv_scratch.take(), cache.as_mut()) {
            kv.put_scratch(s);
        }
        Ok(layer_norm(
            &h,
            &params.tensors[self.idx.ln_f_scale],
            &params.tensors[self.idx.ln_f_bias],
        ))
    }

    /// One registered linear layer: packed (qgemm on codes) or dense
    /// (full-precision gemm), plus the layer bias. `capture`, when set,
    /// receives the layer input (forward order = manifest linear order).
    /// Addressed entirely by construction-resolved [`LinearIdx`] — no
    /// registry scan, no name lookup.
    fn linear(
        &self,
        params: &ModelParams,
        packed: Option<&PackedLayers>,
        li: &LinearIdx,
        x: &Matrix,
        threads: usize,
        capture: Option<&mut Vec<LayerCalib>>,
    ) -> Result<Matrix> {
        anyhow::ensure!(x.cols == li.d, "linear input dim mismatch");
        if let Some(c) = capture {
            c.push(LayerCalib::from_activations(x));
        }
        let mut y = match packed {
            Some(p) => p.layers[li.lin].forward_est_threaded(x, threads),
            None => {
                let w = &params.tensors[li.param];
                let mut out = Matrix::zeros(x.rows, li.c);
                kernels::gemm(x.rows, li.d, li.c, &x.data, w, &mut out.data, threads);
                out
            }
        };
        let bias = &params.tensors[li.bias];
        for i in 0..y.rows {
            for (o, &bv) in y.row_mut(i).iter_mut().zip(bias) {
                *o += bv;
            }
        }
        Ok(y)
    }

    /// Causal multi-head attention over (B*S, d) q/k/v with sequence
    /// length `s`; returns (B*S, d). Each query position runs through
    /// [`kernels::attend_cached`] over the preceding K/V rows — the same
    /// kernel [`NativeModel::decode_step`] calls over a [`KvCache`] slot,
    /// so the two paths cannot drift.
    fn attention(&self, q: &Matrix, k: &Matrix, v: &Matrix, s: usize) -> Matrix {
        let (hn, hd, d) = (self.n_heads, self.head_dim, self.d_model);
        let b = q.rows / s;
        let mut o = Matrix::zeros(q.rows, d);
        let mut scores = vec![0f32; s];
        for bi in 0..b {
            let base = bi * s;
            for qi in 0..s {
                kernels::attend_cached(
                    q.row(base + qi),
                    &k.data[base * d..(base + qi + 1) * d],
                    &v.data[base * d..(base + qi + 1) * d],
                    qi + 1,
                    hn,
                    hd,
                    &mut scores,
                    o.row_mut(base + qi),
                );
            }
        }
        o
    }

    /// Allocate a [`KvCache`] sized for this model (`capacity = seq_len`)
    /// with `slots` independent request slots.
    pub fn kv_cache(&self, slots: usize) -> KvCache {
        KvCache::new(self.n_layers, slots, self.seq_len, self.d_model)
    }

    /// [`NativeModel::kv_cache`] with **quantized** storage: rows live as
    /// packed RaBitQ codes under the per-layer bit `plan` (see
    /// [`crate::kvq`]); prefill and decode attend directly over the codes.
    pub fn kv_cache_quantized(
        &self,
        slots: usize,
        plan: KvqPlan,
        rot_seed: u64,
    ) -> Result<KvCache, KvqError> {
        KvCache::new_quantized(
            self.n_layers,
            slots,
            self.seq_len,
            self.d_model,
            self.n_heads,
            plan,
            rot_seed,
        )
    }

    /// Run a whole prompt once at positions `0..tokens.len()`, fill cache
    /// `slot`'s per-layer K/V rows, and return the last-token logits
    /// `(vocab,)`. Whatever the slot previously held is evicted.
    ///
    /// The prompt must fit the slot window (`1..=capacity` tokens, with
    /// `capacity <= seq_len`); callers serving longer contexts pass the
    /// last `capacity` tokens — the same truncation the recompute
    /// reference applies.
    pub fn prefill(
        &self,
        m: &Manifest,
        params: &ModelParams,
        packed: Option<&PackedLayers>,
        tokens: &[i32],
        cache: &mut KvCache,
        slot: usize,
        threads: usize,
    ) -> Result<Vec<f32>> {
        let t0 = crate::obs::trace::tracer().now_us();
        cache.check_model(self)?;
        let l = tokens.len();
        anyhow::ensure!(
            l >= 1 && l <= cache.capacity(),
            "prompt length {l} not in 1..={}",
            cache.capacity()
        );
        anyhow::ensure!(slot < cache.slots(), "cache slot {slot} out of range");
        cache.reset(slot);
        let hid = self.forward_hidden_seq(
            m,
            params,
            packed,
            tokens,
            l,
            threads,
            None,
            Some((&mut *cache, slot)),
        )?;
        cache.set_len(slot, l);
        let out = self.project_rows(params, &hid, &[l - 1], threads);
        crate::obs::metrics()
            .native_prefill_us
            .observe_us(crate::obs::trace::tracer().now_us().saturating_sub(t0));
        out
    }

    /// One KV-cached generation step over a batch of active cache slots:
    /// row `i` embeds `tokens[i]` at position `cache.len(slots[i])`,
    /// appends its K/V rows to that slot, attends over the slot's cached
    /// window (itself included), and yields next-token logits. Returns
    /// `(slots.len() * vocab)` row-major logits and advances each slot by
    /// one position.
    ///
    /// Linear layers run through the same packed [`crate::kernels::qgemm`]
    /// path as the full forward — still zero dequantization — and every
    /// output row is bit-identical to the last row of a full recompute of
    /// that slot's context, independent of which other slots share the
    /// batch. Slots whose window is full are rejected: slide them with a
    /// fresh [`NativeModel::prefill`] over the last `capacity` tokens.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_step(
        &self,
        m: &Manifest,
        params: &ModelParams,
        packed: Option<&PackedLayers>,
        cache: &mut KvCache,
        slots: &[usize],
        tokens: &[i32],
        threads: usize,
    ) -> Result<Vec<f32>> {
        let t0 = crate::obs::trace::tracer().now_us();
        cache.check_model(self)?;
        let bsz = slots.len();
        anyhow::ensure!(bsz >= 1 && tokens.len() == bsz, "slots/tokens arity mismatch");
        for (i, &sl) in slots.iter().enumerate() {
            anyhow::ensure!(sl < cache.slots(), "cache slot {sl} out of range");
            anyhow::ensure!(!slots[..i].contains(&sl), "duplicate cache slot {sl}");
            anyhow::ensure!(cache.len(sl) >= 1, "slot {sl} has no prefilled context");
            anyhow::ensure!(
                cache.len(sl) < cache.capacity(),
                "slot {sl} window is full — re-prefill the slid window"
            );
        }
        if let Some(p) = packed {
            anyhow::ensure!(p.layers.len() == m.linears.len(), "packed layer arity");
        }
        self.check_params(params)?;

        // embeddings at each slot's next position (indexed access — the
        // decode step performs zero name lookups and zero `format!`s)
        let d = self.d_model;
        let tok_emb = &params.tensors[self.idx.tok_emb];
        let pos_emb = &params.tensors[self.idx.pos_emb];
        let mut h = Matrix::zeros(bsz, d);
        for (i, (&sl, &t)) in slots.iter().zip(tokens).enumerate() {
            anyhow::ensure!(
                t >= 0 && (t as usize) < self.vocab,
                "token {t} out of vocab range"
            );
            let pos = cache.len(sl);
            let te = &tok_emb[(t as usize) * d..(t as usize + 1) * d];
            let pe = &pos_emb[pos * d..(pos + 1) * d];
            for ((o, &a), &p) in h.row_mut(i).iter_mut().zip(te).zip(pe) {
                *o = a + p;
            }
        }

        let mut scratch = cache.take_scratch();
        for layer in 0..self.n_layers {
            let blk = &self.idx.blocks[layer];

            let x = layer_norm(
                &h,
                &params.tensors[blk.ln1_scale],
                &params.tensors[blk.ln1_bias],
            );
            let q = self.linear(params, packed, &blk.wq, &x, threads, None)?;
            let k = self.linear(params, packed, &blk.wk, &x, threads, None)?;
            let v = self.linear(params, packed, &blk.wv, &x, threads, None)?;
            let mut att = Matrix::zeros(bsz, d);
            for (i, &sl) in slots.iter().enumerate() {
                let pos = cache.len(sl);
                cache.store(layer, sl, pos, k.row(i), v.row(i));
                cache.attend(
                    layer,
                    sl,
                    pos + 1,
                    q.row(i),
                    self.n_heads,
                    self.head_dim,
                    &mut scratch,
                    att.row_mut(i),
                );
            }
            let proj = self.linear(params, packed, &blk.wo, &att, threads, None)?;
            h.add_assign(&proj);

            let x = layer_norm(
                &h,
                &params.tensors[blk.ln2_scale],
                &params.tensors[blk.ln2_bias],
            );
            let mut y = self.linear(params, packed, &blk.fc1, &x, threads, None)?;
            for vv in y.data.iter_mut() {
                *vv = gelu(*vv);
            }
            let y = self.linear(params, packed, &blk.fc2, &y, threads, None)?;
            h.add_assign(&y);
        }
        cache.put_scratch(scratch);
        let hid = layer_norm(
            &h,
            &params.tensors[self.idx.ln_f_scale],
            &params.tensors[self.idx.ln_f_bias],
        );
        for &sl in slots {
            cache.advance(sl);
        }
        let rows: Vec<usize> = (0..bsz).collect();
        let out = self.project_rows(params, &hid, &rows, threads);
        // model-only timing (no serve-layer overhead): the histogram pair
        // native_decode_us vs decode_step_us is what separates kernel
        // cost from batcher cost in the /metrics breakdown
        crate::obs::metrics()
            .native_decode_us
            .observe_us(crate::obs::trace::tracer().now_us().saturating_sub(t0));
        out
    }
}

/// Per-token LayerNorm (population variance, eps 1e-5 — matches
/// `_layer_norm` in python/compile/model.py).
fn layer_norm(h: &Matrix, scale: &[f32], bias: &[f32]) -> Matrix {
    let d = h.cols;
    let mut out = Matrix::zeros(h.rows, d);
    for i in 0..h.rows {
        let row = h.row(i);
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let orow = out.row_mut(i);
        for (j, o) in orow.iter_mut().enumerate() {
            *o = (row[j] - mean) * inv * scale[j] + bias[j];
        }
    }
    out
}

/// Tanh-approximate GELU (jax.nn.gelu's default).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

// ----------------------------------------------------------- packed layers

/// Resident packed weights for serving: every registered linear layer as a
/// [`QuantizedLinear`], in manifest linear order. This is what
/// `ModelRuntime` keeps hot so `fwd_logits` computes on codes.
#[derive(Clone, Debug)]
pub struct PackedLayers {
    pub layers: Vec<QuantizedLinear>,
}

impl PackedLayers {
    /// Quantize every registered linear of `params` at the per-layer
    /// bit-widths (AllocateBits output order). `stats` supplies the
    /// calibration statistics per layer (use [`LayerCalib::zeros`] for the
    /// calibration-free path).
    pub fn quantize(
        m: &Manifest,
        params: &ModelParams,
        bits: &[u8],
        stats: &[LayerCalib],
        tricks: &TrickConfig,
        seed: u64,
        threads: usize,
    ) -> Result<PackedLayers> {
        anyhow::ensure!(bits.len() == m.linears.len(), "bits/linears arity");
        anyhow::ensure!(stats.len() == m.linears.len(), "stats/linears arity");
        let mut rng = Rng::new(seed);
        let mut layers = Vec::with_capacity(m.linears.len());
        for (k, lin) in m.linears.iter().enumerate() {
            let w = params.matrix(&lin.param)?;
            layers.push(QuantizedLinear::quantize(
                &lin.name, &w, bits[k], &stats[k], tricks, &mut rng, threads,
            )?);
        }
        Ok(PackedLayers { layers })
    }

    /// Total stored payload bits across all layers.
    pub fn stored_bits(&self) -> usize {
        self.layers.iter().map(|l| l.stored_bits()).sum()
    }

    /// Average stored bits per quantizable parameter.
    pub fn avg_bits(&self) -> f64 {
        let m: usize = self.layers.iter().map(|l| l.d * l.c).sum();
        if m == 0 {
            return 0.0;
        }
        self.stored_bits() as f64 / m as f64
    }
}

// -------------------------------------------------------------- KV cache

/// Per-slot, per-layer K/V buffers backing incremental decoding.
///
/// One cache holds `slots` independent request slots; each slot owns, for
/// every transformer layer, a fixed-capacity window of K and V rows
/// (`capacity` positions × `d_model`, with `capacity` = the model's max
/// context). [`NativeModel::prefill`] fills positions `0..L` for one
/// slot; [`NativeModel::decode_step`] appends one row per step and
/// attends over the filled prefix. Slots are recycled between requests
/// with [`KvCache::reset`] — the batching server keeps exactly one cache
/// alive and maps request lanes onto slots.
///
/// Wraparound: the buffers are rings in the serving sense — when a slot's
/// window is full, the oldest entries are retired by re-prefilling the
/// window slid by one token. The slide is a genuine recompute because the
/// model's **absolute** position embeddings change every remaining
/// token's position, invalidating the cached rows; in-window decoding
/// (the common case) never recomputes anything.
///
/// # Backing stores
///
/// Two storage representations live behind this one API, so prefill,
/// decode, and the window slide are storage-agnostic:
///
/// * **Dense f32** ([`KvCache::new`]) — rows stored verbatim; attention
///   via [`crate::kernels::attend_cached`]. Bit-identical to full
///   recompute (the PR-2 contract, unchanged).
/// * **Quantized codes** ([`KvCache::new_quantized`]) — rows RHT-rotated
///   per head and RaBitQ-packed at store time under a per-layer
///   [`KvqPlan`] ([`crate::kvq`]); attention runs directly over the codes
///   via [`crate::kernels::attend_cached_q`]. Accuracy is *bounded
///   drift* (~`2^-bits`), in exchange for several-fold more lanes per
///   byte of cache RAM.
#[derive(Clone)]
pub struct KvCache {
    n_layers: usize,
    slots: usize,
    capacity: usize,
    d_model: usize,
    /// Filled prefix length per slot.
    len: Vec<usize>,
    store: KvStore,
    /// Parked attention scratch, reused across prefill/decode calls so the
    /// serving loop allocates nothing per token (see
    /// [`KvCache::take_scratch`]).
    parked_scratch: Option<KvAttendScratch>,
}

/// The two storage backends behind [`KvCache`].
#[derive(Clone, Debug)]
enum KvStore {
    /// Full-precision rows: flat `(layer, slot, pos) -> d_model` f32s.
    Dense {
        /// Flat K rows.
        k: Vec<f32>,
        /// Flat V rows, same layout.
        v: Vec<f32>,
    },
    /// RaBitQ-coded rows (boxed: the store holds its own per-layer
    /// buffers and scratch).
    Quantized(Box<QuantizedKvStore>),
}

/// Caller-owned attention scratch for [`KvCache`] batch loops: holds the
/// dense score buffer and (for quantized caches) the code-path scratch,
/// so neither backend allocates per query. Obtain via
/// [`KvCache::attend_scratch`] (fresh) or [`KvCache::take_scratch`]
/// (recycled across calls).
#[derive(Clone)]
pub struct KvAttendScratch {
    scores: Vec<f32>,
    q: Option<kernels::AttendQScratch>,
}

impl std::fmt::Debug for KvCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "KvCache(layers={} slots={} capacity={} d={} bits={:.1} lens={:?})",
            self.n_layers, self.slots, self.capacity, self.d_model, self.kv_bits(), self.len
        )
    }
}

#[deny(missing_docs)]
impl KvCache {
    /// Allocate an all-empty **dense f32** cache. Every dimension must be
    /// >= 1; memory is `2 * n_layers * slots * capacity * d_model` f32s,
    /// allocated once up front so the serving loop never allocates per
    /// token.
    pub fn new(n_layers: usize, slots: usize, capacity: usize, d_model: usize) -> KvCache {
        assert!(
            n_layers >= 1 && slots >= 1 && capacity >= 1 && d_model >= 1,
            "KvCache dimensions must be >= 1"
        );
        let n = n_layers * slots * capacity * d_model;
        KvCache {
            n_layers,
            slots,
            capacity,
            d_model,
            len: vec![0; slots],
            store: KvStore::Dense { k: vec![0.0; n], v: vec![0.0; n] },
            parked_scratch: None,
        }
    }

    /// Allocate an all-empty **quantized** cache: rows are RaBitQ-coded at
    /// store time under the per-layer bit `plan` (see [`crate::kvq`]).
    /// `rot_seed` seeds the shared per-head rotation signs
    /// ([`crate::kvq::DEFAULT_ROT_SEED`] serves fine). Errors are typed
    /// ([`KvqError`]) so servers can refuse bad configs at construction.
    pub fn new_quantized(
        n_layers: usize,
        slots: usize,
        capacity: usize,
        d_model: usize,
        n_heads: usize,
        plan: KvqPlan,
        rot_seed: u64,
    ) -> Result<KvCache, KvqError> {
        let store =
            QuantizedKvStore::new(n_layers, slots, capacity, d_model, n_heads, plan, rot_seed)?;
        Ok(KvCache {
            n_layers,
            slots,
            capacity,
            d_model,
            len: vec![0; slots],
            store: KvStore::Quantized(Box::new(store)),
            parked_scratch: None,
        })
    }

    /// Number of independent request slots.
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Maximum cached positions per slot (the model's context window).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Filled prefix length of `slot` (0 = empty / evicted).
    pub fn len(&self, slot: usize) -> usize {
        self.len[slot]
    }

    /// True when `slot` holds no context (fresh or evicted).
    pub fn is_empty(&self, slot: usize) -> bool {
        self.len[slot] == 0
    }

    /// True when `slot`'s window is full — the next token needs a
    /// window-slide re-prefill instead of [`NativeModel::decode_step`].
    pub fn is_full(&self, slot: usize) -> bool {
        self.len[slot] >= self.capacity
    }

    /// Evict `slot`: drop its cached context so the slot can host a new
    /// request. O(1) — rows are overwritten by the next prefill (the
    /// quantized packer clears recycled code bits on store).
    pub fn reset(&mut self, slot: usize) {
        self.len[slot] = 0;
    }

    /// True when rows live as packed RaBitQ codes rather than f32.
    pub fn is_quantized(&self) -> bool {
        matches!(self.store, KvStore::Quantized(_))
    }

    /// Mean stored bits per cached element: 32 for the dense store, the
    /// plan average for quantized codes (`/v1/stats` reports this).
    pub fn kv_bits(&self) -> f64 {
        match &self.store {
            KvStore::Dense { .. } => 32.0,
            KvStore::Quantized(q) => q.plan().avg_bits(),
        }
    }

    /// Per-lane (per-slot) footprint in bytes — the quantity a KV memory
    /// budget divides by to get a lane count.
    pub fn bytes_per_lane(&self) -> usize {
        match &self.store {
            KvStore::Dense { .. } => {
                crate::kvq::dense_bytes_per_lane(self.n_layers, self.capacity, self.d_model)
            }
            KvStore::Quantized(q) => q.bytes_per_lane(),
        }
    }

    /// Total buffer footprint in bytes (K + V payloads, plus rescale
    /// tables for the quantized store).
    pub fn mem_bytes(&self) -> usize {
        match &self.store {
            KvStore::Dense { k, v } => (k.len() + v.len()) * std::mem::size_of::<f32>(),
            KvStore::Quantized(q) => q.mem_bytes(),
        }
    }

    /// Flat offset of `(layer, slot)`'s first row (dense layout).
    fn base(&self, layer: usize, slot: usize) -> usize {
        (layer * self.slots + slot) * self.capacity * self.d_model
    }

    /// Store one K row and one V row at `pos` of `(layer, slot)` — copied
    /// verbatim (dense) or rotated + quantized + packed in place
    /// (quantized). Does not touch the slot length — callers commit via
    /// [`KvCache::set_len`] / [`KvCache::advance`] once every layer has
    /// stored its rows.
    pub(crate) fn store(&mut self, layer: usize, slot: usize, pos: usize, k: &[f32], v: &[f32]) {
        debug_assert!(pos < self.capacity && k.len() == self.d_model && v.len() == self.d_model);
        let at = self.base(layer, slot) + pos * self.d_model;
        match &mut self.store {
            KvStore::Dense { k: dk, v: dv } => {
                dk[at..at + self.d_model].copy_from_slice(k);
                dv[at..at + self.d_model].copy_from_slice(v);
            }
            KvStore::Quantized(q) => q.store_row(layer, slot, pos, k, v),
        }
    }

    /// The first `n` cached (K, V) rows of `(layer, slot)`, contiguous —
    /// the gather path [`crate::kernels::attend_cached`] consumes.
    /// **Dense store only**: quantized rows have no f32 representation to
    /// hand out (use [`KvCache::attend`]).
    pub(crate) fn window(&self, layer: usize, slot: usize, n: usize) -> (&[f32], &[f32]) {
        debug_assert!(n <= self.capacity);
        let at = self.base(layer, slot);
        let end = at + n * self.d_model;
        match &self.store {
            KvStore::Dense { k, v } => (&k[at..end], &v[at..end]),
            KvStore::Quantized(_) => {
                panic!("KvCache::window is dense-only; quantized rows are packed codes")
            }
        }
    }

    /// Fresh attention scratch sized for this cache's window (allocate
    /// once per batch loop; both backends then allocate nothing per
    /// query).
    pub fn attend_scratch(&self) -> KvAttendScratch {
        KvAttendScratch {
            scores: vec![0f32; self.capacity],
            q: match &self.store {
                KvStore::Dense { .. } => None,
                KvStore::Quantized(qs) => Some(qs.scratch()),
            },
        }
    }

    /// Recycled attention scratch: hands back the parked buffers (or a
    /// fresh set the first time) so the per-token decode path allocates
    /// nothing; return it with [`KvCache::put_scratch`] when the batch
    /// loop is done.
    pub(crate) fn take_scratch(&mut self) -> KvAttendScratch {
        match self.parked_scratch.take() {
            Some(s) => s,
            None => self.attend_scratch(),
        }
    }

    /// Park a scratch for the next [`KvCache::take_scratch`].
    pub(crate) fn put_scratch(&mut self, scratch: KvAttendScratch) {
        self.parked_scratch = Some(scratch);
    }

    /// Single-query attention over the first `ctx` cached rows of
    /// `(layer, slot)`, dispatched to the backend's kernel
    /// ([`crate::kernels::attend_cached`] on f32 rows,
    /// [`crate::kernels::attend_cached_q`] on codes). Accumulates into
    /// `out` — pass it zeroed, per the kernel contract. Both paths reduce
    /// each output row in a batch-size-independent order, so decode steps
    /// reproduce a same-backend prefill of the same context bit-for-bit.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn attend(
        &self,
        layer: usize,
        slot: usize,
        ctx: usize,
        q: &[f32],
        n_heads: usize,
        head_dim: usize,
        scratch: &mut KvAttendScratch,
        out: &mut [f32],
    ) {
        match &self.store {
            KvStore::Dense { .. } => {
                let (krows, vrows) = self.window(layer, slot, ctx);
                kernels::attend_cached(
                    q,
                    krows,
                    vrows,
                    ctx,
                    n_heads,
                    head_dim,
                    &mut scratch.scores,
                    out,
                );
            }
            KvStore::Quantized(qs) => {
                let qscratch = scratch.q.as_mut().expect("quantized scratch (attend_scratch)");
                qs.attend(layer, slot, ctx, q, qscratch, out);
            }
        }
    }

    /// Commit a prefilled prefix length.
    pub(crate) fn set_len(&mut self, slot: usize, n: usize) {
        debug_assert!(n <= self.capacity);
        self.len[slot] = n;
    }

    /// Advance a slot by the one position a decode step appended.
    pub(crate) fn advance(&mut self, slot: usize) {
        debug_assert!(self.len[slot] < self.capacity);
        self.len[slot] += 1;
    }

    /// Shape-check against a model: layer count, width, and window must
    /// match (`capacity <= seq_len`, or decode positions would index past
    /// the positional-embedding table); a quantized store's head split
    /// must match too (its rotation is per head).
    pub(crate) fn check_model(&self, model: &NativeModel) -> Result<()> {
        anyhow::ensure!(
            self.n_layers == model.n_layers && self.d_model == model.d_model,
            "cache shape (layers={}, d={}) != model (layers={}, d={})",
            self.n_layers,
            self.d_model,
            model.n_layers,
            model.d_model
        );
        anyhow::ensure!(
            self.capacity <= model.seq_len,
            "cache capacity {} exceeds model context {}",
            self.capacity,
            model.seq_len
        );
        if let KvStore::Quantized(q) = &self.store {
            anyhow::ensure!(
                q.n_heads() == model.n_heads,
                "quantized cache heads {} != model heads {}",
                q.n_heads(),
                model.n_heads
            );
        }
        Ok(())
    }
}

/// GPT-2-style parameter init mirroring `init_params` in
/// python/compile/model.py (different RNG stream than JAX, same law):
/// ones for LN scales, zeros for biases, N(0, std) elsewhere with
/// std = 0.02 for embeddings, 1/sqrt(fan_in) for projections, and the
/// GPT-2 depth scaling on residual-branch outputs.
pub fn native_init(m: &Manifest, seed: u64) -> ModelParams {
    let mut rng = Rng::new(seed);
    let mut tensors = Vec::with_capacity(m.params.len());
    for spec in &m.params {
        let n = spec.numel();
        let t = if spec.name.ends_with(".scale") {
            vec![1.0; n]
        } else if spec.name.ends_with(".bias") || spec.name.ends_with(".b") {
            vec![0.0; n]
        } else {
            let fan_in = if spec.shape.len() == 2 {
                spec.shape[0]
            } else {
                *spec.shape.last().unwrap_or(&1)
            };
            let mut std = if spec.name.contains("emb") {
                0.02
            } else {
                1.0 / (fan_in as f32).sqrt()
            };
            if spec.name.ends_with("attn.wo") || spec.name.ends_with("mlp.fc2") {
                std /= (2.0 * m.n_layers as f32).sqrt();
            }
            let mut v = rng.gaussian_vec(n);
            for x in v.iter_mut() {
                *x *= std;
            }
            v
        };
        tensors.push(t);
    }
    ModelParams { specs: m.params.clone(), tensors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_manifest;

    fn tiny_setup() -> (Manifest, NativeModel, ModelParams, Vec<i32>) {
        let m = synthetic_manifest("nat-test", 32, 2, 2, 64, 16, 256, 2);
        let model = NativeModel::new(&m).unwrap();
        let params = native_init(&m, 5);
        let tokens: Vec<i32> = (0..2 * 16).map(|i| (i * 7 % 256) as i32).collect();
        (m, model, params, tokens)
    }

    #[test]
    fn dense_forward_shapes_and_finite() {
        let (m, model, params, tokens) = tiny_setup();
        let logits = model.last_logits(&m, &params, None, &tokens, 2).unwrap();
        assert_eq!(logits.len(), 2 * 256);
        assert!(logits.iter().all(|x| x.is_finite()));
        let nll = model.token_nll(&m, &params, None, &tokens, 2).unwrap();
        assert_eq!(nll.len(), 2 * 15);
        assert!(nll.iter().all(|x| x.is_finite() && *x > 0.0));
        // untrained byte model: mean NLL near ln(256)
        let mean = nll.iter().sum::<f32>() / nll.len() as f32;
        assert!(mean > 2.0 && mean < 9.0, "mean nll {mean}");
    }

    #[test]
    fn forward_rejects_bad_batches() {
        let (m, model, params, _) = tiny_setup();
        assert!(model.last_logits(&m, &params, None, &[0i32; 17], 1).is_err());
        assert!(model.last_logits(&m, &params, None, &[], 1).is_err());
        assert!(model.last_logits(&m, &params, None, &[300i32; 16], 1).is_err());
    }

    #[test]
    fn forward_deterministic_across_thread_counts() {
        let (m, model, params, tokens) = tiny_setup();
        let a = model.last_logits(&m, &params, None, &tokens, 1).unwrap();
        let b = model.last_logits(&m, &params, None, &tokens, 8).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn packed_forward_matches_dense_reconstruction() {
        let (m, model, params, tokens) = tiny_setup();
        let nl = m.linears.len();
        let stats: Vec<LayerCalib> =
            m.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
        let bits = vec![8u8; nl];
        let packed = PackedLayers::quantize(
            &m, &params, &bits, &stats, &TrickConfig::none(), 11, 2,
        )
        .unwrap();

        // dense reference: fold each layer's reconstruction into params
        let mut dense = params.clone();
        for (ql, lin) in packed.layers.iter().zip(&m.linears) {
            let (w_hat, corr) = ql.reconstruct();
            dense.set_matrix(&lin.param, &w_hat).unwrap();
            let bias = dense.get_mut(&lin.bias).unwrap();
            for (b, c) in bias.iter_mut().zip(&corr) {
                *b += c;
            }
        }
        let got = model.last_logits(&m, &params, Some(&packed), &tokens, 2).unwrap();
        let want = model.last_logits(&m, &dense, None, &tokens, 2).unwrap();
        let num: f64 = got
            .iter()
            .zip(&want)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let den: f64 = want.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!(num / den < 0.05, "packed vs dense logits rel err {}", num / den);
    }

    #[test]
    fn packed_forward_deterministic_across_thread_counts() {
        let (m, model, params, tokens) = tiny_setup();
        let stats: Vec<LayerCalib> =
            m.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
        let bits = vec![4u8; m.linears.len()];
        let packed = PackedLayers::quantize(
            &m, &params, &bits, &stats, &TrickConfig::none(), 3, 1,
        )
        .unwrap();
        let a = model.last_logits(&m, &params, Some(&packed), &tokens, 1).unwrap();
        let b = model.last_logits(&m, &params, Some(&packed), &tokens, 8).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn capture_stats_cover_every_linear() {
        let (m, model, params, tokens) = tiny_setup();
        let stats = model.capture_layer_stats(&m, &params, &tokens, 2).unwrap();
        assert_eq!(stats.len(), m.linears.len());
        for (st, lin) in stats.iter().zip(&m.linears) {
            assert_eq!(st.mean_input.len(), lin.d);
            assert_eq!(st.col_norms.len(), lin.d);
            assert!(st.col_norms.iter().any(|&n| n > 0.0));
        }
    }

    #[test]
    fn native_init_follows_spec_rules() {
        let m = synthetic_manifest("init-test", 16, 1, 2, 32, 8, 64, 1);
        let p = native_init(&m, 1);
        assert!(p.get("blk0.ln1.scale").unwrap().iter().all(|&x| x == 1.0));
        assert!(p.get("blk0.attn.wq.b").unwrap().iter().all(|&x| x == 0.0));
        assert!(p.get("tok_emb").unwrap().iter().any(|&x| x != 0.0));
        // deterministic in the seed
        let q = native_init(&m, 1);
        assert_eq!(p.tensors, q.tensors);
        let r = native_init(&m, 2);
        assert_ne!(p.tensors, r.tensors);
    }

    #[test]
    fn embed_is_unit_norm_deterministic_and_length_sensitive() {
        let (m, model, params, _) = tiny_setup();
        let tokens: Vec<i32> = (0..9).map(|i| (i * 11 % 256) as i32).collect();
        let e = model.embed(&m, &params, None, &tokens, 2).unwrap();
        assert_eq!(e.len(), model.d_model);
        let norm: f64 = e.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5, "embedding must be L2-normalized, norm {norm}");
        // deterministic in the thread count
        let e8 = model.embed(&m, &params, None, &tokens, 8).unwrap();
        assert_eq!(e, e8);
        // a different context embeds differently
        let other = model.embed(&m, &params, None, &[1, 2, 3], 2).unwrap();
        assert_ne!(e, other);
        // bad contexts refuse cleanly
        assert!(model.embed(&m, &params, None, &[], 1).is_err());
        let long = vec![1i32; model.seq_len + 1];
        assert!(model.embed(&m, &params, None, &long, 1).is_err());
        assert!(model.embed(&m, &params, None, &[300], 1).is_err());
    }

    #[test]
    fn kv_cache_slot_lifecycle() {
        let mut kv = KvCache::new(2, 3, 4, 8);
        assert_eq!(kv.slots(), 3);
        assert_eq!(kv.capacity(), 4);
        assert!(kv.is_empty(1));
        assert!(!kv.is_full(1));
        kv.store(0, 1, 0, &[1.0; 8], &[2.0; 8]);
        kv.store(1, 1, 0, &[3.0; 8], &[4.0; 8]);
        kv.set_len(1, 1);
        assert_eq!(kv.len(1), 1);
        let (k, v) = kv.window(1, 1, 1);
        assert_eq!(k, &[3.0; 8]);
        assert_eq!(v, &[4.0; 8]);
        // other slots and layers untouched
        assert_eq!(kv.window(0, 0, 1).0, &[0.0; 8]);
        kv.advance(1);
        kv.advance(1);
        kv.advance(1);
        assert!(kv.is_full(1));
        kv.reset(1);
        assert!(kv.is_empty(1));
        assert_eq!(kv.mem_bytes(), 2 * 2 * 3 * 4 * 8 * 4);
    }

    #[test]
    fn prefill_matches_variable_length_recompute() {
        let (m, model, params, _) = tiny_setup();
        let prompt: Vec<i32> = (0..7).map(|i| (i * 13 % 256) as i32).collect();
        let mut cache = model.kv_cache(2);
        let got = model.prefill(&m, &params, None, &prompt, &mut cache, 1, 2).unwrap();
        let want = model.last_logits_ctx(&m, &params, None, &prompt, 2).unwrap();
        assert_eq!(got, want, "prefill logits must equal the recompute reference");
        assert_eq!(cache.len(1), 7);
        assert_eq!(cache.len(0), 0);
    }

    #[test]
    fn decode_steps_match_recompute_bit_exact_dense() {
        let (m, model, params, _) = tiny_setup();
        let mut cache = model.kv_cache(1);
        let mut ctx: Vec<i32> = vec![5, 9, 200];
        let mut logits = model.prefill(&m, &params, None, &ctx, &mut cache, 0, 2).unwrap();
        for step in 0..6 {
            // greedy next token from the incremental path
            let tok = crate::util::argmax(&logits) as i32;
            logits = model
                .decode_step(&m, &params, None, &mut cache, &[0], &[tok], 2)
                .unwrap();
            ctx.push(tok);
            let want = model.last_logits_ctx(&m, &params, None, &ctx, 2).unwrap();
            assert_eq!(logits, want, "step {step}: decode must be bit-exact");
        }
        assert_eq!(cache.len(0), ctx.len());
    }

    #[test]
    fn decode_step_rejects_bad_slots() {
        let (m, model, params, _) = tiny_setup();
        let mut cache = model.kv_cache(2);
        // not prefilled yet
        assert!(model
            .decode_step(&m, &params, None, &mut cache, &[0], &[1], 1)
            .is_err());
        model.prefill(&m, &params, None, &[1, 2], &mut cache, 0, 1).unwrap();
        // out-of-range and duplicate slots
        assert!(model
            .decode_step(&m, &params, None, &mut cache, &[5], &[1], 1)
            .is_err());
        assert!(model
            .decode_step(&m, &params, None, &mut cache, &[0, 0], &[1, 2], 1)
            .is_err());
        // arity mismatch
        assert!(model
            .decode_step(&m, &params, None, &mut cache, &[0], &[1, 2], 1)
            .is_err());
        // fill the window: further decode must demand a re-prefill
        let seq = model.seq_len;
        for t in 0..seq - 2 {
            model
                .decode_step(&m, &params, None, &mut cache, &[0], &[(t % 250) as i32], 1)
                .unwrap();
        }
        assert!(cache.is_full(0));
        assert!(model
            .decode_step(&m, &params, None, &mut cache, &[0], &[1], 1)
            .is_err());
    }

    #[test]
    fn prefill_rejects_oversized_and_empty_prompts() {
        let (m, model, params, _) = tiny_setup();
        let mut cache = model.kv_cache(1);
        assert!(model.prefill(&m, &params, None, &[], &mut cache, 0, 1).is_err());
        let long: Vec<i32> = vec![1; model.seq_len + 1];
        assert!(model.prefill(&m, &params, None, &long, &mut cache, 0, 1).is_err());
        assert!(model.prefill(&m, &params, None, &[1], &mut cache, 9, 1).is_err());
        // mismatched cache shape
        let mut wrong = KvCache::new(model.n_layers + 1, 1, model.seq_len, model.d_model);
        assert!(model.prefill(&m, &params, None, &[1], &mut wrong, 0, 1).is_err());
    }

    #[test]
    fn quantized_kv_decode_matches_quantized_prefill_bit_exact() {
        // quantize→pack is deterministic and every attend reduces in a
        // batch-size-independent order, so a decode step over a quantized
        // cache must equal re-prefilling the same context into a fresh
        // quantized cache — bit for bit, at any bit-width
        use crate::kvq::{KvqPlan, DEFAULT_ROT_SEED};
        let (m, model, params, _) = tiny_setup();
        for bits in [2u8, 4, 8] {
            let plan = KvqPlan::uniform(model.n_layers, bits).unwrap();
            let mut cache =
                model.kv_cache_quantized(1, plan.clone(), DEFAULT_ROT_SEED).unwrap();
            let mut ctx: Vec<i32> = vec![5, 9, 200];
            let mut logits =
                model.prefill(&m, &params, None, &ctx, &mut cache, 0, 2).unwrap();
            for step in 0..5 {
                let tok = crate::util::argmax(&logits) as i32;
                logits = model
                    .decode_step(&m, &params, None, &mut cache, &[0], &[tok], 2)
                    .unwrap();
                ctx.push(tok);
                let mut fresh =
                    model.kv_cache_quantized(1, plan.clone(), DEFAULT_ROT_SEED).unwrap();
                let want =
                    model.prefill(&m, &params, None, &ctx, &mut fresh, 0, 2).unwrap();
                assert_eq!(
                    logits, want,
                    "bits={bits} step {step}: quantized decode must equal quantized re-prefill"
                );
            }
        }
    }

    #[test]
    fn quantized_kv_drift_bounded_and_monotone_in_bits() {
        // bounded drift vs the f32 cache, shrinking with bits (the
        // serving-level quality ladder; the full greedy-agreement property
        // lives in rust/tests/integration.rs)
        use crate::kvq::{KvqPlan, DEFAULT_ROT_SEED};
        let (m, model, params, _) = tiny_setup();
        let prompt: Vec<i32> = (0..10).map(|i| (i * 13 % 256) as i32).collect();
        let mut dense = model.kv_cache(1);
        let exact = model.prefill(&m, &params, None, &prompt, &mut dense, 0, 2).unwrap();
        let norm: f64 = exact.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        let mut prev = f64::INFINITY;
        for bits in [2u8, 4, 8] {
            let plan = KvqPlan::uniform(model.n_layers, bits).unwrap();
            let mut cache = model.kv_cache_quantized(1, plan, DEFAULT_ROT_SEED).unwrap();
            let got = model.prefill(&m, &params, None, &prompt, &mut cache, 0, 2).unwrap();
            let err: f64 = got
                .iter()
                .zip(&exact)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
                / norm;
            assert!(err < prev, "bits={bits}: logit drift {err} !< {prev}");
            assert!(err.is_finite());
            prev = err;
        }
        assert!(prev < 0.05, "8-bit logit drift too large: {prev}");
    }

    #[test]
    fn quantized_cache_rejects_mismatched_models() {
        use crate::kvq::{KvqPlan, DEFAULT_ROT_SEED};
        let (m, model, params, _) = tiny_setup();
        // plan arity != layers is a typed construction error
        assert!(model
            .kv_cache_quantized(1, KvqPlan::uniform(model.n_layers + 1, 4).unwrap(), 1)
            .is_err());
        // head mismatch caught by check_model at prefill time
        let mut wrong = KvCache::new_quantized(
            model.n_layers,
            1,
            model.seq_len,
            model.d_model,
            model.n_heads * 2,
            KvqPlan::uniform(model.n_layers, 4).unwrap(),
            DEFAULT_ROT_SEED,
        )
        .unwrap();
        assert!(model.prefill(&m, &params, None, &[1, 2], &mut wrong, 0, 1).is_err());
    }

    #[test]
    fn quantized_cache_window_slide_reprefill_works() {
        use crate::kvq::{KvqPlan, DEFAULT_ROT_SEED};
        let (m, model, params, _) = tiny_setup();
        let plan = KvqPlan::uniform(model.n_layers, 4).unwrap();
        let mut cache = model.kv_cache_quantized(1, plan, DEFAULT_ROT_SEED).unwrap();
        let seq = model.seq_len;
        let mut ctx: Vec<i32> = (0..seq).map(|i| (i * 3 % 256) as i32).collect();
        let mut logits =
            model.prefill(&m, &params, None, &ctx, &mut cache, 0, 1).unwrap();
        assert!(cache.is_full(0));
        // slide twice: re-prefill the trailing window, then keep decoding
        for _ in 0..2 {
            let tok = crate::util::argmax(&logits) as i32;
            ctx.push(tok);
            let window = &ctx[ctx.len() - seq..];
            logits = model.prefill(&m, &params, None, window, &mut cache, 0, 1).unwrap();
            assert!(logits.iter().all(|x| x.is_finite()));
        }
        assert_eq!(cache.len(0), seq);
    }

    #[test]
    fn kv_cache_reports_storage_metrics() {
        use crate::kvq::{dense_bytes_per_lane, KvqPlan, DEFAULT_ROT_SEED};
        let (_, model, _, _) = tiny_setup();
        let dense = model.kv_cache(2);
        assert!(!dense.is_quantized());
        assert_eq!(dense.kv_bits(), 32.0);
        assert_eq!(
            dense.bytes_per_lane(),
            dense_bytes_per_lane(model.n_layers, model.seq_len, model.d_model)
        );
        let q = model
            .kv_cache_quantized(2, KvqPlan::uniform(model.n_layers, 4).unwrap(), DEFAULT_ROT_SEED)
            .unwrap();
        assert!(q.is_quantized());
        assert_eq!(q.kv_bits(), 4.0);
        // the whole point: >= 2x lanes per byte at 4-bit
        assert!(dense.bytes_per_lane() >= 2 * q.bytes_per_lane());
        assert!(q.mem_bytes() < dense.mem_bytes());
    }

    #[test]
    fn packed_avg_bits_sane() {
        let (m, _model, params, _tokens) = tiny_setup();
        let stats: Vec<LayerCalib> =
            m.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
        let bits = vec![3u8; m.linears.len()];
        let packed = PackedLayers::quantize(
            &m, &params, &bits, &stats, &TrickConfig::none(), 1, 1,
        )
        .unwrap();
        let avg = packed.avg_bits();
        assert!(avg > 3.0 && avg < 4.5, "avg bits {avg}");
    }
}
