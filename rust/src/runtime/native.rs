//! Native CPU backend: the GPT-style forward pass (python/compile/model.py)
//! implemented directly over the fused kernels, plus the packed-weight
//! serving state.
//!
//! Two weight representations drive the same forward:
//!
//! * **dense** — full-precision parameters out of [`ModelParams`], linear
//!   layers via [`crate::kernels::gemm`];
//! * **packed** ([`PackedLayers`]) — every registered linear held as a
//!   RaBitQ-H [`QuantizedLinear`] (bit-packed codes + RHT signs + outlier
//!   rows), applied via [`crate::kernels::qgemm`] with **zero full-matrix
//!   dequantization per forward** — the request path computes on codes.
//!
//! The architecture mirrors `python/compile/model.py` exactly (pre-LN
//! blocks, causal attention, tanh-approximate GELU, weight-tied nothing,
//! fp lm_head), so when the PJRT artifacts are available the two backends
//! are interchangeable; when they are not (offline vendor stub), this is
//! the serving path.

use anyhow::{Context, Result};

use crate::kernels;
use crate::model::{Manifest, ModelParams};
use crate::quant::{LayerCalib, QuantizedLinear, TrickConfig};
use crate::rng::Rng;
use crate::tensor::Matrix;

/// Validated model dimensions for the native forward.
#[derive(Clone, Debug)]
pub struct NativeModel {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub vocab: usize,
}

impl NativeModel {
    pub fn new(m: &Manifest) -> Result<Self> {
        anyhow::ensure!(m.n_heads > 0 && m.d_model % m.n_heads == 0, "d_model % n_heads != 0");
        anyhow::ensure!(m.seq_len >= 2, "seq_len must be >= 2");
        Ok(NativeModel {
            d_model: m.d_model,
            n_layers: m.n_layers,
            n_heads: m.n_heads,
            head_dim: m.d_model / m.n_heads,
            d_ff: m.d_ff,
            seq_len: m.seq_len,
            vocab: m.vocab,
        })
    }

    /// Last-position logits, (B, vocab) row-major. `tokens` is any whole
    /// number of sequences (B*S); the artifact path's fixed eval_batch
    /// does not bind here.
    pub fn last_logits(
        &self,
        m: &Manifest,
        params: &ModelParams,
        packed: Option<&PackedLayers>,
        tokens: &[i32],
        threads: usize,
    ) -> Result<Vec<f32>> {
        let hid = self.forward_hidden(m, params, packed, tokens, threads, None)?;
        let (s, d, v) = (self.seq_len, self.d_model, self.vocab);
        let b = hid.rows / s;
        let lm = params.get("lm_head")?;
        let mut last = Matrix::zeros(b, d);
        for bi in 0..b {
            last.row_mut(bi).copy_from_slice(hid.row(bi * s + s - 1));
        }
        let mut out = Matrix::zeros(b, v);
        kernels::gemm(b, d, v, &last.data, lm, &mut out.data, threads);
        Ok(out.data)
    }

    /// Per-token next-token NLL, (B, S-1) row-major — matches the
    /// `fwd_loss` artifact's output layout.
    pub fn token_nll(
        &self,
        m: &Manifest,
        params: &ModelParams,
        packed: Option<&PackedLayers>,
        tokens: &[i32],
        threads: usize,
    ) -> Result<Vec<f32>> {
        let hid = self.forward_hidden(m, params, packed, tokens, threads, None)?;
        let (s, d, v) = (self.seq_len, self.d_model, self.vocab);
        let b = hid.rows / s;
        let lm = params.get("lm_head")?;
        let mut logits = Matrix::zeros(b * s, v);
        kernels::gemm(b * s, d, v, &hid.data, lm, &mut logits.data, threads);
        let mut nll = Vec::with_capacity(b * (s - 1));
        for bi in 0..b {
            for t in 0..s - 1 {
                let row = logits.row(bi * s + t);
                let tgt = tokens[bi * s + t + 1] as usize;
                let maxl = row.iter().fold(f32::NEG_INFINITY, |mx, &x| mx.max(x));
                let lse = maxl
                    + row
                        .iter()
                        .map(|&x| ((x - maxl) as f64).exp())
                        .sum::<f64>()
                        .ln() as f32;
                nll.push(lse - row[tgt]);
            }
        }
        Ok(nll)
    }

    /// Run a forward capturing each registered linear layer's input
    /// statistics (calibration without the PJRT `calib_capture` artifact).
    /// Stats are reduced in place per capture point — no activation matrix
    /// is retained. Returns per-layer stats in manifest linear order.
    pub fn capture_layer_stats(
        &self,
        m: &Manifest,
        params: &ModelParams,
        tokens: &[i32],
        threads: usize,
    ) -> Result<Vec<LayerCalib>> {
        let mut captures: Vec<LayerCalib> = Vec::with_capacity(m.linears.len());
        let _ = self.forward_hidden(m, params, None, tokens, threads, Some(&mut captures))?;
        anyhow::ensure!(captures.len() == m.linears.len(), "capture arity");
        Ok(captures)
    }

    /// Full forward through every block and the final LayerNorm; returns
    /// the (B*S, d_model) hidden states ready for the lm_head projection.
    fn forward_hidden(
        &self,
        m: &Manifest,
        params: &ModelParams,
        packed: Option<&PackedLayers>,
        tokens: &[i32],
        threads: usize,
        mut capture: Option<&mut Vec<LayerCalib>>,
    ) -> Result<Matrix> {
        let (s, d) = (self.seq_len, self.d_model);
        anyhow::ensure!(
            !tokens.is_empty() && tokens.len() % s == 0,
            "token batch must be a whole number of seq_len={s} sequences"
        );
        let b = tokens.len() / s;
        if let Some(p) = packed {
            anyhow::ensure!(p.layers.len() == m.linears.len(), "packed layer arity");
        }

        // embeddings
        let tok_emb = params.get("tok_emb")?;
        let pos_emb = params.get("pos_emb")?;
        let mut h = Matrix::zeros(b * s, d);
        for bi in 0..b {
            for si in 0..s {
                let t = tokens[bi * s + si];
                anyhow::ensure!(
                    t >= 0 && (t as usize) < self.vocab,
                    "token {t} out of vocab range"
                );
                let te = &tok_emb[(t as usize) * d..(t as usize + 1) * d];
                let pe = &pos_emb[si * d..(si + 1) * d];
                let row = h.row_mut(bi * s + si);
                for ((o, &a), &p) in row.iter_mut().zip(te).zip(pe) {
                    *o = a + p;
                }
            }
        }

        for layer in 0..self.n_layers {
            let pre = format!("blk{layer}.");

            // attention sub-block (pre-LN)
            let x = layer_norm(
                &h,
                params.get(&format!("{pre}ln1.scale"))?,
                params.get(&format!("{pre}ln1.bias"))?,
            );
            let lin = |nm: &str, inp: &Matrix, cap: Option<&mut Vec<LayerCalib>>| {
                self.linear(m, params, packed, &format!("{pre}{nm}"), inp, threads, cap)
            };
            let q = lin("attn.wq", &x, capture.as_deref_mut())?;
            let k = lin("attn.wk", &x, capture.as_deref_mut())?;
            let v = lin("attn.wv", &x, capture.as_deref_mut())?;
            let att = self.attention(&q, &k, &v);
            let proj = lin("attn.wo", &att, capture.as_deref_mut())?;
            h.add_assign(&proj);

            // MLP sub-block (pre-LN)
            let x = layer_norm(
                &h,
                params.get(&format!("{pre}ln2.scale"))?,
                params.get(&format!("{pre}ln2.bias"))?,
            );
            let lin = |nm: &str, inp: &Matrix, cap: Option<&mut Vec<LayerCalib>>| {
                self.linear(m, params, packed, &format!("{pre}{nm}"), inp, threads, cap)
            };
            let mut y = lin("mlp.fc1", &x, capture.as_deref_mut())?;
            for v in y.data.iter_mut() {
                *v = gelu(*v);
            }
            let y = lin("mlp.fc2", &y, capture.as_deref_mut())?;
            h.add_assign(&y);
        }

        Ok(layer_norm(&h, params.get("ln_f.scale")?, params.get("ln_f.bias")?))
    }

    /// One registered linear layer: packed (qgemm on codes) or dense
    /// (full-precision gemm), plus the layer bias. `capture`, when set,
    /// receives the layer input (forward order = manifest linear order).
    #[allow(clippy::too_many_arguments)]
    fn linear(
        &self,
        m: &Manifest,
        params: &ModelParams,
        packed: Option<&PackedLayers>,
        name: &str,
        x: &Matrix,
        threads: usize,
        capture: Option<&mut Vec<LayerCalib>>,
    ) -> Result<Matrix> {
        let k = m
            .linears
            .iter()
            .position(|l| l.param == name)
            .with_context(|| format!("linear '{name}' not registered in manifest"))?;
        let lin = &m.linears[k];
        anyhow::ensure!(x.cols == lin.d, "linear '{name}' input dim");
        if let Some(c) = capture {
            c.push(LayerCalib::from_activations(x));
        }
        let mut y = match packed {
            Some(p) => p.layers[k].forward_est_threaded(x, threads),
            None => {
                let w = params.get(&lin.param)?;
                let mut out = Matrix::zeros(x.rows, lin.c);
                kernels::gemm(x.rows, lin.d, lin.c, &x.data, w, &mut out.data, threads);
                out
            }
        };
        let bias = params.get(&lin.bias)?;
        for i in 0..y.rows {
            for (o, &bv) in y.row_mut(i).iter_mut().zip(bias) {
                *o += bv;
            }
        }
        Ok(y)
    }

    /// Causal multi-head attention over (B*S, d) q/k/v; returns (B*S, d).
    fn attention(&self, q: &Matrix, k: &Matrix, v: &Matrix) -> Matrix {
        let (s, hn, hd) = (self.seq_len, self.n_heads, self.head_dim);
        let b = q.rows / s;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut o = Matrix::zeros(q.rows, self.d_model);
        let mut scores = vec![0f32; s];
        for bi in 0..b {
            for head in 0..hn {
                let hoff = head * hd;
                for qi in 0..s {
                    let qrow = &q.row(bi * s + qi)[hoff..hoff + hd];
                    let mut maxs = f32::NEG_INFINITY;
                    for (ki, sc) in scores[..=qi].iter_mut().enumerate() {
                        let krow = &k.row(bi * s + ki)[hoff..hoff + hd];
                        let mut dp = 0f32;
                        for t in 0..hd {
                            dp += qrow[t] * krow[t];
                        }
                        *sc = dp * scale;
                        maxs = maxs.max(*sc);
                    }
                    let mut denom = 0f32;
                    for sc in scores[..=qi].iter_mut() {
                        *sc = (*sc - maxs).exp();
                        denom += *sc;
                    }
                    let inv = 1.0 / denom;
                    let orow = &mut o.row_mut(bi * s + qi)[hoff..hoff + hd];
                    for (ki, &sc) in scores[..=qi].iter().enumerate() {
                        let w = sc * inv;
                        let vrow = &v.row(bi * s + ki)[hoff..hoff + hd];
                        for (ov, &vv) in orow.iter_mut().zip(vrow) {
                            *ov += w * vv;
                        }
                    }
                }
            }
        }
        o
    }
}

/// Per-token LayerNorm (population variance, eps 1e-5 — matches
/// `_layer_norm` in python/compile/model.py).
fn layer_norm(h: &Matrix, scale: &[f32], bias: &[f32]) -> Matrix {
    let d = h.cols;
    let mut out = Matrix::zeros(h.rows, d);
    for i in 0..h.rows {
        let row = h.row(i);
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&x| (x - mean) * (x - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let orow = out.row_mut(i);
        for (j, o) in orow.iter_mut().enumerate() {
            *o = (row[j] - mean) * inv * scale[j] + bias[j];
        }
    }
    out
}

/// Tanh-approximate GELU (jax.nn.gelu's default).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

// ----------------------------------------------------------- packed layers

/// Resident packed weights for serving: every registered linear layer as a
/// [`QuantizedLinear`], in manifest linear order. This is what
/// `ModelRuntime` keeps hot so `fwd_logits` computes on codes.
#[derive(Clone, Debug)]
pub struct PackedLayers {
    pub layers: Vec<QuantizedLinear>,
}

impl PackedLayers {
    /// Quantize every registered linear of `params` at the per-layer
    /// bit-widths (AllocateBits output order). `stats` supplies the
    /// calibration statistics per layer (use [`LayerCalib::zeros`] for the
    /// calibration-free path).
    pub fn quantize(
        m: &Manifest,
        params: &ModelParams,
        bits: &[u8],
        stats: &[LayerCalib],
        tricks: &TrickConfig,
        seed: u64,
        threads: usize,
    ) -> Result<PackedLayers> {
        anyhow::ensure!(bits.len() == m.linears.len(), "bits/linears arity");
        anyhow::ensure!(stats.len() == m.linears.len(), "stats/linears arity");
        let mut rng = Rng::new(seed);
        let mut layers = Vec::with_capacity(m.linears.len());
        for (k, lin) in m.linears.iter().enumerate() {
            let w = params.matrix(&lin.param)?;
            layers.push(QuantizedLinear::quantize(
                &lin.name, &w, bits[k], &stats[k], tricks, &mut rng, threads,
            )?);
        }
        Ok(PackedLayers { layers })
    }

    /// Total stored payload bits across all layers.
    pub fn stored_bits(&self) -> usize {
        self.layers.iter().map(|l| l.stored_bits()).sum()
    }

    /// Average stored bits per quantizable parameter.
    pub fn avg_bits(&self) -> f64 {
        let m: usize = self.layers.iter().map(|l| l.d * l.c).sum();
        if m == 0 {
            return 0.0;
        }
        self.stored_bits() as f64 / m as f64
    }
}

/// GPT-2-style parameter init mirroring `init_params` in
/// python/compile/model.py (different RNG stream than JAX, same law):
/// ones for LN scales, zeros for biases, N(0, std) elsewhere with
/// std = 0.02 for embeddings, 1/sqrt(fan_in) for projections, and the
/// GPT-2 depth scaling on residual-branch outputs.
pub fn native_init(m: &Manifest, seed: u64) -> ModelParams {
    let mut rng = Rng::new(seed);
    let mut tensors = Vec::with_capacity(m.params.len());
    for spec in &m.params {
        let n = spec.numel();
        let t = if spec.name.ends_with(".scale") {
            vec![1.0; n]
        } else if spec.name.ends_with(".bias") || spec.name.ends_with(".b") {
            vec![0.0; n]
        } else {
            let fan_in = if spec.shape.len() == 2 {
                spec.shape[0]
            } else {
                *spec.shape.last().unwrap_or(&1)
            };
            let mut std = if spec.name.contains("emb") {
                0.02
            } else {
                1.0 / (fan_in as f32).sqrt()
            };
            if spec.name.ends_with("attn.wo") || spec.name.ends_with("mlp.fc2") {
                std /= (2.0 * m.n_layers as f32).sqrt();
            }
            let mut v = rng.gaussian_vec(n);
            for x in v.iter_mut() {
                *x *= std;
            }
            v
        };
        tensors.push(t);
    }
    ModelParams { specs: m.params.clone(), tensors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_manifest;

    fn tiny_setup() -> (Manifest, NativeModel, ModelParams, Vec<i32>) {
        let m = synthetic_manifest("nat-test", 32, 2, 2, 64, 16, 256, 2);
        let model = NativeModel::new(&m).unwrap();
        let params = native_init(&m, 5);
        let tokens: Vec<i32> = (0..2 * 16).map(|i| (i * 7 % 256) as i32).collect();
        (m, model, params, tokens)
    }

    #[test]
    fn dense_forward_shapes_and_finite() {
        let (m, model, params, tokens) = tiny_setup();
        let logits = model.last_logits(&m, &params, None, &tokens, 2).unwrap();
        assert_eq!(logits.len(), 2 * 256);
        assert!(logits.iter().all(|x| x.is_finite()));
        let nll = model.token_nll(&m, &params, None, &tokens, 2).unwrap();
        assert_eq!(nll.len(), 2 * 15);
        assert!(nll.iter().all(|x| x.is_finite() && *x > 0.0));
        // untrained byte model: mean NLL near ln(256)
        let mean = nll.iter().sum::<f32>() / nll.len() as f32;
        assert!(mean > 2.0 && mean < 9.0, "mean nll {mean}");
    }

    #[test]
    fn forward_rejects_bad_batches() {
        let (m, model, params, _) = tiny_setup();
        assert!(model.last_logits(&m, &params, None, &[0i32; 17], 1).is_err());
        assert!(model.last_logits(&m, &params, None, &[], 1).is_err());
        assert!(model.last_logits(&m, &params, None, &[300i32; 16], 1).is_err());
    }

    #[test]
    fn forward_deterministic_across_thread_counts() {
        let (m, model, params, tokens) = tiny_setup();
        let a = model.last_logits(&m, &params, None, &tokens, 1).unwrap();
        let b = model.last_logits(&m, &params, None, &tokens, 8).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn packed_forward_matches_dense_reconstruction() {
        let (m, model, params, tokens) = tiny_setup();
        let nl = m.linears.len();
        let stats: Vec<LayerCalib> =
            m.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
        let bits = vec![8u8; nl];
        let packed = PackedLayers::quantize(
            &m, &params, &bits, &stats, &TrickConfig::none(), 11, 2,
        )
        .unwrap();

        // dense reference: fold each layer's reconstruction into params
        let mut dense = params.clone();
        for (ql, lin) in packed.layers.iter().zip(&m.linears) {
            let (w_hat, corr) = ql.reconstruct();
            dense.set_matrix(&lin.param, &w_hat).unwrap();
            let bias = dense.get_mut(&lin.bias).unwrap();
            for (b, c) in bias.iter_mut().zip(&corr) {
                *b += c;
            }
        }
        let got = model.last_logits(&m, &params, Some(&packed), &tokens, 2).unwrap();
        let want = model.last_logits(&m, &dense, None, &tokens, 2).unwrap();
        let num: f64 = got
            .iter()
            .zip(&want)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let den: f64 = want.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!(num / den < 0.05, "packed vs dense logits rel err {}", num / den);
    }

    #[test]
    fn packed_forward_deterministic_across_thread_counts() {
        let (m, model, params, tokens) = tiny_setup();
        let stats: Vec<LayerCalib> =
            m.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
        let bits = vec![4u8; m.linears.len()];
        let packed = PackedLayers::quantize(
            &m, &params, &bits, &stats, &TrickConfig::none(), 3, 1,
        )
        .unwrap();
        let a = model.last_logits(&m, &params, Some(&packed), &tokens, 1).unwrap();
        let b = model.last_logits(&m, &params, Some(&packed), &tokens, 8).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn capture_stats_cover_every_linear() {
        let (m, model, params, tokens) = tiny_setup();
        let stats = model.capture_layer_stats(&m, &params, &tokens, 2).unwrap();
        assert_eq!(stats.len(), m.linears.len());
        for (st, lin) in stats.iter().zip(&m.linears) {
            assert_eq!(st.mean_input.len(), lin.d);
            assert_eq!(st.col_norms.len(), lin.d);
            assert!(st.col_norms.iter().any(|&n| n > 0.0));
        }
    }

    #[test]
    fn native_init_follows_spec_rules() {
        let m = synthetic_manifest("init-test", 16, 1, 2, 32, 8, 64, 1);
        let p = native_init(&m, 1);
        assert!(p.get("blk0.ln1.scale").unwrap().iter().all(|&x| x == 1.0));
        assert!(p.get("blk0.attn.wq.b").unwrap().iter().all(|&x| x == 0.0));
        assert!(p.get("tok_emb").unwrap().iter().any(|&x| x != 0.0));
        // deterministic in the seed
        let q = native_init(&m, 1);
        assert_eq!(p.tensors, q.tensors);
        let r = native_init(&m, 2);
        assert_ne!(p.tensors, r.tensors);
    }

    #[test]
    fn packed_avg_bits_sane() {
        let (m, _model, params, _tokens) = tiny_setup();
        let stats: Vec<LayerCalib> =
            m.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
        let bits = vec![3u8; m.linears.len()];
        let packed = PackedLayers::quantize(
            &m, &params, &bits, &stats, &TrickConfig::none(), 1, 1,
        )
        .unwrap();
        let avg = packed.avg_bits();
        assert!(avg > 3.0 && avg < 4.5, "avg bits {avg}");
    }
}
