//! Model runtime: AOT PJRT artifacts when available, native CPU kernels
//! always — and resident packed weights for the serving path.
//!
//! The PJRT half wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. HLO **text**
//! is the interchange format (jax ≥ 0.5 serialized protos are rejected by
//! xla_extension 0.5.1 — see DESIGN.md). All entry points were lowered
//! with `return_tuple=True`, so outputs arrive as a single tuple literal
//! that we decompose.
//!
//! The native half ([`native`]) runs the same transformer forward on the
//! fused CPU kernels ([`crate::kernels`]). [`ModelRuntime`] dispatches:
//! when packed weights are attached ([`ModelRuntime::attach_packed`]),
//! `fwd_logits`/`fwd_loss` compute **directly on RaBitQ codes** via
//! `qgemm` — zero full-matrix dequantization on the request path; else
//! PJRT artifacts are used when loaded, and the dense native forward
//! otherwise.

pub mod native;

use std::path::Path;

use anyhow::{Context, Result};

pub use native::{native_init, KvAttendScratch, KvCache, NativeModel, PackedLayers};

use crate::model::{ArtifactPaths, Manifest, ModelParams};

/// Shared PJRT client (CPU).
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Artifact> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Artifact {
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            exe,
        })
    }
}

/// A compiled executable artifact.
pub struct Artifact {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact '{}'", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching output literal")?;
        lit.to_tuple().context("decomposing output tuple")
    }
}

// ------------------------------------------------------------ literal glue

/// Build an f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "lit_f32 shape/data mismatch");
    let flat = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
    flat.reshape(&dims).context("reshaping f32 literal")
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "lit_i32 shape/data mismatch");
    let flat = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
    flat.reshape(&dims).context("reshaping i32 literal")
}

pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("extracting f32 literal")
}

/// Extract a scalar f32.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>().context("extracting f32 literal")?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
    Ok(v[0])
}

// ------------------------------------------------------- model-level glue

/// The six PJRT-compiled entry points of a model.
pub struct PjrtEntries {
    pub init_params: Artifact,
    pub train_step: Artifact,
    pub fwd_loss: Artifact,
    pub fwd_logits: Artifact,
    pub calib_grads: Artifact,
    pub calib_capture: Artifact,
}

/// A loaded model: manifest + backends.
///
/// * `pjrt` — the AOT entry points (None on the artifact-free native
///   backend; training and gradient calibration require them).
/// * `native_model` — the kernel-backed CPU forward, always available.
/// * packed weights — when attached, `fwd_logits` / `fwd_loss` serve
///   straight from bit-packed codes via [`crate::kernels::qgemm`]; the
///   dense parameters' linear weights are never touched on that path.
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub pjrt: Option<PjrtEntries>,
    pub native_model: NativeModel,
    packed: Option<PackedLayers>,
}

impl ModelRuntime {
    /// Load every PJRT entry point for `model` from the artifacts root.
    pub fn load(rt: &Runtime, root: &Path, model: &str) -> Result<Self> {
        let paths = ArtifactPaths::new(root, model);
        let manifest = Manifest::load(&paths.dir)
            .with_context(|| format!("run `make artifacts` first (model {model})"))?;
        let pjrt = PjrtEntries {
            init_params: rt.load(&paths.hlo("init_params"))?,
            train_step: rt.load(&paths.hlo("train_step"))?,
            fwd_loss: rt.load(&paths.hlo("fwd_loss"))?,
            fwd_logits: rt.load(&paths.hlo("fwd_logits"))?,
            calib_grads: rt.load(&paths.hlo("calib_grads"))?,
            calib_capture: rt.load(&paths.hlo("calib_capture"))?,
        };
        let native_model = NativeModel::new(&manifest)?;
        Ok(ModelRuntime { manifest, pjrt: Some(pjrt), native_model, packed: None })
    }

    /// Artifact-free runtime over the native CPU backend.
    pub fn native(manifest: Manifest) -> Result<Self> {
        let native_model = NativeModel::new(&manifest)?;
        Ok(ModelRuntime { manifest, pjrt: None, native_model, packed: None })
    }

    /// Keep packed (RaBitQ-coded) weights resident; subsequent forwards
    /// compute on codes. Layers must match the manifest's linear registry.
    pub fn attach_packed(&mut self, packed: PackedLayers) -> Result<()> {
        anyhow::ensure!(
            packed.layers.len() == self.manifest.linears.len(),
            "packed layer count {} != {} registered linears",
            packed.layers.len(),
            self.manifest.linears.len()
        );
        for (ql, lin) in packed.layers.iter().zip(&self.manifest.linears) {
            anyhow::ensure!(
                ql.d == lin.d && ql.c == lin.c,
                "packed layer '{}' shape {}x{} != manifest {}x{}",
                ql.name,
                ql.d,
                ql.c,
                lin.d,
                lin.c
            );
        }
        self.packed = Some(packed);
        Ok(())
    }

    /// Drop the resident packed weights (back to dense/PJRT dispatch).
    pub fn detach_packed(&mut self) -> Option<PackedLayers> {
        self.packed.take()
    }

    /// Resident packed weights, if attached.
    pub fn packed(&self) -> Option<&PackedLayers> {
        self.packed.as_ref()
    }

    fn entries(&self) -> Result<&PjrtEntries> {
        self.pjrt
            .as_ref()
            .context("PJRT artifacts not loaded (native backend); this path needs `make artifacts`")
    }

    /// The AOT training step (PJRT only).
    pub fn train_step_art(&self) -> Result<&Artifact> {
        Ok(&self.entries()?.train_step)
    }

    /// The AOT calibration-gradient entry point (PJRT only).
    pub fn calib_grads_art(&self) -> Result<&Artifact> {
        Ok(&self.entries()?.calib_grads)
    }

    /// The AOT activation-capture entry point (PJRT only).
    pub fn calib_capture_art(&self) -> Result<&Artifact> {
        Ok(&self.entries()?.calib_capture)
    }

    /// Initialize parameters: AOT init artifact when loaded, otherwise the
    /// native GPT-2-style init (same law, different RNG stream).
    pub fn init(&self, seed: i32) -> Result<ModelParams> {
        let entries = match &self.pjrt {
            Some(e) => e,
            None => return Ok(native_init(&self.manifest, seed as u64)),
        };
        let outs = entries.init_params.run(&[lit_scalar_i32(seed)])?;
        anyhow::ensure!(
            outs.len() == self.manifest.params.len(),
            "init output arity {} != {}",
            outs.len(),
            self.manifest.params.len()
        );
        let tensors = outs
            .iter()
            .map(to_vec_f32)
            .collect::<Result<Vec<_>>>()?;
        ModelParams::from_tensors(&self.manifest, tensors)
    }

    /// Literal list for the current params (shared prefix of PJRT calls).
    pub fn param_literals(&self, params: &ModelParams) -> Result<Vec<xla::Literal>> {
        params
            .specs
            .iter()
            .zip(&params.tensors)
            .map(|(spec, t)| lit_f32(t, &spec.shape))
            .collect()
    }

    /// Per-token negative log likelihood for a (B, S) token batch.
    ///
    /// Packed weights resident → native forward on codes; else the AOT
    /// `fwd_loss` artifact (fixed eval_batch); else dense native forward.
    pub fn token_nll(&self, params: &ModelParams, tokens: &[i32]) -> Result<Vec<f32>> {
        if self.packed.is_some() || self.pjrt.is_none() {
            return self.native_model.token_nll(
                &self.manifest,
                params,
                self.packed.as_ref(),
                tokens,
                0,
            );
        }
        let m = &self.manifest;
        anyhow::ensure!(
            tokens.len() == m.eval_batch * m.seq_len,
            "token batch must be eval_batch x seq_len"
        );
        let mut inputs = self.param_literals(params)?;
        inputs.push(lit_i32(tokens, &[m.eval_batch, m.seq_len])?);
        let outs = self.entries()?.fwd_loss.run(&inputs)?;
        to_vec_f32(&outs[0])
    }

    /// Last-position logits for a (B, S) token batch -> (B, vocab).
    ///
    /// The serving hot path: with packed weights resident this runs the
    /// native forward whose linear layers call `qgemm` on bit-packed
    /// codes — no dense weight is read and nothing is dequantized.
    pub fn last_logits(&self, params: &ModelParams, tokens: &[i32]) -> Result<Vec<f32>> {
        if self.packed.is_some() || self.pjrt.is_none() {
            return self.native_model.last_logits(
                &self.manifest,
                params,
                self.packed.as_ref(),
                tokens,
                0,
            );
        }
        let m = &self.manifest;
        let mut inputs = self.param_literals(params)?;
        inputs.push(lit_i32(tokens, &[m.eval_batch, m.seq_len])?);
        let outs = self.entries()?.fwd_logits.run(&inputs)?;
        to_vec_f32(&outs[0])
    }

    // --------------------------------------------- KV-cached generation

    /// Allocate a [`KvCache`] for this model: `slots` request lanes, each
    /// with `capacity = seq_len` positions per layer. One cache is meant
    /// to live as long as the runtime and be recycled across requests.
    ///
    /// Incremental decoding always runs on the native backend (packed
    /// codes when attached, dense otherwise) — the AOT artifacts have no
    /// incremental entry point.
    ///
    /// # Examples
    ///
    /// ```
    /// use raana::model::synthetic_manifest;
    /// use raana::runtime::ModelRuntime;
    ///
    /// let m = synthetic_manifest("kv-doc", 32, 1, 2, 64, 8, 256, 1);
    /// let mrt = ModelRuntime::native(m).unwrap();
    /// let params = mrt.init(1).unwrap();
    /// let mut cache = mrt.new_kv_cache(1);
    /// // run the prompt once, then extend one token per decode step
    /// let logits = mrt.prefill(&params, &mut cache, 0, &[10, 11, 12]).unwrap();
    /// assert_eq!(logits.len(), 256);
    /// let next = mrt.decode_step(&params, &mut cache, &[0], &[13]).unwrap();
    /// assert_eq!(next.len(), 256);
    /// assert_eq!(cache.len(0), 4);
    /// ```
    pub fn new_kv_cache(&self, slots: usize) -> KvCache {
        self.native_model.kv_cache(slots)
    }

    /// [`ModelRuntime::new_kv_cache`] with **quantized** row storage: K/V
    /// rows live as packed RaBitQ codes under the per-layer bit `plan` and
    /// attention runs directly over the codes (see [`crate::kvq`]).
    /// Construction errors are typed so servers can refuse bad KV configs
    /// up front.
    pub fn new_kv_cache_quantized(
        &self,
        slots: usize,
        plan: crate::kvq::KvqPlan,
        rot_seed: u64,
    ) -> Result<KvCache, crate::kvq::KvqError> {
        self.native_model.kv_cache_quantized(slots, plan, rot_seed)
    }

    /// Run a prompt once, filling cache `slot`; returns last-token logits
    /// `(vocab,)`. See [`NativeModel::prefill`].
    pub fn prefill(
        &self,
        params: &ModelParams,
        cache: &mut KvCache,
        slot: usize,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        self.native_model.prefill(
            &self.manifest,
            params,
            self.packed.as_ref(),
            tokens,
            cache,
            slot,
            0,
        )
    }

    /// One batched KV-cached generation step over `slots`; returns
    /// `(slots.len() * vocab)` row-major logits and advances each slot.
    /// See [`NativeModel::decode_step`].
    pub fn decode_step(
        &self,
        params: &ModelParams,
        cache: &mut KvCache,
        slots: &[usize],
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        self.native_model.decode_step(
            &self.manifest,
            params,
            self.packed.as_ref(),
            cache,
            slots,
            tokens,
            0,
        )
    }

    /// Embed one variable-length token sequence: mean-pooled,
    /// L2-normalized final hidden states — the retrieval subsystem's
    /// representation. Runs on the native backend (packed codes when
    /// attached). Contexts beyond `seq_len` are an **error** at this
    /// level; the serving layer
    /// ([`crate::serve::index::IndexServer::embed`]) truncates to the
    /// model window before calling. See [`NativeModel::embed`].
    ///
    /// # Examples
    ///
    /// ```
    /// use raana::model::synthetic_manifest;
    /// use raana::runtime::ModelRuntime;
    ///
    /// let m = synthetic_manifest("embed-doc", 32, 1, 2, 64, 8, 256, 1);
    /// let mrt = ModelRuntime::native(m).unwrap();
    /// let params = mrt.init(1).unwrap();
    /// let e = mrt.embed(&params, &[10, 11, 12]).unwrap();
    /// assert_eq!(e.len(), 32);
    /// let norm: f32 = e.iter().map(|x| x * x).sum::<f32>().sqrt();
    /// assert!((norm - 1.0).abs() < 1e-4); // unit-norm by contract
    /// assert!(mrt.embed(&params, &[0; 9]).is_err()); // beyond seq_len
    /// ```
    pub fn embed(&self, params: &ModelParams, tokens: &[i32]) -> Result<Vec<f32>> {
        self.native_model.embed(
            &self.manifest,
            params,
            self.packed.as_ref(),
            tokens,
            0,
        )
    }

    /// Full-recompute last-token logits for one variable-length context —
    /// the reference the KV path is bit-identical to, and the per-token
    /// cost recompute serving pays. See [`NativeModel::last_logits_ctx`].
    pub fn last_logits_ctx(&self, params: &ModelParams, tokens: &[i32]) -> Result<Vec<f32>> {
        self.native_model.last_logits_ctx(
            &self.manifest,
            params,
            self.packed.as_ref(),
            tokens,
            0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_manifest;

    // Runtime tests that need artifacts live in rust/tests/ (integration);
    // here we cover the literal glue and the native dispatch.

    #[test]
    fn literal_roundtrip_f32() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn scalar_extraction() {
        let lit = lit_scalar_f32(7.5);
        assert_eq!(to_scalar_f32(&lit).unwrap(), 7.5);
        let v = lit_f32(&[1.0, 2.0], &[2]).unwrap();
        assert!(to_scalar_f32(&v).is_err());
    }

    #[test]
    fn native_runtime_dispatches_without_artifacts() {
        let manifest = synthetic_manifest("rt-native", 32, 1, 2, 64, 8, 256, 2);
        let mrt = ModelRuntime::native(manifest).unwrap();
        let params = mrt.init(3).unwrap();
        let tokens: Vec<i32> = (0..2 * 8).map(|i| (i % 250) as i32).collect();
        let logits = mrt.last_logits(&params, &tokens).unwrap();
        assert_eq!(logits.len(), 2 * 256);
        let nll = mrt.token_nll(&params, &tokens).unwrap();
        assert_eq!(nll.len(), 2 * 7);
        // PJRT-only entry points refuse cleanly
        assert!(mrt.train_step_art().is_err());
        assert!(mrt.calib_grads_art().is_err());
    }

    #[test]
    fn attach_packed_validates_shapes() {
        use crate::quant::{LayerCalib, TrickConfig};
        let manifest = synthetic_manifest("rt-packed", 16, 1, 2, 32, 8, 64, 1);
        let mut mrt = ModelRuntime::native(manifest.clone()).unwrap();
        let params = mrt.init(1).unwrap();
        let stats: Vec<LayerCalib> =
            manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
        let bits = vec![4u8; manifest.linears.len()];
        let packed = PackedLayers::quantize(
            &manifest, &params, &bits, &stats, &TrickConfig::none(), 2, 1,
        )
        .unwrap();
        // wrong arity rejected
        let mut truncated = packed.clone();
        truncated.layers.pop();
        assert!(mrt.attach_packed(truncated).is_err());
        assert!(mrt.packed().is_none());
        // correct one accepted and used
        mrt.attach_packed(packed).unwrap();
        assert!(mrt.packed().is_some());
        let tokens: Vec<i32> = (0..8).map(|i| i as i32).collect();
        let logits = mrt.last_logits(&params, &tokens).unwrap();
        assert_eq!(logits.len(), 64);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert!(mrt.detach_packed().is_some());
        assert!(mrt.packed().is_none());
    }

    #[test]
    fn kv_decode_matches_recompute_over_packed_weights() {
        use crate::quant::{LayerCalib, TrickConfig};
        let manifest = synthetic_manifest("rt-kv", 32, 2, 2, 64, 12, 256, 2);
        let mut mrt = ModelRuntime::native(manifest.clone()).unwrap();
        let params = mrt.init(5).unwrap();
        let stats: Vec<LayerCalib> =
            manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
        let bits = vec![5u8; manifest.linears.len()];
        let packed = PackedLayers::quantize(
            &manifest, &params, &bits, &stats, &TrickConfig::none(), 4, 1,
        )
        .unwrap();
        mrt.attach_packed(packed).unwrap();

        let mut cache = mrt.new_kv_cache(1);
        let mut ctx: Vec<i32> = vec![3, 1, 4, 1, 5];
        let mut logits = mrt.prefill(&params, &mut cache, 0, &ctx).unwrap();
        assert_eq!(logits, mrt.last_logits_ctx(&params, &ctx).unwrap());
        for _ in 0..4 {
            let tok = crate::util::argmax(&logits) as i32;
            logits = mrt.decode_step(&params, &mut cache, &[0], &[tok]).unwrap();
            ctx.push(tok);
            assert_eq!(
                logits,
                mrt.last_logits_ctx(&params, &ctx).unwrap(),
                "packed KV decode must match packed recompute bit-for-bit"
            );
        }
    }
}
