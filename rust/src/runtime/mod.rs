//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `compile` → `execute`. HLO **text** is the interchange
//! format (jax ≥ 0.5 serialized protos are rejected by xla_extension
//! 0.5.1 — see DESIGN.md). All entry points were lowered with
//! `return_tuple=True`, so outputs arrive as a single tuple literal that
//! we decompose.

use std::path::Path;

use anyhow::{Context, Result};

use crate::model::{ArtifactPaths, Manifest, ModelParams};

/// Shared PJRT client (CPU).
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Artifact> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Artifact {
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            exe,
        })
    }
}

/// A compiled executable artifact.
pub struct Artifact {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact '{}'", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching output literal")?;
        lit.to_tuple().context("decomposing output tuple")
    }
}

// ------------------------------------------------------------ literal glue

/// Build an f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "lit_f32 shape/data mismatch");
    let flat = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
    Ok(flat.reshape(&dims)?)
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "lit_i32 shape/data mismatch");
    let flat = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
    Ok(flat.reshape(&dims)?)
}

pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a scalar f32.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
    Ok(v[0])
}

// ------------------------------------------------------- model-level glue

/// A loaded model: manifest + the compiled entry points used everywhere.
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub init_params: Artifact,
    pub train_step: Artifact,
    pub fwd_loss: Artifact,
    pub fwd_logits: Artifact,
    pub calib_grads: Artifact,
    pub calib_capture: Artifact,
}

impl ModelRuntime {
    /// Load every entry point for `model` from the artifacts root.
    pub fn load(rt: &Runtime, root: &Path, model: &str) -> Result<Self> {
        let paths = ArtifactPaths::new(root, model);
        let manifest = Manifest::load(&paths.dir)
            .with_context(|| format!("run `make artifacts` first (model {model})"))?;
        Ok(ModelRuntime {
            manifest,
            init_params: rt.load(&paths.hlo("init_params"))?,
            train_step: rt.load(&paths.hlo("train_step"))?,
            fwd_loss: rt.load(&paths.hlo("fwd_loss"))?,
            fwd_logits: rt.load(&paths.hlo("fwd_logits"))?,
            calib_grads: rt.load(&paths.hlo("calib_grads"))?,
            calib_capture: rt.load(&paths.hlo("calib_capture"))?,
        })
    }

    /// Initialize parameters via the AOT init artifact.
    pub fn init(&self, seed: i32) -> Result<ModelParams> {
        let outs = self.init_params.run(&[lit_scalar_i32(seed)])?;
        anyhow::ensure!(
            outs.len() == self.manifest.params.len(),
            "init output arity {} != {}",
            outs.len(),
            self.manifest.params.len()
        );
        let tensors = outs
            .iter()
            .map(to_vec_f32)
            .collect::<Result<Vec<_>>>()?;
        ModelParams::from_tensors(&self.manifest, tensors)
    }

    /// Literal list for the current params (shared prefix of most calls).
    pub fn param_literals(&self, params: &ModelParams) -> Result<Vec<xla::Literal>> {
        params
            .specs
            .iter()
            .zip(&params.tensors)
            .map(|(spec, t)| lit_f32(t, &spec.shape))
            .collect()
    }

    /// Per-token negative log likelihood for a (B, S) token batch.
    pub fn token_nll(&self, params: &ModelParams, tokens: &[i32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        anyhow::ensure!(
            tokens.len() == m.eval_batch * m.seq_len,
            "token batch must be eval_batch x seq_len"
        );
        let mut inputs = self.param_literals(params)?;
        inputs.push(lit_i32(tokens, &[m.eval_batch, m.seq_len])?);
        let outs = self.fwd_loss.run(&inputs)?;
        to_vec_f32(&outs[0])
    }

    /// Last-position logits for a (B, S) token batch -> (B, vocab).
    pub fn last_logits(&self, params: &ModelParams, tokens: &[i32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let mut inputs = self.param_literals(params)?;
        inputs.push(lit_i32(tokens, &[m.eval_batch, m.seq_len])?);
        let outs = self.fwd_logits.run(&inputs)?;
        to_vec_f32(&outs[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/ (integration);
    // here we only cover the literal glue.

    #[test]
    fn literal_roundtrip_f32() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1, 2, 3], &[2, 2]).is_err());
    }

    #[test]
    fn scalar_extraction() {
        let lit = lit_scalar_f32(7.5);
        assert_eq!(to_scalar_f32(&lit).unwrap(), 7.5);
        let v = lit_f32(&[1.0, 2.0], &[2]).unwrap();
        assert!(to_scalar_f32(&v).is_err());
    }
}
