//! Thread-parallel helpers (no rayon in the offline vendor set).
//!
//! Since PR 7 the parallel primitives ride one **persistent, process-wide
//! worker pool** ([`global`]) instead of spawning and joining
//! `std::thread::scope` threads per call. Workers are spawned once
//! (`default_threads() - 1` of them; the calling thread is always the
//! remaining executor), park on a condvar between jobs, and are fed work
//! through a shared job queue. A *job* is a batch of `n_tasks` indices;
//! executors claim indices from an atomic cursor, so the **chunking is
//! fixed by the caller** and only *which executor* runs a chunk varies —
//! the bit-determinism-in-thread-count contract every kernel relies on
//! (see `rust/tests/integration.rs` and the per-kernel
//! `*_deterministic_across_thread_counts` tests).
//!
//! [`parallel_for`], [`parallel_map`] and [`parallel_chunks_mut`] keep
//! their pre-pool signatures, so every call site (`kernels::qgemm`,
//! `scan_scores_q`, dense `gemm`, `hadamard::fwht_batch` /
//! `PracticalRht::apply_rows`, the RaBitQ quantizer, and therefore the
//! serve batcher's prefill/decode steps) shares the same pool without
//! `Arc`-wrapping any kernel input: tasks borrow the caller's slices
//! exactly as the scoped version did.
//!
//! # How borrowed tasks meet persistent workers
//!
//! A worker thread is `'static`; a kernel's inputs are not. Safe Rust has
//! exactly one std mechanism for lending non-`'static` data to another
//! thread — `std::thread::scope` — and it is the spawn/join tax this pool
//! removes. So the handoff erases the task borrow at the pool boundary
//! (a raw pointer to the caller's `dyn Fn(usize)` task) and re-earns
//! safety with a **completion barrier**, which is precisely how
//! `thread::scope` is implemented inside std:
//!
//! * [`WorkerPool::run`] publishes the erased task, then **blocks until
//!   every index has finished executing** before returning. The borrow it
//!   erased therefore strictly outlives every dereference.
//! * Executors dereference the task only for claimed indices `i < n`,
//!   and the completion count reaches `n` only after each such call has
//!   returned. A worker that still holds a (now-dangling) pointer after
//!   the job completed can never dereference it again: the claim cursor
//!   is already `>= n`.
//! * Panics inside a task are caught per index (`catch_unwind`), counted
//!   as completed so the submitter can never hang, and surfaced as a
//!   typed [`PoolError`] — the job is poisoned, the pool is not (the
//!   PR-6 batcher containment idiom, one layer down).
//!
//! Those three invariants are the entire unsafe surface of the crate and
//! they live in this module only; all public APIs are safe.
//!
//! Re-entrant submission (a task calling back into the pool) is
//! **supported**: the nested call executes inline on the submitting
//! executor, which is deadlock-free and bit-identical because results
//! never depend on which executor runs an index. [`WorkerPool::shutdown`]
//! can race any in-flight job without hanging it: the submitting thread
//! is itself an executor, so it finishes whatever the exiting workers do
//! not.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread;

/// Number of worker threads to use (env `RAANA_THREADS` overrides).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("RAANA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Typed failure of a pool job (satellite of the PR-6 containment story:
/// a panicking work item poisons only its own job, never the pool).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PoolError {
    /// At least one work item panicked. `detail` carries the first
    /// captured panic message; the remaining indices of the job still ran
    /// (the completion barrier requires it), and the pool remains
    /// serviceable for subsequent jobs.
    TaskPanicked {
        /// First captured panic payload, stringified.
        detail: String,
    },
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::TaskPanicked { detail } => {
                write!(f, "pool work item panicked: {detail}")
            }
        }
    }
}

impl std::error::Error for PoolError {}

/// The caller-facing task shape: `task(i)` for each index in `0..n_tasks`.
/// Must be callable from any executor concurrently (`Sync`).
type Task<'a> = dyn Fn(usize) + Sync + 'a;

/// One submitted batch of indices, shared between the submitter and the
/// workers that joined it.
struct Job {
    /// Lifetime-erased pointer to the submitter's task. See the module
    /// docs: valid until `done == n`, which [`WorkerPool::run`] awaits
    /// before returning (and before the borrow it erased can end).
    task: *const Task<'static>,
    /// Total indices in the job; the fixed chunking lives in the caller.
    n: usize,
    /// Claim cursor: `fetch_add` hands out each index exactly once.
    next: AtomicUsize,
    /// Executors currently registered on this job (submitter included).
    active: AtomicUsize,
    /// Maximum executors allowed to join (the caller's `threads` hint).
    width: usize,
    /// Completed-index count behind a mutex so the submitter can condvar-
    /// wait on it; `done == n` is the completion barrier.
    done: Mutex<usize>,
    done_cv: Condvar,
    /// First panic payload captured from a work item, if any.
    panic_detail: Mutex<Option<String>>,
}

// SAFETY: `task` points at a `dyn Fn(usize) + Sync` owned by the
// submitting thread's stack frame. Sending the pointer between threads is
// sound because (a) the pointee is `Sync`, so concurrent `&`-calls are
// allowed, and (b) every dereference happens-before `done == n`, which
// `WorkerPool::run` awaits while the pointee is still borrowed (the
// completion barrier in the module docs). No executor dereferences after
// the cursor passes `n`.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    fn has_unclaimed(&self) -> bool {
        self.next.load(Ordering::Relaxed) < self.n
    }

    /// Try to join this job as one more executor (bounded by `width`).
    fn try_register(&self) -> bool {
        self.active
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |a| {
                if a < self.width {
                    Some(a + 1)
                } else {
                    None
                }
            })
            .is_ok()
    }

    fn unregister(&self) {
        self.active.fetch_sub(1, Ordering::Relaxed);
    }

    /// Claim-and-run loop shared by the submitter and every worker that
    /// joined the job. Each claimed index runs under `catch_unwind` and is
    /// counted completed even on panic, so the barrier always releases.
    fn drain(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            // SAFETY: i < n, so the barrier has not released and the
            // submitter still holds the borrow behind `task` (see the
            // `unsafe impl` above and the module docs).
            let res = catch_unwind(AssertUnwindSafe(|| unsafe { (*self.task)(i) }));
            if let Err(payload) = res {
                let mut slot = self.panic_detail.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(panic_message(&payload));
                }
            }
            let mut c = self.done.lock().unwrap();
            *c += 1;
            if *c == self.n {
                self.done_cv.notify_all();
            }
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

struct PoolShared {
    /// Pending / in-flight jobs. The submitter removes its own job after
    /// the barrier releases; workers only scan for joinable entries.
    jobs: Mutex<Vec<Arc<Job>>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
}

thread_local! {
    /// Set while this thread is executing inside a pool job (worker main
    /// loop, or a submitter draining its own job). Nested submissions
    /// observe it and run inline — re-entrancy support without deadlock.
    static IN_POOL_JOB: Cell<bool> = const { Cell::new(false) };
}

/// A persistent worker pool executing borrowed, index-addressed jobs.
///
/// `WorkerPool::new(k)` parks `k - 1` worker threads; the submitting
/// thread is always the k-th executor, so a pool of size 1 has **no**
/// workers and runs jobs inline with zero synchronization — the serial
/// reference path the determinism tests compare against.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Vec<thread::JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    /// Create a pool with `threads` executors (`threads - 1` parked
    /// worker threads plus the submitter).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            jobs: Mutex::new(Vec::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..threads - 1)
            .map(|_| {
                let sh = Arc::clone(&shared);
                thread::spawn(move || worker_main(&sh))
            })
            .collect();
        WorkerPool { shared, workers, threads }
    }

    /// Executor count this pool was built with (workers + submitter).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `task(i)` for every `i in 0..n_tasks`, at most `width`
    /// executors touching the job (the caller's `threads` hint; clamped
    /// to at least the submitting thread). Blocks until **every** index
    /// has completed — the barrier that makes lending `task`'s borrows to
    /// persistent workers sound.
    ///
    /// Determinism contract: `task` must derive everything from `i` (and
    /// captured state it only reads, or writes disjointly by `i`), never
    /// from the executing thread. Under that contract the output is
    /// bit-identical for every `width` and pool size, warm or cold.
    ///
    /// Runs inline (serially, on the calling thread) when `n_tasks <= 1`,
    /// `width <= 1`, the pool has no workers or is shut down, or the
    /// caller is itself a pool executor (re-entrant submission).
    pub fn run(&self, n_tasks: usize, width: usize, task: &Task<'_>) -> Result<(), PoolError> {
        if n_tasks == 0 {
            return Ok(());
        }
        let inline = n_tasks == 1
            || width <= 1
            || self.workers.is_empty()
            || self.shared.shutdown.load(Ordering::SeqCst)
            || IN_POOL_JOB.with(|f| f.get());
        if inline {
            return run_inline(n_tasks, task);
        }

        // SAFETY: erase the task borrow for the worker handoff. The
        // pointee lives in our caller's frame; the barrier below (`done ==
        // n_tasks`) completes before this function returns, hence before
        // the borrow can end. See the module docs and `unsafe impl Send /
        // Sync for Job`.
        let task: *const Task<'static> = unsafe { std::mem::transmute(task as *const Task<'_>) };
        let job = Arc::new(Job {
            task,
            n: n_tasks,
            next: AtomicUsize::new(0),
            active: AtomicUsize::new(1), // the submitter
            width: width.max(1),
            done: Mutex::new(0),
            done_cv: Condvar::new(),
            panic_detail: Mutex::new(None),
        });
        {
            let mut q = self.shared.jobs.lock().unwrap();
            q.push(Arc::clone(&job));
        }
        self.shared.work_cv.notify_all();

        // The submitter is executor #1: drain alongside the workers, then
        // hold at the barrier for indices other executors still run.
        IN_POOL_JOB.with(|f| f.set(true));
        job.drain();
        IN_POOL_JOB.with(|f| f.set(false));
        let mut c = job.done.lock().unwrap();
        while *c < job.n {
            c = job.done_cv.wait(c).unwrap();
        }
        drop(c);
        job.unregister();

        // Barrier released: retire the job before the erased borrow ends.
        {
            let mut q = self.shared.jobs.lock().unwrap();
            q.retain(|j| !Arc::ptr_eq(j, &job));
        }

        let detail = job.panic_detail.lock().unwrap().take();
        match detail {
            Some(detail) => Err(PoolError::TaskPanicked { detail }),
            None => Ok(()),
        }
    }

    /// Ask the workers to exit after their current job. In-flight and
    /// subsequent [`WorkerPool::run`] calls still complete — the
    /// submitting thread is always an executor, so a drained pool just
    /// degrades to inline execution; nothing can hang on shutdown.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.work_cv.notify_all();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Serial fallback used for tiny jobs, width-1 requests, and re-entrant
/// submissions. Panic semantics match the pooled path: every index runs,
/// the first panic is reported as a typed error.
fn run_inline(n_tasks: usize, task: &Task<'_>) -> Result<(), PoolError> {
    let mut first_panic: Option<String> = None;
    for i in 0..n_tasks {
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| task(i))) {
            if first_panic.is_none() {
                first_panic = Some(panic_message(&payload));
            }
        }
    }
    match first_panic {
        Some(detail) => Err(PoolError::TaskPanicked { detail }),
        None => Ok(()),
    }
}

fn worker_main(shared: &PoolShared) {
    IN_POOL_JOB.with(|f| f.set(true));
    let mut q = shared.jobs.lock().unwrap();
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let job = q.iter().find(|j| j.has_unclaimed() && j.try_register()).map(Arc::clone);
        match job {
            Some(job) => {
                drop(q);
                job.drain();
                job.unregister();
                q = shared.jobs.lock().unwrap();
            }
            None => {
                q = shared.work_cv.wait(q).unwrap();
            }
        }
    }
}

/// The process-wide pool every parallel kernel shares, sized
/// [`default_threads`] (so `RAANA_THREADS` set at process start bounds
/// the whole serving substrate). Created lazily on first use; never torn
/// down — worker threads park between jobs and cost nothing idle.
pub fn global() -> &'static WorkerPool {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(|| WorkerPool::new(default_threads()))
}

/// Re-raise a pooled task panic on the submitting thread, preserving the
/// pre-pool `thread::scope` semantics the kernel callers (and the serve
/// batcher's `catch_unwind` containment above them) were built on.
fn propagate(res: Result<(), PoolError>) {
    if let Err(e) = res {
        panic!("{e}");
    }
}

/// Run `f(index, item)` over all items on the shared pool, work-stealing
/// via the job's atomic claim cursor. Bit-deterministic in `threads`.
pub fn parallel_for<T: Sync, F: Fn(usize, &T) + Sync>(items: &[T], threads: usize, f: F) {
    if items.is_empty() {
        return;
    }
    let width = threads.clamp(1, items.len());
    propagate(global().run(items.len(), width, &|i| f(i, &items[i])));
}

/// Map `f` over items in parallel preserving order.
pub fn parallel_map<T: Sync, R: Send, F: Fn(usize, &T) -> R + Sync>(
    items: &[T],
    threads: usize,
    f: F,
) -> Vec<R> {
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    {
        let slots = Mutex::new(&mut out);
        let width = threads.clamp(1, items.len().max(1));
        propagate(global().run(items.len(), width, &|i| {
            let r = f(i, &items[i]);
            // lock only to place the result; disjoint slots by index
            let mut guard = slots.lock().unwrap();
            guard[i] = Some(r);
        }));
    }
    out.into_iter().map(|x| x.expect("worker filled slot")).collect()
}

/// Split a mutable slice into `chunk`-sized pieces processed in parallel
/// on the shared pool. Chunk boundaries depend only on (`data.len()`,
/// `chunk`) — never on the pool — so outputs are bit-identical across
/// pool sizes and thread counts.
pub fn parallel_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk: usize,
    threads: usize,
    f: F,
) {
    if data.is_empty() {
        return;
    }
    let chunks: Vec<Option<(usize, &mut [T])>> =
        data.chunks_mut(chunk).enumerate().map(Some).collect();
    let n = chunks.len();
    let slots = Mutex::new(chunks);
    let width = threads.clamp(1, n);
    propagate(global().run(n, width, &|i| {
        let taken = {
            let mut g = slots.lock().unwrap();
            g[i].take()
        };
        if let Some((idx, slice)) = taken {
            f(idx, slice);
        }
    }));
}

/// A long-lived FIFO task pool for `'static` jobs (the HTTP connection
/// workers in `net/`). Distinct from [`WorkerPool`]: these jobs block on
/// sockets for seconds, so they must never occupy kernel executors.
pub struct Pool {
    tx: Option<mpsc::Sender<Box<dyn FnOnce() + Send>>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Pool {
    pub fn new(threads: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Box<dyn FnOnce() + Send>>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break,
                    }
                })
            })
            .collect();
        Pool { tx: Some(tx), workers }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool closed")
            .send(Box::new(f))
            .expect("pool workers alive");
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_all() {
        let items: Vec<usize> = (0..1000).collect();
        let sum = AtomicU64::new(0);
        parallel_for(&items, 8, |_, &x| {
            sum.fetch_add(x as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 499_500);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = parallel_map(&items, 7, |_, &x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_chunks_mut_writes_disjoint() {
        let mut data = vec![0u32; 1003];
        parallel_chunks_mut(&mut data, 100, 4, |idx, chunk| {
            for x in chunk.iter_mut() {
                *x = idx as u32 + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[1002], 11);
    }

    #[test]
    fn pool_runs_tasks() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn empty_inputs_ok() {
        let items: Vec<u8> = vec![];
        parallel_for(&items, 4, |_, _| panic!("should not run"));
        let out: Vec<u8> = parallel_map(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    /// The wall: one borrowed job, every pool size, bit-identical output
    /// and full coverage (each index exactly once).
    #[test]
    fn worker_pool_deterministic_across_pool_sizes() {
        let input: Vec<u64> = (0..997).map(|i| i * 2654435761 % 1013).collect();
        let reference: Vec<u64> = input.iter().map(|&x| x * x + 7).collect();
        for pool_size in [1usize, 2, 3, 7, 8] {
            let pool = WorkerPool::new(pool_size);
            let out: Vec<AtomicU64> = (0..input.len()).map(|_| AtomicU64::new(0)).collect();
            let hits = AtomicUsize::new(0);
            pool.run(input.len(), pool_size, &|i| {
                out[i].store(input[i] * input[i] + 7, Ordering::Relaxed);
                hits.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
            assert_eq!(hits.load(Ordering::Relaxed), input.len(), "size {pool_size}");
            let got: Vec<u64> = out.iter().map(|a| a.load(Ordering::Relaxed)).collect();
            assert_eq!(got, reference, "pool size {pool_size}");
        }
    }

    /// Warm-pool reuse: repeated jobs on one pool leak no state between
    /// jobs (fresh cursor/barrier per job, identical results each time).
    #[test]
    fn warm_pool_repeated_jobs_identical() {
        let pool = WorkerPool::new(4);
        let items: Vec<usize> = (0..513).collect();
        let mut first: Option<Vec<usize>> = None;
        for round in 0..20 {
            let out: Vec<AtomicUsize> = (0..items.len()).map(|_| AtomicUsize::new(0)).collect();
            pool.run(items.len(), 4, &|i| {
                out[i].store(items[i] * 3 + 1, Ordering::Relaxed);
            })
            .unwrap();
            let got: Vec<usize> = out.iter().map(|a| a.load(Ordering::Relaxed)).collect();
            match &first {
                None => first = Some(got),
                Some(f) => assert_eq!(&got, f, "round {round}"),
            }
        }
    }

    /// A panicking work item poisons only its job: the submitter gets a
    /// typed error, every other index still ran, and the same pool
    /// services the next job normally.
    #[test]
    fn panic_poisons_job_not_pool() {
        let pool = WorkerPool::new(4);
        let ran = AtomicUsize::new(0);
        let err = pool
            .run(64, 4, &|i| {
                if i == 13 {
                    panic!("boom at 13");
                }
                ran.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap_err();
        let PoolError::TaskPanicked { detail } = err;
        assert!(detail.contains("boom at 13"), "detail: {detail}");
        assert_eq!(ran.load(Ordering::Relaxed), 63, "all non-panicking indices ran");

        // pool stays serviceable
        let ok = AtomicUsize::new(0);
        pool.run(64, 4, &|_| {
            ok.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(ok.load(Ordering::Relaxed), 64);
    }

    /// Re-entrant submission from inside a task is supported: it runs
    /// inline on the submitting executor and cannot deadlock.
    #[test]
    fn reentrant_submission_runs_inline() {
        let pool = WorkerPool::new(3);
        let inner_total = AtomicUsize::new(0);
        pool.run(6, 3, &|_| {
            // nested submission to the *global* pool from a pool executor
            let local = AtomicUsize::new(0);
            global()
                .run(10, 8, &|j| {
                    local.fetch_add(j + 1, Ordering::Relaxed);
                })
                .unwrap();
            assert_eq!(local.load(Ordering::Relaxed), 55);
            inner_total.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(inner_total.load(Ordering::Relaxed), 6);
    }

    /// Shutdown racing an in-flight job never hangs the submitter: the
    /// submitting thread is an executor and finishes what workers drop.
    #[test]
    fn shutdown_during_job_completes_and_stays_usable() {
        let pool = Arc::new(WorkerPool::new(4));
        let p2 = Arc::clone(&pool);
        let done = Arc::new(AtomicUsize::new(0));
        let d2 = Arc::clone(&done);
        let submitter = thread::spawn(move || {
            p2.run(200, 4, &|_| {
                std::thread::sleep(std::time::Duration::from_micros(50));
                d2.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        });
        pool.shutdown();
        submitter.join().unwrap();
        assert_eq!(done.load(Ordering::Relaxed), 200);

        // post-shutdown jobs degrade to inline execution, still correct
        let after = AtomicUsize::new(0);
        pool.run(32, 4, &|_| {
            after.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(after.load(Ordering::Relaxed), 32);
    }

    /// The helpers ride the global pool and agree with serial for every
    /// requested width (the primitive-level thread-count wall).
    #[test]
    fn helpers_bit_identical_across_widths() {
        let items: Vec<u32> = (0..731).map(|i| i * 2654435761u32).collect();
        let serial = parallel_map(&items, 1, |i, &x| x.rotate_left((i % 31) as u32));
        for width in [2usize, 3, 7, 8] {
            let got = parallel_map(&items, width, |i, &x| x.rotate_left((i % 31) as u32));
            assert_eq!(got, serial, "width {width}");
        }
        let mut base = vec![0u64; 1003];
        parallel_chunks_mut(&mut base, 64, 1, |idx, chunk| {
            for (k, x) in chunk.iter_mut().enumerate() {
                *x = (idx * 1000 + k) as u64;
            }
        });
        for width in [2usize, 3, 7, 8] {
            let mut data = vec![0u64; 1003];
            parallel_chunks_mut(&mut data, 64, width, |idx, chunk| {
                for (k, x) in chunk.iter_mut().enumerate() {
                    *x = (idx * 1000 + k) as u64;
                }
            });
            assert_eq!(data, base, "width {width}");
        }
    }
}
