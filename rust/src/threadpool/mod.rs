//! Thread-parallel helpers (no rayon in the offline vendor set).
//!
//! [`parallel_chunks_mut`] is the quantizer hot-path primitive: it splits
//! a mutable slice of work items across `std::thread::scope` workers.
//! [`Pool`] is a long-lived task pool used by the serving coordinator.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Number of worker threads to use (env `RAANA_THREADS` overrides).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("RAANA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(index, item)` over all items, work-stealing via an atomic cursor.
pub fn parallel_for<T: Sync, F: Fn(usize, &T) + Sync>(items: &[T], threads: usize, f: F) {
    if items.is_empty() {
        return;
    }
    let threads = threads.clamp(1, items.len());
    let cursor = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                f(i, &items[i]);
            });
        }
    });
}

/// Map `f` over items in parallel preserving order.
pub fn parallel_map<T: Sync, R: Send, F: Fn(usize, &T) -> R + Sync>(
    items: &[T],
    threads: usize,
    f: F,
) -> Vec<R> {
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    let cursor = AtomicUsize::new(0);
    let threads = threads.clamp(1, items.len().max(1));
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                // SAFETY-free approach: short lock to place the result.
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(r);
            });
        }
    });
    out.into_iter().map(|x| x.expect("worker filled slot")).collect()
}

/// Split a mutable slice into chunks processed by separate threads.
pub fn parallel_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk: usize,
    threads: usize,
    f: F,
) {
    if data.is_empty() {
        return;
    }
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk).enumerate().collect();
    let cursor = AtomicUsize::new(0);
    let chunks = Mutex::new(chunks.into_iter().map(Some).collect::<Vec<_>>());
    let n = {
        let g = chunks.lock().unwrap();
        g.len()
    };
    let threads = threads.clamp(1, n);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let taken = {
                    let mut g = chunks.lock().unwrap();
                    g[i].take()
                };
                if let Some((idx, slice)) = taken {
                    f(idx, slice);
                }
            });
        }
    });
}

/// A long-lived FIFO task pool (used by the serving coordinator).
pub struct Pool {
    tx: Option<mpsc::Sender<Box<dyn FnOnce() + Send>>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Pool {
    pub fn new(threads: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Box<dyn FnOnce() + Send>>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break,
                    }
                })
            })
            .collect();
        Pool { tx: Some(tx), workers }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool closed")
            .send(Box::new(f))
            .expect("pool workers alive");
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_all() {
        let items: Vec<usize> = (0..1000).collect();
        let sum = AtomicU64::new(0);
        parallel_for(&items, 8, |_, &x| {
            sum.fetch_add(x as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 499_500);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let out = parallel_map(&items, 7, |_, &x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_chunks_mut_writes_disjoint() {
        let mut data = vec![0u32; 1003];
        parallel_chunks_mut(&mut data, 100, 4, |idx, chunk| {
            for x in chunk.iter_mut() {
                *x = idx as u32 + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[1002], 11);
    }

    #[test]
    fn pool_runs_tasks() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..64 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn empty_inputs_ok() {
        let items: Vec<u8> = vec![];
        parallel_for(&items, 4, |_, _| panic!("should not run"));
        let out: Vec<u8> = parallel_map(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }
}
