//! RaBitQ multi-bit grid quantization (Gao & Long 2024; Gao et al. 2024),
//! the vector-quantization core of the paper's RaBitQ-H.
//!
//! Given an (already RHT-rotated) column v in R^d and a bit-width b:
//!
//! ```text
//! t      = scale (max-abs grid, optionally refined by a 1-D search)
//! codes  = clip(round(v / t + c_b), 0, 2^b - 1),   c_b = (2^b - 1)/2
//! r      = <v, q> / <q, q>,  q = codes - c_b       (least-squares rescale)
//! ```
//!
//! so that `v ~= r * (codes - c_b)` and the paper's Algorithm-3 estimator
//! `y_j = r_j * (X' codes_j - c_b X' 1)` is the least-squares-optimal
//! collinear reconstruction. The error obeys the empirical bound of paper
//! eq. (11): `|<x,w> - est| < c_err/(sqrt(d) 2^b) ||x|| ||w||` whp after
//! random rotation — property-tested in this module and exercised by
//! `benches/error_bound.rs`.
//!
//! Codes are bit-packed ([`PackedCodes`]) — b bits per weight, the format
//! whose size the paper's "avg bits" accounting counts.
#![deny(missing_docs)]

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::tensor::Matrix;
use crate::threadpool;

/// Process-wide count of full-matrix dequantizations
/// ([`QuantizedMatrix::dequantize`] calls). The packed serving path must
/// not dequantize per forward — tests assert this counter stays flat
/// across `ModelRuntime` forwards (ISSUE 1 acceptance criterion).
static DEQUANT_CALLS: AtomicUsize = AtomicUsize::new(0);

/// Read the full-matrix dequantization counter.
pub fn dequant_calls() -> usize {
    DEQUANT_CALLS.load(Ordering::Relaxed)
}

/// Grid midpoint c_b = (2^b - 1) / 2.
#[inline]
pub fn grid_center(bits: u8) -> f32 {
    ((1u32 << bits) - 1) as f32 / 2.0
}

/// Empirical error-bound constant from the RaBitQ paper (eq. 11).
pub const C_ERROR: f64 = 5.75;

/// Scale-selection strategy for the grid.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScaleMode {
    /// t = max|v| / c_b — one pass, what the Pallas kernel implements.
    MaxAbs,
    /// 1-D search over `n` candidate shrink factors of the max-abs scale,
    /// picking the reconstruction-error minimizer (extended RaBitQ's
    /// scalar search). Slightly better codes at ~n x the quantization cost.
    Search(usize),
}

impl Default for ScaleMode {
    fn default() -> Self {
        ScaleMode::Search(8)
    }
}

/// Quantize one column. Returns (codes, r) with codes in [0, 2^bits - 1].
///
/// # Examples
///
/// ```
/// use raana::rabitq::{dequantize_column, quantize_column, ScaleMode};
///
/// let v = vec![0.9f32, -0.4, 0.1, -1.0];
/// let (codes, r) = quantize_column(&v, 4, ScaleMode::MaxAbs);
/// assert!(codes.iter().all(|&c| c < 16)); // 4-bit grid
/// let mut rec = vec![0.0; 4];
/// dequantize_column(&codes, r, 4, &mut rec);
/// for (a, b) in v.iter().zip(&rec) {
///     assert!((a - b).abs() < 0.2, "v ~= r * (codes - c_b)");
/// }
/// ```
pub fn quantize_column(v: &[f32], bits: u8, mode: ScaleMode) -> (Vec<u8>, f32) {
    let mut codes = Vec::with_capacity(v.len());
    let r = quantize_column_into(v, bits, mode, &mut codes);
    (codes, r)
}

/// Quantize one column into a caller-owned buffer (cleared first) and
/// return the least-squares rescale r — the allocation-free variant the
/// block-parallel [`QuantizedMatrix::quantize`] hot loop uses.
pub fn quantize_column_into(v: &[f32], bits: u8, mode: ScaleMode, codes: &mut Vec<u8>) -> f32 {
    assert!((1..=8).contains(&bits), "bits must be in 1..=8");
    let cb = grid_center(bits);
    let maxv = (1u32 << bits) - 1;
    let maxabs = v.iter().fold(0f32, |m, x| m.max(x.abs()));
    if maxabs == 0.0 {
        codes.clear();
        codes.resize(v.len(), cb.floor() as u8);
        return 0.0;
    }
    let base_t = maxabs / cb;

    // Hot path notes (EXPERIMENTS.md §Perf): the per-element division was
    // the dominant cost (fp div has ~14-cycle latency and does not
    // pipeline in this scalar loop) — we multiply by 1/t instead; the
    // search loop scores candidates without materializing code vectors
    // (only <v,q> and <q,q> are needed for the LS error) and quantizes
    // once at the winning scale.
    let quant_into = |t: f32, out: &mut Vec<u8>| -> (f64, f64) {
        out.clear();
        let inv_t = 1.0 / t;
        let mut vq = 0f64;
        let mut qq = 0f64;
        for &x in v {
            let code = (x * inv_t + cb).round().clamp(0.0, maxv as f32);
            let q = code - cb;
            vq += (x as f64) * (q as f64);
            qq += (q as f64) * (q as f64);
            out.push(code as u8);
        }
        (vq, qq)
    };
    // Candidate scoring subsamples long columns (>=512 dims): the LS error
    // is an average over near-iid rotated coordinates, so a ~256-element
    // stratified sample ranks scales reliably at a fraction of the cost.
    let stride = (v.len() / 256).max(1);
    let score_only = |t: f32| -> f64 {
        let inv_t = 1.0 / t;
        let mut vq = 0f64;
        let mut qq = 0f64;
        let mut vv = 0f64;
        let mut k = 0;
        while k < v.len() {
            let x = v[k];
            let code = (x * inv_t + cb).round().clamp(0.0, maxv as f32);
            let q = code - cb;
            vq += (x as f64) * (q as f64);
            qq += (q as f64) * (q as f64);
            vv += (x as f64) * (x as f64);
            k += stride;
        }
        // sampled ||v - r q||^2 at the LS-optimal r
        vv - if qq > 0.0 { vq * vq / qq } else { 0.0 }
    };

    match mode {
        ScaleMode::MaxAbs => {
            let (vq, qq) = quant_into(base_t, codes);
            if qq > 0.0 { (vq / qq) as f32 } else { 0.0 }
        }
        ScaleMode::Search(n) => {
            // Shrinking the grid clips tails but refines the bulk; after a
            // random rotation coordinates are near-Gaussian so the optimum
            // is typically at 60-100% of the max-abs scale.
            let n = n.max(1);
            let mut best_t = base_t;
            let mut best_err = f64::INFINITY;
            for i in 0..=n {
                let factor = if i == n { 1.0 } else { 0.55 + 0.45 * (i as f32 / n as f32) };
                let t = base_t * factor;
                let err = score_only(t);
                if err < best_err {
                    best_err = err;
                    best_t = t;
                }
            }
            let (vq, qq) = quant_into(best_t, codes);
            if qq > 0.0 { (vq / qq) as f32 } else { 0.0 }
        }
    }
}

/// Reconstruct a column from its codes: v_hat = r * (codes - c_b).
pub fn dequantize_column(codes: &[u8], r: f32, bits: u8, out: &mut [f32]) {
    let cb = grid_center(bits);
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = r * (c as f32 - cb);
    }
}

/// Estimate <x, v> from codes without dequantizing (paper Alg. 3 for one
/// column): r * (<x, codes> - c_b * sum(x)).
pub fn estimate_ip(x: &[f32], codes: &[u8], r: f32, bits: u8) -> f64 {
    debug_assert_eq!(x.len(), codes.len());
    let cb = grid_center(bits) as f64;
    let mut xc = 0f64;
    let mut xs = 0f64;
    for (&xi, &ci) in x.iter().zip(codes) {
        xc += xi as f64 * ci as f64;
        xs += xi as f64;
    }
    r as f64 * (xc - cb * xs)
}

/// Bit-packed code storage: `bits` bits per entry, column-major
/// (column j occupies entries [j*d, (j+1)*d)).
#[derive(Clone, Debug)]
pub struct PackedCodes {
    /// Bits per element (1..=8).
    pub bits: u8,
    /// Number of packed elements.
    pub len: usize,
    /// LSB-first packed payload, `ceil(len * bits / 8)` bytes.
    pub data: Vec<u8>,
}

impl PackedCodes {
    /// Pack `values` (each `< 2^bits`) at `bits` bits per element,
    /// LSB-first within each byte.
    pub fn pack(values: &[u8], bits: u8) -> Self {
        assert!((1..=8).contains(&bits));
        let total_bits = values.len() * bits as usize;
        let mut data = vec![0u8; total_bits.div_ceil(8)];
        for (i, &v) in values.iter().enumerate() {
            debug_assert!(v < (1u16 << bits) as u8 || bits == 8);
            let bit0 = i * bits as usize;
            let byte0 = bit0 / 8;
            let off = bit0 % 8;
            let w = (v as u16) << off;
            data[byte0] |= (w & 0xFF) as u8;
            if off + bits as usize > 8 {
                data[byte0 + 1] |= (w >> 8) as u8;
            }
        }
        PackedCodes { bits, len: values.len(), data }
    }

    /// Read element `i` (random access; the bulk path is
    /// [`crate::kernels::decode_codes_into`]).
    #[inline]
    pub fn get(&self, i: usize) -> u8 {
        let bits = self.bits as usize;
        let bit0 = i * bits;
        let byte0 = bit0 / 8;
        let off = bit0 % 8;
        let mut w = self.data[byte0] as u16;
        if off + bits > 8 {
            w |= (self.data[byte0 + 1] as u16) << 8;
        }
        ((w >> off) & ((1u16 << bits) - 1)) as u8
    }

    /// Unpack every element back to one byte each.
    pub fn unpack(&self) -> Vec<u8> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// Stored size in bits (payload only).
    pub fn stored_bits(&self) -> usize {
        self.len * self.bits as usize
    }
}

/// Quantized matrix: all columns of a (d x c) matrix at a shared bit-width.
#[derive(Clone, Debug)]
pub struct QuantizedMatrix {
    /// Rows (input dimension) of the original matrix.
    pub d: usize,
    /// Columns of the original matrix.
    pub c: usize,
    /// Bits per code.
    pub bits: u8,
    /// Bit-packed codes, column-major (column j at elements `j*d..(j+1)*d`).
    pub codes: PackedCodes,
    /// Per-column least-squares rescale factors.
    pub r: Vec<f32>,
}

impl QuantizedMatrix {
    /// Quantize every column of `m`, parallel across column blocks. Each
    /// worker reuses one gather buffer and one code buffer for its whole
    /// block (no per-column allocation — see [`crate::tensor::Col`]).
    pub fn quantize(m: &Matrix, bits: u8, mode: ScaleMode, threads: usize) -> Self {
        const BLOCK: usize = 16;
        let (d, c) = (m.rows, m.cols);
        let blocks: Vec<usize> = (0..c).step_by(BLOCK).collect();
        let results = threadpool::parallel_map(&blocks, threads, |_, &j0| {
            let jend = (j0 + BLOCK).min(c);
            let mut gather = vec![0f32; d];
            let mut colcodes: Vec<u8> = Vec::with_capacity(d);
            let mut codes = Vec::with_capacity(d * (jend - j0));
            let mut rs = Vec::with_capacity(jend - j0);
            for j in j0..jend {
                m.col_view(j).copy_into(&mut gather);
                rs.push(quantize_column_into(&gather, bits, mode, &mut colcodes));
                codes.extend_from_slice(&colcodes);
            }
            (codes, rs)
        });
        let mut all = Vec::with_capacity(d * c);
        let mut r = Vec::with_capacity(c);
        for (codes, rs) in results {
            all.extend_from_slice(&codes);
            r.extend_from_slice(&rs);
        }
        QuantizedMatrix { d, c, bits, codes: PackedCodes::pack(&all, bits), r }
    }

    /// Dequantize back to a dense (d x c) matrix.
    ///
    /// Counted by [`dequant_calls`]: the packed serving path must never
    /// reach this per forward.
    pub fn dequantize(&self) -> Matrix {
        DEQUANT_CALLS.fetch_add(1, Ordering::Relaxed);
        let cb = grid_center(self.bits);
        let mut out = Matrix::zeros(self.d, self.c);
        let mut col = vec![0f32; self.d];
        for j in 0..self.c {
            crate::kernels::decode_codes_into(&self.codes, j * self.d, &mut col);
            let rj = self.r[j];
            for i in 0..self.d {
                *out.at_mut(i, j) = rj * (col[i] - cb);
            }
        }
        out
    }

    /// Algorithm-3 matmul estimation: given X' (n x d) rotated activations,
    /// estimate X' @ V. Routed through the fused packed-code kernel
    /// [`crate::kernels::qgemm`] — cache-blocked, thread-parallel
    /// (`RAANA_THREADS`), decoding each code tile once and reusing it
    /// across all n activation rows. Bit-deterministic in the thread count.
    pub fn matmul_est(&self, x: &Matrix) -> Matrix {
        crate::kernels::qgemm(x, self, 0)
    }

    /// The pre-kernel serial reference path (one column decoded at a time,
    /// f64 dots, single thread). Kept for `benches/kernels.rs` to measure
    /// the fused kernel against, and as a correctness oracle.
    pub fn matmul_est_serial(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.d);
        let cb = grid_center(self.bits);
        let mut out = Matrix::zeros(x.rows, self.c);
        let row_sums: Vec<f32> = (0..x.rows)
            .map(|i| x.row(i).iter().sum::<f32>())
            .collect();
        let mut col = vec![0f32; self.d];
        for j in 0..self.c {
            let base = j * self.d;
            for (k, slot) in col.iter_mut().enumerate() {
                *slot = self.codes.get(base + k) as f32;
            }
            let rj = self.r[j];
            for i in 0..x.rows {
                let xc = crate::tensor::dot(x.row(i), &col) as f32;
                *out.at_mut(i, j) = rj * (xc - cb * row_sums[i]);
            }
        }
        out
    }

    /// Payload size in bits: codes + one f32 rescale per column.
    pub fn stored_bits(&self) -> usize {
        self.codes.stored_bits() + self.c * 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::dot;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).gaussian_vec(n)
    }

    #[test]
    fn grid_center_values() {
        assert_eq!(grid_center(1), 0.5);
        assert_eq!(grid_center(2), 1.5);
        assert_eq!(grid_center(4), 7.5);
        assert_eq!(grid_center(8), 127.5);
    }

    #[test]
    fn codes_in_range_all_bits() {
        let v = randvec(256, 1);
        for bits in 1..=8u8 {
            for mode in [ScaleMode::MaxAbs, ScaleMode::Search(6)] {
                let (codes, _) = quantize_column(&v, bits, mode);
                let max = (1u32 << bits) - 1;
                assert!(codes.iter().all(|&c| (c as u32) <= max), "bits={bits}");
            }
        }
    }

    #[test]
    fn zero_column_gives_zero_r() {
        let v = vec![0f32; 64];
        let (_, r) = quantize_column(&v, 4, ScaleMode::default());
        assert_eq!(r, 0.0);
    }

    #[test]
    fn reconstruction_error_decays_with_bits() {
        let v = randvec(512, 3);
        let vnorm = crate::tensor::norm(&v);
        let mut prev = f64::INFINITY;
        for bits in 1..=8u8 {
            let (codes, r) = quantize_column(&v, bits, ScaleMode::default());
            let mut rec = vec![0f32; v.len()];
            dequantize_column(&codes, r, bits, &mut rec);
            let err: f64 = v
                .iter()
                .zip(&rec)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
                / vnorm;
            assert!(err < prev * 1.05, "bits={bits}: {err} !< {prev}");
            // Assumption 4.1 scaling: err ~ 2^-b (generous constant)
            assert!(err < 3.0 * 2f64.powi(-(bits as i32)), "bits={bits} err={err}");
            prev = err;
        }
    }

    #[test]
    fn search_never_worse_than_maxabs() {
        for seed in 0..10u64 {
            let v = randvec(256, seed);
            let vnorm2 = dot(&v, &v);
            let err_of = |mode| {
                let (codes, r) = quantize_column(&v, 3, mode);
                let mut rec = vec![0f32; v.len()];
                dequantize_column(&codes, r, 3, &mut rec);
                v.iter()
                    .zip(&rec)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    / vnorm2
            };
            let e_max = err_of(ScaleMode::MaxAbs);
            let e_search = err_of(ScaleMode::Search(8));
            assert!(e_search <= e_max + 1e-9, "seed={seed}: {e_search} > {e_max}");
        }
    }

    #[test]
    fn least_squares_rescale_is_optimal() {
        // perturbing r in either direction must not reduce the error
        let v = randvec(128, 5);
        let (codes, r) = quantize_column(&v, 4, ScaleMode::MaxAbs);
        let err_with = |rr: f32| {
            let mut rec = vec![0f32; v.len()];
            dequantize_column(&codes, rr, 4, &mut rec);
            v.iter().zip(&rec).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
        };
        let e0 = err_with(r);
        assert!(e0 <= err_with(r * 1.01) + 1e-9);
        assert!(e0 <= err_with(r * 0.99) + 1e-9);
    }

    #[test]
    fn estimate_ip_matches_dequantized_product() {
        let v = randvec(200, 6);
        let x = randvec(200, 7);
        let (codes, r) = quantize_column(&v, 4, ScaleMode::default());
        let est = estimate_ip(&x, &codes, r, 4);
        let mut rec = vec![0f32; v.len()];
        dequantize_column(&codes, r, 4, &mut rec);
        let direct = dot(&x, &rec);
        assert!((est - direct).abs() < 1e-3 * direct.abs().max(1.0));
    }

    #[test]
    fn error_bound_eq11_after_rotation() {
        // |<x,v> - est| < 3*c_err/(sqrt(d) 2^b) ||x|| ||v|| for >=98% of
        // random pairs, after RHT rotation (the bound's precondition).
        use crate::hadamard::PracticalRht;
        let d = 512;
        let mut rng = Rng::new(11);
        let rot = PracticalRht::sample(d, &mut rng);
        let mut violations = 0;
        let trials = 200;
        for s in 0..trials {
            let mut v = randvec(d, 100 + s);
            let mut x = randvec(d, 500 + s);
            rot.apply(&mut v);
            rot.apply(&mut x);
            for bits in [3u8, 5] {
                let (codes, r) = quantize_column(&v, bits, ScaleMode::default());
                let est = estimate_ip(&x, &codes, r, bits);
                let exact = dot(&x, &v);
                let bound = 3.0 * C_ERROR / ((d as f64).sqrt() * 2f64.powi(bits as i32))
                    * crate::tensor::norm(&x)
                    * crate::tensor::norm(&v);
                if (est - exact).abs() > bound {
                    violations += 1;
                }
            }
        }
        assert!(violations <= 2 * trials / 50, "violations={violations}");
    }

    #[test]
    fn packed_codes_roundtrip_all_bits() {
        let mut rng = Rng::new(13);
        for bits in 1..=8u8 {
            let max = (1u32 << bits) - 1;
            let values: Vec<u8> = (0..1000).map(|_| (rng.below(max as usize + 1)) as u8).collect();
            let packed = PackedCodes::pack(&values, bits);
            assert_eq!(packed.unpack(), values, "bits={bits}");
            assert_eq!(packed.stored_bits(), 1000 * bits as usize);
            assert!(packed.data.len() <= 1000 * bits as usize / 8 + 1);
        }
    }

    #[test]
    fn packed_get_random_access() {
        let values: Vec<u8> = (0..97).map(|i| (i % 8) as u8).collect();
        let packed = PackedCodes::pack(&values, 3);
        for (i, &v) in values.iter().enumerate() {
            assert_eq!(packed.get(i), v, "i={i}");
        }
    }

    #[test]
    fn quantized_matrix_roundtrip_and_est() {
        let mut rng = Rng::new(21);
        let m = Matrix::from_vec(64, 16, rng.gaussian_vec(64 * 16));
        let qm = QuantizedMatrix::quantize(&m, 6, ScaleMode::default(), 2);
        let rec = qm.dequantize();
        assert!(rec.rel_err(&m) < 0.1);
        // matmul_est == X @ dequantize
        let x = Matrix::from_vec(8, 64, rng.gaussian_vec(8 * 64));
        let est = qm.matmul_est(&x);
        let direct = x.matmul(&rec);
        assert!(est.rel_err(&direct) < 1e-4);
    }

    #[test]
    fn quantized_matrix_threads_agree() {
        let mut rng = Rng::new(22);
        let m = Matrix::from_vec(32, 24, rng.gaussian_vec(32 * 24));
        let a = QuantizedMatrix::quantize(&m, 3, ScaleMode::Search(4), 1);
        let b = QuantizedMatrix::quantize(&m, 3, ScaleMode::Search(4), 8);
        assert_eq!(a.codes.unpack(), b.codes.unpack());
        assert_eq!(a.r, b.r);
    }

    #[test]
    fn matmul_est_agrees_with_serial_reference() {
        let mut rng = Rng::new(31);
        for bits in [1u8, 3, 5, 8] {
            let m = Matrix::from_vec(90, 41, rng.gaussian_vec(90 * 41));
            let x = Matrix::from_vec(7, 90, rng.gaussian_vec(7 * 90));
            let qm = QuantizedMatrix::quantize(&m, bits, ScaleMode::MaxAbs, 2);
            let fused = qm.matmul_est(&x);
            let serial = qm.matmul_est_serial(&x);
            assert!(
                fused.rel_err(&serial) < 1e-4,
                "bits={bits} rel {}",
                fused.rel_err(&serial)
            );
        }
    }

    #[test]
    fn dequant_counter_increments() {
        // counter is process-global and unit tests run concurrently, so
        // only monotonic lower bounds are asserted here; the exact
        // zero-dequant-per-forward property is pinned down under a lock in
        // rust/tests/integration.rs.
        let mut rng = Rng::new(32);
        let m = Matrix::from_vec(16, 4, rng.gaussian_vec(64));
        let qm = QuantizedMatrix::quantize(&m, 4, ScaleMode::MaxAbs, 1);
        let before = dequant_calls();
        let _ = qm.dequantize();
        assert!(dequant_calls() >= before + 1);
    }

    #[test]
    fn stored_bits_accounting() {
        let mut rng = Rng::new(23);
        let m = Matrix::from_vec(128, 4, rng.gaussian_vec(128 * 4));
        let qm = QuantizedMatrix::quantize(&m, 2, ScaleMode::MaxAbs, 1);
        assert_eq!(qm.stored_bits(), 128 * 4 * 2 + 4 * 32);
    }
}
