//! RaanA quantization pipeline: RaBitQ-H per layer + tricks (paper App. C.3).
//!
//! Per registered linear layer W (d x c):
//!
//! 1. **Column outlier excluding** — the top `frac` input dimensions by
//!    calibration-activation column norm keep their weight *rows* in full
//!    precision (their products are computed exactly at inference).
//! 2. **Practical RHT** (paper Alg. 5) rotates the remaining rows'
//!    columns — works for any d, not just powers of two.
//! 3. **RaBitQ** grid-quantizes each rotated column at the layer's
//!    AllocateBits-assigned bit-width, with a least-squares rescale.
//! 4. **Centralization** — the rank-1 correction `1 s_hat^T (W - W_hat)`
//!    (s_hat = calibration mean input row) is exact and folds into the
//!    layer bias at dequantization, removing the quantization error along
//!    the mean-input direction.
//!
//! [`QuantizedLinear::reconstruct`] produces the effective weight + bias
//! the evaluation path feeds to the AOT `fwd_loss` artifact; the
//! Algorithm-3 streaming path ([`QuantizedLinear::forward_est`]) is the
//! serving-time estimator and is property-tested to agree with the
//! reconstruction exactly.
#![deny(missing_docs)]

use anyhow::Result;

use crate::hadamard::PracticalRht;
use crate::rabitq::{QuantizedMatrix, ScaleMode};
use crate::rng::Rng;
use crate::tensor::Matrix;

/// Trick configuration (paper App. C.3; defaults = the paper's setting:
/// Centralization + Column Outlier Excluding at 0.3%).
#[derive(Clone, Copy, Debug)]
pub struct TrickConfig {
    /// Remove the quantization error along the calibration mean-input
    /// direction via a rank-1 bias correction (paper App. C.3).
    pub centralization: bool,
    /// Fraction of input dimensions kept full-precision (paper: 0.003).
    pub col_outlier_frac: f64,
    /// Scale-selection mode for the RaBitQ grid.
    pub scale_mode: ScaleMode,
}

impl Default for TrickConfig {
    fn default() -> Self {
        TrickConfig {
            centralization: true,
            col_outlier_frac: 0.003,
            scale_mode: ScaleMode::default(),
        }
    }
}

impl TrickConfig {
    /// No tricks (for the ablation bench).
    pub fn none() -> Self {
        TrickConfig {
            centralization: false,
            col_outlier_frac: 0.0,
            scale_mode: ScaleMode::default(),
        }
    }
}

/// Per-layer calibration statistics consumed by the tricks.
#[derive(Clone, Debug)]
pub struct LayerCalib {
    /// Mean input row s(X) over calibration tokens (d,).
    pub mean_input: Vec<f32>,
    /// Per-input-dimension activation column norms (d,).
    pub col_norms: Vec<f64>,
}

impl LayerCalib {
    /// Reduce an (n x d) activation matrix to the statistics the tricks
    /// consume (column means and norms); the activations are not kept.
    pub fn from_activations(x: &Matrix) -> Self {
        LayerCalib { mean_input: x.col_means(), col_norms: x.col_norms() }
    }

    /// Zero stats (calibration-free operation: tricks become inert).
    pub fn zeros(d: usize) -> Self {
        LayerCalib { mean_input: vec![0.0; d], col_norms: vec![0.0; d] }
    }
}

/// A RaBitQ-H-quantized linear layer.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    /// Layer name (from the manifest's linear registry).
    pub name: String,
    /// Input dimension (weight rows).
    pub d: usize,
    /// Output dimension (weight columns).
    pub c: usize,
    /// AllocateBits-assigned code width for this layer.
    pub bits: u8,
    /// Input dimensions whose weight rows stay full precision, sorted.
    pub outlier_idx: Vec<u32>,
    /// Full-precision rows for the outlier dims (|O| x c).
    pub outlier_rows: Matrix,
    /// RHT over the remaining d_rest dims.
    pub rot: PracticalRht,
    /// RaBitQ codes of the rotated remaining rows (d_rest x c).
    pub qm: QuantizedMatrix,
    /// Calibration mean input (d,) — the centralization anchor.
    pub shat: Vec<f32>,
    /// Rank-1 centralization correction folded into the bias (c,).
    pub bias_corr: Vec<f32>,
    /// Precomputed serving constant `s_hat^T W_hat + bias_corr` (c,).
    /// Folding this at quantization time is what lets
    /// [`QuantizedLinear::forward_est`] run with zero full-matrix
    /// dequantization per forward (ISSUE 1 acceptance criterion).
    pub fold_const: Vec<f32>,
}

impl QuantizedLinear {
    /// Quantize `w` (d x c) at `bits`, using calibration stats for tricks.
    ///
    /// # Examples
    ///
    /// ```
    /// use raana::quant::{LayerCalib, QuantizedLinear, TrickConfig};
    /// use raana::rng::Rng;
    /// use raana::tensor::Matrix;
    ///
    /// let mut rng = Rng::new(7);
    /// let w = Matrix::from_vec(16, 4, rng.gaussian_vec(16 * 4));
    /// let ql = QuantizedLinear::quantize(
    ///     "demo", &w, 8, &LayerCalib::zeros(16), &TrickConfig::none(), &mut rng, 1,
    /// )
    /// .unwrap();
    ///
    /// // the serving estimator computes on packed codes, yet agrees with
    /// // a dense matmul against the reconstructed weights
    /// let x = Matrix::from_vec(2, 16, rng.gaussian_vec(2 * 16));
    /// let est = ql.forward_est(&x);
    /// let (w_hat, _corr) = ql.reconstruct();
    /// assert!(est.rel_err(&x.matmul(&w_hat)) < 1e-3);
    /// ```
    pub fn quantize(
        name: &str,
        w: &Matrix,
        bits: u8,
        calib: &LayerCalib,
        tricks: &TrickConfig,
        rng: &mut Rng,
        threads: usize,
    ) -> Result<Self> {
        let (d, c) = (w.rows, w.cols);
        anyhow::ensure!(calib.mean_input.len() == d, "calib dim mismatch");

        // 1. column-outlier selection on calibration activation norms
        let n_out = ((tricks.col_outlier_frac * d as f64).ceil() as usize).min(d.saturating_sub(2));
        let mut outlier_idx: Vec<u32> = if n_out > 0 {
            let mut order: Vec<usize> = (0..d).collect();
            order.sort_by(|&a, &b| {
                calib.col_norms[b].partial_cmp(&calib.col_norms[a]).unwrap()
            });
            let mut sel: Vec<u32> = order[..n_out].iter().map(|&i| i as u32).collect();
            sel.sort_unstable();
            sel
        } else {
            Vec::new()
        };
        // If calibration stats are all-zero the selection is arbitrary noise
        // — drop it (zero-shot-without-capture / tricks-off path).
        if calib.col_norms.iter().all(|&n| n == 0.0) {
            outlier_idx.clear();
        }

        let is_outlier = {
            let mut mask = vec![false; d];
            for &i in &outlier_idx {
                mask[i as usize] = true;
            }
            mask
        };
        let rest_idx: Vec<usize> = (0..d).filter(|&i| !is_outlier[i]).collect();
        let d_rest = rest_idx.len();

        let mut outlier_rows = Matrix::zeros(outlier_idx.len(), c);
        for (oi, &i) in outlier_idx.iter().enumerate() {
            outlier_rows.row_mut(oi).copy_from_slice(w.row(i as usize));
        }

        // 2. practical RHT over remaining rows
        let rot = PracticalRht::sample(d_rest, rng);
        let mut v = Matrix::zeros(d_rest, c);
        for (ri, &i) in rest_idx.iter().enumerate() {
            v.row_mut(ri).copy_from_slice(w.row(i));
        }
        rot.apply_columns(&mut v);

        // 3. RaBitQ grid quantization, parallel across columns
        let qm = QuantizedMatrix::quantize(&v, bits, tricks.scale_mode, threads);

        let mut ql = QuantizedLinear {
            name: name.to_string(),
            d,
            c,
            bits,
            outlier_idx,
            outlier_rows,
            rot,
            qm,
            shat: if tricks.centralization {
                calib.mean_input.clone()
            } else {
                vec![0.0; d]
            },
            bias_corr: vec![0.0; c],
            fold_const: vec![0.0; c],
        };

        // 4. centralization: bias correction (W - W_hat)^T s_hat, plus the
        // serving constant s_hat^T W_hat + bias_corr. Both come from one
        // dense reconstruction here, at quantization time — the serving
        // path then never dequantizes.
        if tricks.centralization {
            let w_hat = ql.effective_weight();
            let diff = w.sub(&w_hat);
            let mut corr = vec![0f32; c];
            let mut mean_term = vec![0f32; c];
            for i in 0..d {
                let s = ql.shat[i];
                if s == 0.0 {
                    continue;
                }
                for (j, (&dv, &wv)) in diff.row(i).iter().zip(w_hat.row(i)).enumerate() {
                    corr[j] += s * dv;
                    mean_term[j] += s * wv;
                }
            }
            ql.fold_const = corr.iter().zip(&mean_term).map(|(a, b)| a + b).collect();
            ql.bias_corr = corr;
        }
        Ok(ql)
    }

    /// Indices of the non-outlier input dims, in order.
    fn rest_idx(&self) -> Vec<usize> {
        let mut mask = vec![false; self.d];
        for &i in &self.outlier_idx {
            mask[i as usize] = true;
        }
        (0..self.d).filter(|&i| !mask[i]).collect()
    }

    /// The dense effective weight matrix W_hat (d x c): outlier rows exact,
    /// remaining rows = R^-1 dequantize(codes).
    pub fn effective_weight(&self) -> Matrix {
        let mut v_hat = self.qm.dequantize();
        self.rot.apply_inverse_columns(&mut v_hat);
        let mut out = Matrix::zeros(self.d, self.c);
        for (ri, &i) in self.rest_idx().iter().enumerate() {
            out.row_mut(i).copy_from_slice(v_hat.row(ri));
        }
        for (oi, &i) in self.outlier_idx.iter().enumerate() {
            out.row_mut(i as usize).copy_from_slice(self.outlier_rows.row(oi));
        }
        out
    }

    /// Reconstruct (effective weight, effective extra bias): the evaluation
    /// path replaces the layer's (W, b) with (W_hat, b + bias_corr).
    pub fn reconstruct(&self) -> (Matrix, Vec<f32>) {
        (self.effective_weight(), self.bias_corr.clone())
    }

    /// Serving-path estimator (paper Alg. 3 + tricks): estimate X @ W + corr
    /// directly from codes.  X is (n x d) *unrotated* activations.
    ///
    /// Exactly equals `X @ effective_weight() + 1 bias_corr^T` (tested),
    /// but performs **zero full-matrix dequantization**: the quantized
    /// product runs on packed codes via [`crate::kernels::qgemm`] and the
    /// mean-direction constant is the precomputed
    /// [`QuantizedLinear::fold_const`].
    pub fn forward_est(&self, x: &Matrix) -> Matrix {
        self.forward_est_threaded(x, 0)
    }

    /// [`QuantizedLinear::forward_est`] with an explicit thread count
    /// (0 = default / `RAANA_THREADS`). Bit-deterministic in `threads`.
    pub fn forward_est_threaded(&self, x: &Matrix, threads: usize) -> Matrix {
        assert_eq!(x.cols, self.d);
        let rest = self.rest_idx();
        let n = x.rows;

        // centered, gathered, rotated activations
        let mut xr = Matrix::zeros(n, rest.len());
        for i in 0..n {
            let xrow = x.row(i);
            let xrrow = xr.row_mut(i);
            for (rj, &j) in rest.iter().enumerate() {
                xrrow[rj] = xrow[j] - self.shat[j];
            }
        }
        self.rot.apply_rows_threaded(&mut xr, threads);

        // fused packed-code product on centered-rotated activations
        let mut y = crate::kernels::qgemm(&xr, &self.qm, threads);

        // exact outlier product (also centered)
        for i in 0..n {
            let xrow = x.row(i);
            for (oi, &j) in self.outlier_idx.iter().enumerate() {
                let xv = xrow[j as usize] - self.shat[j as usize];
                if xv == 0.0 {
                    continue;
                }
                let orow = self.outlier_rows.row(oi);
                let yrow = y.row_mut(i);
                for (o, &wv) in yrow.iter_mut().zip(orow) {
                    *o += xv * wv;
                }
            }
        }

        // mean-direction constant: X W_hat + 1 s_hat^T (W - W_hat)
        //   = (X - 1 s_hat^T) W_hat + 1 (s_hat^T W_hat + bias_corr),
        // with the second term precomputed at quantization time.
        for i in 0..n {
            for (o, &fc) in y.row_mut(i).iter_mut().zip(&self.fold_const) {
                *o += fc;
            }
        }
        y
    }

    /// Total stored bits including every side payload the paper's "avg
    /// bits" accounting would have to count: codes, rescales, RHT signs,
    /// outlier rows + indices, centering vector, bias correction. Side
    /// scalars are counted at fp16 (how a deployment stores them; the fp32
    /// in-memory copies here are a simulator convenience).
    pub fn stored_bits(&self) -> usize {
        let mut bits = self.qm.codes.stored_bits();
        bits += self.c * 16; // rescale r per column, fp16
        bits += self.rot.stored_bits(); // 1 bit per Rademacher sign
        bits += self.outlier_rows.rows * self.c * 16;
        bits += self.outlier_idx.len() * 16; // d < 2^16 always here
        if self.shat.iter().any(|&s| s != 0.0) {
            bits += self.d * 16; // s_hat
            bits += self.c * 16; // bias_corr
        }
        bits
    }

    /// Average bits per original weight parameter.
    pub fn avg_bits(&self) -> f64 {
        self.stored_bits() as f64 / (self.d * self.c) as f64
    }

    /// Relative Frobenius reconstruction error vs the original weights.
    pub fn recon_rel_err(&self, w: &Matrix) -> f64 {
        self.effective_weight().rel_err(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_w(d: usize, c: usize, seed: u64) -> Matrix {
        Matrix::from_vec(d, c, Rng::new(seed).gaussian_vec(d * c))
    }

    fn random_calib(d: usize, n: usize, seed: u64) -> LayerCalib {
        let x = Matrix::from_vec(n, d, Rng::new(seed).gaussian_vec(n * d));
        LayerCalib::from_activations(&x)
    }

    #[test]
    fn quantize_reconstruct_error_scales_with_bits() {
        let w = random_w(128, 64, 1);
        let calib = random_calib(128, 32, 2);
        let mut prev = f64::INFINITY;
        for bits in [2u8, 4, 6, 8] {
            let mut rng = Rng::new(3);
            let ql = QuantizedLinear::quantize(
                "t", &w, bits, &calib, &TrickConfig::default(), &mut rng, 2,
            )
            .unwrap();
            let err = ql.recon_rel_err(&w);
            assert!(err < prev, "bits={bits}: {err} !< {prev}");
            assert!(err < 3.0 * 2f64.powi(-(bits as i32)), "bits={bits} err={err}");
            prev = err;
        }
    }

    #[test]
    fn outlier_rows_are_exact() {
        let w = random_w(100, 16, 4);
        let mut calib = random_calib(100, 8, 5);
        // force dims 7 and 42 to be the outliers
        for n in calib.col_norms.iter_mut() {
            *n = 1.0;
        }
        calib.col_norms[7] = 100.0;
        calib.col_norms[42] = 90.0;
        let mut tricks = TrickConfig::default();
        tricks.col_outlier_frac = 0.02; // ceil(2) = 2 outliers
        let mut rng = Rng::new(6);
        let ql = QuantizedLinear::quantize("t", &w, 2, &calib, &tricks, &mut rng, 1).unwrap();
        assert_eq!(ql.outlier_idx, vec![7, 42]);
        let w_hat = ql.effective_weight();
        for &i in &[7usize, 42] {
            for j in 0..16 {
                assert_eq!(w_hat.at(i, j), w.at(i, j), "row {i} col {j}");
            }
        }
    }

    #[test]
    fn forward_est_equals_reconstructed_matmul() {
        let d = 96; // non-power-of-2 exercises practical RHT
        let w = random_w(d, 32, 7);
        let calib = random_calib(d, 16, 8);
        let mut rng = Rng::new(9);
        let ql = QuantizedLinear::quantize(
            "t", &w, 4, &calib, &TrickConfig::default(), &mut rng, 2,
        )
        .unwrap();
        let x = Matrix::from_vec(8, d, Rng::new(10).gaussian_vec(8 * d));
        let est = ql.forward_est(&x);
        let (w_hat, corr) = ql.reconstruct();
        let mut want = x.matmul(&w_hat);
        for i in 0..want.rows {
            for j in 0..want.cols {
                *want.at_mut(i, j) += corr[j];
            }
        }
        assert!(est.rel_err(&want) < 1e-3, "rel {}", est.rel_err(&want));
    }

    #[test]
    fn centralization_removes_mean_direction_error() {
        // with x == s_hat exactly, the quantized layer output must be exact
        let d = 64;
        let w = random_w(d, 16, 11);
        let calib = random_calib(d, 32, 12);
        let mut rng = Rng::new(13);
        let ql = QuantizedLinear::quantize(
            "t", &w, 2, &calib, &TrickConfig::default(), &mut rng, 1,
        )
        .unwrap();
        let mut x = Matrix::zeros(1, d);
        x.row_mut(0).copy_from_slice(&calib.mean_input);
        let est = ql.forward_est(&x);
        let want = x.matmul(&w);
        assert!(
            est.rel_err(&want) < 1e-4,
            "centered input should be exact: {}",
            est.rel_err(&want)
        );
    }

    #[test]
    fn tricks_off_means_no_side_payload() {
        let w = random_w(64, 16, 14);
        let calib = LayerCalib::zeros(64);
        let mut rng = Rng::new(15);
        let ql = QuantizedLinear::quantize(
            "t", &w, 3, &calib, &TrickConfig::none(), &mut rng, 1,
        )
        .unwrap();
        assert!(ql.outlier_idx.is_empty());
        assert!(ql.bias_corr.iter().all(|&b| b == 0.0));
        // avg bits = 3 + rescale/sign overhead only: 16*c + d bits over d*c
        let overhead = ql.avg_bits() - 3.0;
        let expected = (16.0 * 16.0 + 64.0) / (64.0 * 16.0);
        assert!((overhead - expected).abs() < 1e-9, "overhead {overhead}");
    }

    #[test]
    fn avg_bits_accounting_with_tricks() {
        let w = random_w(256, 128, 16);
        let calib = random_calib(256, 32, 17);
        let mut rng = Rng::new(18);
        let ql = QuantizedLinear::quantize(
            "t", &w, 2, &calib, &TrickConfig::default(), &mut rng, 2,
        )
        .unwrap();
        let avg = ql.avg_bits();
        // 2-bit codes + tricks: overhead in the paper's 0.1-0.3 band for
        // realistic layer sizes (256x128 here is on the small side)
        assert!(avg > 2.0 && avg < 2.45, "avg {avg}");
    }

    #[test]
    fn deterministic_given_seed() {
        let w = random_w(64, 8, 19);
        let calib = random_calib(64, 8, 20);
        let q = |seed| {
            let mut rng = Rng::new(seed);
            QuantizedLinear::quantize(
                "t", &w, 3, &calib, &TrickConfig::default(), &mut rng, 4,
            )
            .unwrap()
            .effective_weight()
        };
        assert_eq!(q(7).data, q(7).data);
        assert_ne!(q(7).data, q(8).data); // different RHT signs
    }

    #[test]
    fn quantize_rejects_dim_mismatch() {
        let w = random_w(32, 8, 21);
        let calib = LayerCalib::zeros(16);
        let mut rng = Rng::new(22);
        assert!(QuantizedLinear::quantize(
            "t", &w, 3, &calib, &TrickConfig::default(), &mut rng, 1
        )
        .is_err());
    }

    #[test]
    fn one_bit_quantization_works() {
        let w = random_w(128, 16, 23);
        let calib = random_calib(128, 16, 24);
        let mut rng = Rng::new(25);
        let ql = QuantizedLinear::quantize(
            "t", &w, 1, &calib, &TrickConfig::default(), &mut rng, 1,
        )
        .unwrap();
        let err = ql.recon_rel_err(&w);
        assert!(err < 1.0, "1-bit err {err}"); // sign quantization: still informative
    }
}
