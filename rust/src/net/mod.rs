//! HTTP/1.1 front-end for the batching server — the layer where packed-code
//! serving meets an actual network workload.
//!
//! Std-only by construction (no cargo registry in the build environment):
//! `std::net` sockets, the crate's own [`crate::threadpool::Pool`] for
//! connection handling, and the hardened [`crate::json`] parser for the
//! (untrusted) request bodies. One request per connection, `Connection:
//! close` — the simplest protocol subset that real clients (curl, the
//! loopback tests, `examples/http_client.rs`) speak without negotiation.
//!
//! # Endpoints
//!
//! * `POST /v1/generate` — body `{"prompt": [ints], "max_new_tokens": N,
//!   "temperature": T, "seed": S, "stream": bool}` (every field optional;
//!   defaults `[] / 16 / 0.0 / 0 / false`). Non-streaming responses are one
//!   JSON object mirroring [`Completion`]. With `"stream": true` the
//!   response is `Transfer-Encoding: chunked`: one chunk per sampled token
//!   (`{"id":..,"index":..,"token":..}\n`), then a final chunk with
//!   `"done": true` and the full token list. A full admission queue maps
//!   to **429**, a shut-down server to **503**, an unservable request
//!   (e.g. out-of-vocab prompt token) to **400**.
//! * `GET /healthz` — liveness: `{"ok":true,"running":bool,"state":
//!   "ok"|"draining"}`. `"draining"` is published when the process is
//!   winding down ([`HttpConfig::drain`]): the node still answers
//!   everything, but a cluster router stops sending it *new* work.
//! * `GET /v1/stats` — live [`ServerStats`] snapshot, readable **while
//!   generation is in flight**. Includes the admission-queue depth
//!   (republished per batcher round) and the KV-cache economics:
//!   `kv_bits` (32 = dense f32), `kv_bytes_per_lane`, and the lane
//!   pool's size (`lanes`) and occupancy (`lanes_active`). With an
//!   index attached, also `index_durable` / `index_read_only`, the
//!   segment accounting `index_segments` / `index_head_rows` /
//!   `index_compactions`, and — when the store was opened from a data
//!   dir — the recovery accounting `recovered_rows` /
//!   `dropped_records`.
//!
//! With an [`IndexServer`] attached ([`HttpServer::bind_with_index`]),
//! the retrieval workload rides the same front-end:
//!
//! * `POST /v1/embed` — body `{"text": "..."}` or `{"tokens": [ints]}`;
//!   answers `{"embedding": [f32...], "dim": N}` (mean-pooled,
//!   L2-normalized final hidden states, truncated to the model window).
//! * `POST /v1/collections/{name}/add` — body `{"vectors": [[f32...],
//!   ...]}`, or `{"texts": [...]}` / `{"tokens": [[ints], ...]}` to
//!   embed server-side; answers `{"collection", "ids", "count"}`. A
//!   budget-policy store that cannot fit the rows refuses with **507**.
//!   An optional `"expect_first_id": N` makes the add conditional: if
//!   the collection does not hold exactly `N` rows the request is
//!   refused with **409** and nothing is applied — the exactly-once
//!   handshake a retrying cluster router needs (a 409 on a retry means
//!   the first attempt landed).
//! * `POST /v1/collections/{name}/query` — body `{"vector": [f32...]}`
//!   (or `"text"` / `"tokens"`), optional `"k"` (default 10) and
//!   `"rerank_factor"` (default 4); answers `{"results": [{"id",
//!   "score"}, ...]}` — estimated scan over packed codes, exact rerank.
//! * `POST /v1/collections/{name}/scan` — phase one of a distributed
//!   query: body `{"vector": [f32...], "take": N}`; answers
//!   `{"collection", "rows", "take", "candidates": [{"id","score"},
//!   ...]}` with the top-`take` rows by **estimated** score, ordered
//!   (score desc, id asc) exactly like the internal candidate
//!   selection. `rows` is this node's local row count.
//! * `POST /v1/collections/{name}/rerank` — phase two: body
//!   `{"vector": [f32...], "ids": [ints]}`; answers `{"collection",
//!   "results"}` with **exact** scores for precisely those rows, in
//!   input order. A cluster router scans every shard, merges the
//!   estimated candidates, and reranks the winners on their owning
//!   shards — reproducing a single node's query bit-for-bit.
//! * `GET /v1/collections` — per-collection bits/bytes/row counts plus
//!   the index serving counters.
//!
//! Without an index attached these paths answer 404. Under overflow
//! (pinned worker pool) the POST index endpoints refuse with 503 like
//! generation — they run model/scan compute — while `GET
//! /v1/collections` stays live next to `/healthz` and `/v1/stats`.
//!
//! # Error shape
//!
//! Every error response on every path —
//! 400/404/405/408/409/413/429/500/503/507 — is the same single-key JSON
//! object `{"error": "..."}` (loopback-tested across all of them),
//! every 405 names the allowed methods in an `Allow:` header per RFC
//! 9110, and the transient refusals (429/503) advertise `Retry-After:
//! 1` so well-behaved clients back off instead of hammering admission.
//! A peer that stalls mid-request past the socket read timeout (a
//! slow-loris client, a dead link) gets a typed **408** instead of a
//! pinned worker.
//!
//! # Cancellation
//!
//! Streamed responses are flushed per token, so a client that disconnects
//! is detected at the next chunk write; non-streaming responses write
//! nothing until completion, so their handler probes the socket for EOF
//! between token events instead. Either way the handler fires the
//! request's [`crate::serve::CancelToken`] and the batcher frees the KV
//! lane mid-flight — a dropped connection never strands a lane
//! (loopback-tested). Deliberate protocol choice: a **half-close**
//! (client `shutdown(SHUT_WR)` after sending the request) is treated the
//! same as a disconnect — this server's clients must keep their socket
//! fully open until they have read the response.
//!
//! # Backpressure
//!
//! Layered and always explicit: the batching server's bounded admission
//! queue maps to **429** (request-level). When every pool worker is
//! pinned by a long-lived generation, new connections are handed to a
//! bounded set of short-lived **overflow handlers** that still answer the
//! cheap endpoints (`/healthz`, `/v1/stats` keep working under full
//! load — liveness probes must not fail on a busy-but-healthy server)
//! and refuse only `POST /v1/generate`, with **503** — after reading the
//! request, so the client sees the response rather than a connection
//! reset. Nothing ever queues silently in the pool's unbounded channel;
//! past the overflow bound the connection is dropped outright.
//!
//! # Shutdown
//!
//! [`HttpServer::shutdown`] is a SIGTERM-style graceful drain: stop
//! accepting, finish every in-flight connection, return. The batching
//! [`Server`] underneath is owned via `Arc` and shut down by the caller
//! afterwards, so queued work still completes.
//!
//! # Observability
//!
//! `GET /metrics` renders the process-wide [`crate::obs`] registry as
//! Prometheus text exposition and — like `/healthz` and `/v1/stats` —
//! stays live under overflow: a scrape must not fail on a
//! busy-but-healthy server. Every request carries a request id (inbound
//! `X-Request-Id` when valid, minted otherwise) that is echoed as an
//! `X-Request-Id` response header on **every** path, success and error
//! alike (400/404/405/408/409/413/429/500/503/507 and the streaming
//! head), and attached by the in-crate HTTP client to outgoing
//! requests — including every attempt of [`http_request_retry_with`],
//! which mints one id up front when the caller has none, so all
//! attempts of one logical request correlate.
//!
//! # Limits
//!
//! Request heads are capped at [`MAX_HEAD_BYTES`], bodies at
//! [`MAX_BODY_BYTES`], and every request's `max_new_tokens` is clamped to
//! [`HttpConfig::max_new_tokens_cap`] (default
//! [`DEFAULT_MAX_NEW_TOKENS_CAP`]) so one patient client cannot pin a KV
//! lane for an unbounded generation; socket reads time out so half-open
//! peers cannot pin a worker forever. These caps plus the JSON parser's
//! depth/number caps are the entire attack surface budget of this
//! front-end.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::tokenize;
use crate::index::{IndexError, DEFAULT_RERANK_FACTOR};
use crate::json::{self, Value};
use crate::obs::{self, trace};
use crate::serve::index::IndexServer;
use crate::serve::{AdmitError, Completion, Server, ServerStats, StreamEvent, StreamHandle};
use crate::threadpool::{default_threads, Pool};

/// Maximum accepted size of a request line + headers, in bytes.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Maximum accepted request-body size, in bytes (prompts are token-id
/// arrays; 1 MiB of JSON is far beyond any real prompt for these models).
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// Default socket read timeout (see [`HttpConfig::read_timeout_ms`]): a
/// peer that stops sending mid-request — the slow-loris shape — is
/// answered with a typed **408** and dropped rather than pinning a
/// connection worker forever.
const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// Socket write timeout for responses and stream chunks.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Default server-side clamp on a request's `max_new_tokens` (see
/// [`HttpConfig::max_new_tokens_cap`]).
pub const DEFAULT_MAX_NEW_TOKENS_CAP: usize = 4096;

/// Most overflow handlers alive at once (see the module's *Backpressure*
/// section); connections beyond this while the pool is pinned are
/// dropped without a response — the genuinely-overloaded regime.
const OVERFLOW_HANDLERS_MAX: usize = 32;

/// Construction options for [`HttpServer::bind_with`].
#[derive(Clone, Debug)]
pub struct HttpConfig {
    /// Connection-handler pool size; `0` means [`default_threads`]
    /// (min 4). Long-lived streaming connections each occupy a worker,
    /// so size the pool to the expected concurrency.
    pub workers: usize,
    /// Server-side clamp applied to every request's `max_new_tokens`
    /// (`0` means [`DEFAULT_MAX_NEW_TOKENS_CAP`]): the generation still
    /// succeeds, truncated — it just cannot pin a KV lane indefinitely.
    pub max_new_tokens_cap: usize,
    /// Socket read timeout in milliseconds for request heads and bodies
    /// (`0` means the 10 s default): the slow-loris guard. A connection
    /// that trickles or stalls its request past this deadline gets a
    /// typed **408** and is closed. Tests shrink it to exercise the
    /// guard without waiting out the production default.
    pub read_timeout_ms: u64,
    /// Optional drain flag for cluster workers: while set, `GET
    /// /healthz` answers `"state":"draining"` (instead of `"ok"`) so a
    /// router's next probe routes new generate traffic elsewhere;
    /// everything else keeps serving — in-flight and already-routed
    /// requests finish normally, which is what makes a drain lose no
    /// requests. `None` (the default) always reports `"ok"`.
    pub drain: Option<Arc<AtomicBool>>,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig { workers: 0, max_new_tokens_cap: 0, read_timeout_ms: 0, drain: None }
    }
}

/// Handle for a running HTTP front-end.
///
/// Binds a listener, spawns an accept loop, and serves each connection on
/// a fixed [`Pool`] of workers. Dropping the handle performs the same
/// graceful drain as [`HttpServer::shutdown`].
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
    /// Live overflow-handler count — their threads are detached, so the
    /// drain must wait on this before the `Arc<Server>` clones they hold
    /// are guaranteed gone (see [`HttpServer::shutdown`]).
    overflow: Arc<AtomicUsize>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:8080"`, or port `0` for an ephemeral
    /// port — see [`HttpServer::local_addr`]) and start serving `server`
    /// with `workers` connection handlers (`0` = default) and the default
    /// `max_new_tokens` clamp. See [`HttpServer::bind_with`].
    pub fn bind(server: Arc<Server>, addr: &str, workers: usize) -> Result<HttpServer> {
        HttpServer::bind_with(server, addr, HttpConfig { workers, ..Default::default() })
    }

    /// [`HttpServer::bind`] with explicit [`HttpConfig`] (no index
    /// endpoints — they answer 404).
    pub fn bind_with(server: Arc<Server>, addr: &str, cfg: HttpConfig) -> Result<HttpServer> {
        HttpServer::bind_with_index(server, None, addr, cfg)
    }

    /// [`HttpServer::bind_with`] plus an optional [`IndexServer`]: when
    /// supplied, `/v1/embed` and `/v1/collections/...` serve the
    /// retrieval workload from the same connection pool (index calls run
    /// directly on the connection workers — see
    /// [`crate::serve::index`]).
    pub fn bind_with_index(
        server: Arc<Server>,
        index: Option<Arc<IndexServer>>,
        addr: &str,
        cfg: HttpConfig,
    ) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding HTTP listener on {addr}"))?;
        let local = listener.local_addr().context("resolving bound address")?;
        // Non-blocking accept so the loop can observe the stop flag; 5 ms
        // poll keeps shutdown latency negligible next to a model step.
        listener.set_nonblocking(true).context("setting listener non-blocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let overflow = Arc::new(AtomicUsize::new(0));
        let overflow2 = Arc::clone(&overflow);
        let workers = if cfg.workers == 0 { default_threads().max(4) } else { cfg.workers };
        let cap = if cfg.max_new_tokens_cap == 0 {
            DEFAULT_MAX_NEW_TOKENS_CAP
        } else {
            cfg.max_new_tokens_cap
        };
        let read_timeout = if cfg.read_timeout_ms == 0 {
            READ_TIMEOUT
        } else {
            Duration::from_millis(cfg.read_timeout_ms)
        };
        let drain = cfg.drain.clone();
        let accept = thread::spawn(move || {
            let pool = Pool::new(workers);
            // Connection-level backpressure: the pool's submission channel
            // is unbounded, so connections past the worker count must not
            // be submitted (they would queue silently with no response at
            // all). Instead a bounded set of short-lived overflow threads
            // still answers cheap endpoints and refuses generation with a
            // real 503 (request drained first, so no RST race).
            let active = Arc::new(AtomicUsize::new(0));
            loop {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                match listener.accept() {
                    Ok((conn, _peer)) => {
                        if active.load(Ordering::SeqCst) < workers {
                            active.fetch_add(1, Ordering::SeqCst);
                            let srv = Arc::clone(&server);
                            let ix = index.clone();
                            let act = Arc::clone(&active);
                            let dr = drain.clone();
                            pool.submit(move || {
                                handle_connection(
                                    &srv,
                                    ix.as_deref(),
                                    dr.as_deref(),
                                    conn,
                                    cap,
                                    read_timeout,
                                    false,
                                );
                                act.fetch_sub(1, Ordering::SeqCst);
                            });
                        } else if overflow2.load(Ordering::SeqCst) < OVERFLOW_HANDLERS_MAX {
                            overflow2.fetch_add(1, Ordering::SeqCst);
                            let srv = Arc::clone(&server);
                            let ix = index.clone();
                            let ovf = Arc::clone(&overflow2);
                            let dr = drain.clone();
                            // detached: lifetime bounded by the socket
                            // read/write timeouts, work bounded to cheap
                            // endpoints + one 503. The Arc<Server> clone
                            // MUST drop before the counter decrements —
                            // shutdown uses the counter as the fence for
                            // "no overflow thread still holds the server".
                            thread::spawn(move || {
                                handle_connection(
                                    &srv,
                                    ix.as_deref(),
                                    dr.as_deref(),
                                    conn,
                                    cap,
                                    read_timeout,
                                    true,
                                );
                                drop(srv);
                                drop(ix);
                                ovf.fetch_sub(1, Ordering::SeqCst);
                            });
                        } else {
                            // genuinely overloaded: drop without response
                            drop(conn);
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => thread::sleep(Duration::from_millis(5)),
                }
            }
            // dropping the pool joins its workers after they finish every
            // already-accepted connection: the graceful drain
            drop(pool);
        });
        Ok(HttpServer { addr: local, stop, accept: Some(accept), overflow })
    }

    /// The actually-bound address (resolves ephemeral port requests).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful drain: stop accepting new connections, let every in-flight
    /// request finish — pool workers via the pool join, detached overflow
    /// handlers via their counter (their lifetime is bounded by the socket
    /// timeouts) — then return. Afterwards no thread of this front-end
    /// holds an `Arc<Server>` clone, so the caller's
    /// `Arc::try_unwrap(server)` is race-free. The underlying [`Server`]
    /// keeps running — shut it down separately once the last front-end is
    /// gone.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        let joined = match self.accept.take() {
            Some(h) => h.join().map_err(|_| anyhow!("HTTP accept loop panicked")),
            None => Ok(()),
        };
        self.drain_overflow();
        joined
    }

    /// Wait (bounded by the socket timeouts, plus slack) for detached
    /// overflow handlers to finish and release their server handles.
    fn drain_overflow(&self) {
        for _ in 0..6000 {
            if self.overflow.load(Ordering::SeqCst) == 0 {
                return;
            }
            thread::sleep(Duration::from_millis(10));
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.drain_overflow();
    }
}

// ------------------------------------------------------------ request path

/// One parsed request, server side. `pub(crate)` so the cluster router
/// ([`crate::cluster`]) can serve its own routes on this same stack.
pub(crate) struct HttpRequest {
    pub(crate) method: String,
    pub(crate) path: String,
    pub(crate) headers: Vec<(String, String)>,
    pub(crate) body: Vec<u8>,
}

/// Request-read failure with the HTTP status it maps to (400 for
/// malformed/truncated requests, 413 for over-cap bodies).
pub(crate) struct HttpError {
    pub(crate) status: u16,
    pub(crate) msg: String,
}

impl HttpError {
    fn bad<M: std::fmt::Display>(msg: M) -> HttpError {
        HttpError { status: 400, msg: msg.to_string() }
    }
}

pub(crate) fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

/// Read one `\n`-terminated line, bounded by the remaining head budget.
fn read_line_capped(reader: &mut BufReader<TcpStream>, total: &mut usize) -> Result<String> {
    let mut buf = Vec::new();
    let budget = (MAX_HEAD_BYTES - *total + 1) as u64;
    let n = reader.by_ref().take(budget).read_until(b'\n', &mut buf)?;
    if n == 0 {
        bail!("connection closed mid-request");
    }
    *total += n;
    anyhow::ensure!(
        buf.last() == Some(&b'\n') && *total <= MAX_HEAD_BYTES,
        "request head truncated or larger than {MAX_HEAD_BYTES} bytes"
    );
    String::from_utf8(buf).map_err(|_| anyhow!("non-UTF-8 bytes in request head"))
}

/// Classify a head-read failure: a socket read timeout means the client
/// stalled mid-request (a slow-loris peer, or just a dead link), which
/// gets a typed 408 so it is distinguishable from a malformed request.
fn head_error(e: anyhow::Error) -> HttpError {
    if let Some(ioe) = e.downcast_ref::<std::io::Error>() {
        if matches!(
            ioe.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        ) {
            return HttpError {
                status: 408,
                msg: "timed out reading request head".to_string(),
            };
        }
    }
    HttpError::bad(e)
}

pub(crate) fn read_request(stream: &TcpStream) -> Result<HttpRequest, HttpError> {
    let mut reader =
        BufReader::new(stream.try_clone().map_err(|e| HttpError::bad(format!("{e}")))?);
    let mut total = 0usize;
    let line = read_line_capped(&mut reader, &mut total).map_err(head_error)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| HttpError::bad("empty request line"))?.to_string();
    let path =
        parts.next().ok_or_else(|| HttpError::bad("request line missing path"))?.to_string();
    let version = parts.next().unwrap_or("HTTP/1.0");
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::bad(format!("unsupported version '{version}'")));
    }

    let mut headers = Vec::new();
    loop {
        let line = read_line_capped(&mut reader, &mut total).map_err(head_error)?;
        let trimmed = line.trim_end_matches(['\r', '\n']);
        if trimmed.is_empty() {
            break;
        }
        let (k, v) = trimmed
            .split_once(':')
            .ok_or_else(|| HttpError::bad("malformed header line"))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }

    // the head parsed: adopt the caller's id NOW, so even refusals decided
    // below (over-cap 413, body timeout 408) echo it instead of minting
    trace::set_current_rid(Some(trace::admit_rid(header(&headers, "x-request-id"))));

    let content_length = match header(&headers, "content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| HttpError::bad(format!("bad content-length '{v}'")))?,
    };
    if content_length > MAX_BODY_BYTES {
        return Err(HttpError {
            status: 413,
            msg: format!(
                "body of {content_length} bytes exceeds the {MAX_BODY_BYTES}-byte cap"
            ),
        });
    }
    // curl sends `Expect: 100-continue` for bodies over ~1 KiB and stalls
    // ~1 s waiting for the interim response; acknowledge so a long-prompt
    // POST does not pay that latency (only once the body passed the cap)
    if content_length > 0 {
        if let Some(v) = header(&headers, "expect") {
            if v.eq_ignore_ascii_case("100-continue") {
                let mut w: &TcpStream = stream;
                let _ = w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n");
                let _ = w.flush();
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).map_err(|e| {
        if matches!(
            e.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        ) {
            HttpError { status: 408, msg: "timed out reading request body".to_string() }
        } else {
            HttpError::bad(format!("reading request body: {e}"))
        }
    })?;
    Ok(HttpRequest { method, path, headers, body })
}

/// Serve one connection. `overflow` marks the pinned-pool path: cheap
/// endpoints are still answered, but generation — and the index's POST
/// compute paths — are refused with 503 (after the request was read, so
/// the refusal actually reaches the client instead of being discarded
/// by an RST).
fn handle_connection(
    server: &Server,
    index: Option<&IndexServer>,
    drain: Option<&AtomicBool>,
    mut stream: TcpStream,
    cap: usize,
    read_timeout: Duration,
    overflow: bool,
) {
    // the listener is non-blocking for the stop-flag poll; accepted
    // sockets must not inherit that (they do on some BSDs)
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(read_timeout));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let req = match read_request(&stream) {
        Ok(r) => r,
        Err(e) => {
            // even a request we failed to read gets a correlatable id: if
            // the head parsed, read_request installed the inbound one and
            // this refusal echoes it — otherwise mint outright
            if trace::current_rid().is_none() {
                trace::set_current_rid(Some(trace::mint_rid()));
            }
            let _ = respond_error(&mut stream, e.status, &e.msg);
            trace::set_current_rid(None);
            // The client may still be mid-send (e.g. a 413 refused before
            // its body arrived). Closing with unread bytes in the receive
            // buffer can RST the queued response away, so: FIN our write
            // side first (the response is delivered), then drain reads
            // until EOF — bounded by the byte budget and the read timeout.
            let _ = stream.shutdown(Shutdown::Write);
            let mut scratch = [0u8; 8192];
            let mut r: &TcpStream = &stream;
            let mut budget = 2 * MAX_BODY_BYTES;
            while budget > 0 {
                match r.read(&mut scratch) {
                    Ok(0) | Err(_) => break,
                    Ok(n) => budget = budget.saturating_sub(n),
                }
            }
            return;
        }
    };
    // admission: adopt a valid inbound X-Request-Id or mint one; the
    // ambient id is echoed by every response writer below and attached
    // to any RPC this thread issues while serving the request
    trace::set_current_rid(Some(trace::admit_rid(header(&req.headers, "x-request-id"))));
    obs::metrics().http_requests.inc();
    dispatch_request(server, index, drain, &mut stream, cap, overflow, &req);
    trace::set_current_rid(None);
}

/// Route one parsed request (the ambient request id is installed).
fn dispatch_request(
    server: &Server,
    index: Option<&IndexServer>,
    drain: Option<&AtomicBool>,
    stream: &mut TcpStream,
    cap: usize,
    overflow: bool,
    req: &HttpRequest,
) {
    let mut stream = stream;
    let method = req.method.as_str();
    match req.path.as_str() {
        "/healthz" => match method {
            "GET" => {
                let draining = drain.is_some_and(|d| d.load(Ordering::SeqCst));
                let body = json::obj(vec![
                    ("ok", Value::Bool(true)),
                    ("running", Value::Bool(server.is_running())),
                    ("state", json::s(if draining { "draining" } else { "ok" })),
                ]);
                let _ = respond(&mut stream, 200, "OK", &body.to_json());
            }
            _ => {
                let _ = respond_method_not_allowed(&mut stream, method, "GET");
            }
        },
        "/v1/stats" => match method {
            "GET" => {
                let _ = respond(&mut stream, 200, "OK", &stats_json(server, index).to_json());
            }
            _ => {
                let _ = respond_method_not_allowed(&mut stream, method, "GET");
            }
        },
        // scrape endpoint: like /healthz, stays live under overflow — a
        // Prometheus scrape must not fail on a busy-but-healthy server
        "/metrics" => match method {
            "GET" => {
                let _ = respond_text(&mut stream, 200, "OK", &obs::metrics().registry.render());
            }
            _ => {
                let _ = respond_method_not_allowed(&mut stream, method, "GET");
            }
        },
        "/v1/generate" => match method {
            "POST" if overflow => {
                let _ =
                    respond_error(&mut stream, 503, "all connection workers busy, retry later");
            }
            "POST" => handle_generate(server, &mut stream, &req.body, cap),
            _ => {
                let _ = respond_method_not_allowed(&mut stream, method, "POST");
            }
        },
        "/v1/embed" => match method {
            // no index attached beats overflow: the path genuinely does
            // not exist on this deployment, so 404 — retrying is useless
            "POST" if overflow && index.is_some() => {
                let _ =
                    respond_error(&mut stream, 503, "all connection workers busy, retry later");
            }
            "POST" => handle_embed(index, &mut stream, &req.body),
            _ => {
                let _ = respond_method_not_allowed(&mut stream, method, "POST");
            }
        },
        "/v1/collections" => match method {
            // accounting read: stays live under overflow like /v1/stats
            "GET" => handle_collections_list(index, &mut stream),
            _ => {
                let _ = respond_method_not_allowed(&mut stream, method, "GET");
            }
        },
        p if p.starts_with("/v1/collections/") => {
            let rest = &p["/v1/collections/".len()..];
            match (rest.split_once('/'), method) {
                // same 404-beats-503 rule as /v1/embed
                (Some((_, "add" | "query" | "scan" | "rerank")), "POST")
                    if overflow && index.is_some() =>
                {
                    let _ = respond_error(
                        &mut stream,
                        503,
                        "all connection workers busy, retry later",
                    );
                }
                (Some((name, "add")), "POST") => {
                    handle_index_add(index, name, &mut stream, &req.body)
                }
                (Some((name, "query")), "POST") => {
                    handle_index_query(index, name, &mut stream, &req.body)
                }
                (Some((name, "scan")), "POST") => {
                    handle_index_scan(index, name, &mut stream, &req.body)
                }
                (Some((name, "rerank")), "POST") => {
                    handle_index_rerank(index, name, &mut stream, &req.body)
                }
                (Some((_, "add" | "query" | "scan" | "rerank")), m) => {
                    let _ = respond_method_not_allowed(&mut stream, m, "POST");
                }
                _ => {
                    let _ = respond_error(&mut stream, 404, &format!("no endpoint {p}"));
                }
            }
        }
        p => {
            let _ = respond_error(&mut stream, 404, &format!("no endpoint {p}"));
        }
    }
}

// --------------------------------------------------------------- /v1/generate

struct GenParams {
    prompt: Vec<i32>,
    max_new_tokens: usize,
    temperature: f32,
    seed: u64,
    stream: bool,
}

fn parse_generate(body: &[u8]) -> Result<GenParams> {
    let text = std::str::from_utf8(body).map_err(|_| anyhow!("body is not UTF-8"))?;
    let v = json::parse(text).map_err(|e| anyhow!("invalid JSON body: {e}"))?;
    let prompt = match v.get("prompt") {
        None => Vec::new(),
        Some(p) => parse_i32_array(p, "prompt")?,
    };
    let max_new_tokens = match v.get("max_new_tokens") {
        None => 16,
        Some(x) => x
            .as_f64()
            .filter(|f| f.fract() == 0.0 && (0.0..=1e9).contains(f))
            .map(|f| f as usize)
            .ok_or_else(|| anyhow!("'max_new_tokens' must be an integer in 0..=1e9"))?,
    };
    let temperature = match v.get("temperature") {
        None => 0.0,
        Some(x) => x
            .as_f64()
            .filter(|f| f.is_finite() && *f >= 0.0)
            .map(|f| f as f32)
            .ok_or_else(|| anyhow!("'temperature' must be a non-negative number"))?,
    };
    let seed = match v.get("seed") {
        None => 0,
        Some(x) => x
            .as_f64()
            .filter(|f| f.fract() == 0.0 && (0.0..=1.8e19).contains(f))
            .map(|f| f as u64)
            .ok_or_else(|| anyhow!("'seed' must be a non-negative integer"))?,
    };
    let stream = match v.get("stream") {
        None => false,
        Some(x) => x.as_bool().ok_or_else(|| anyhow!("'stream' must be a boolean"))?,
    };
    Ok(GenParams { prompt, max_new_tokens, temperature, seed, stream })
}

fn handle_generate(server: &Server, stream: &mut TcpStream, body: &[u8], cap: usize) {
    let gen = match parse_generate(body) {
        Ok(g) => g,
        Err(e) => {
            let _ = respond_error(stream, 400, &e.to_string());
            return;
        }
    };
    // server-side clamp: one patient client must not own a KV lane for an
    // unbounded generation (see HttpConfig::max_new_tokens_cap)
    let max_new_tokens = gen.max_new_tokens.min(cap);
    // Both flavors ride the streaming submit so both get a CancelToken:
    // a non-streaming response writes nothing until completion, so client
    // disconnects are detected by probing the socket for EOF instead of
    // by a failing chunk write — either way the KV lane is freed.
    let t0 = trace::tracer().now_us();
    let submitted = server.submit_streaming(gen.prompt, max_new_tokens, gen.temperature, gen.seed);
    trace::record_ambient("admission", t0, trace::tracer().now_us() - t0, match &submitted {
        Ok(_) => 0,
        Err(_) => -1,
    });
    match submitted {
        Ok(handle) if gen.stream => stream_response(stream, handle),
        Ok(handle) => collect_response(stream, handle),
        Err(e) => {
            let _ = respond_admit_error(stream, &e);
        }
    }
}

/// True once the peer closed its side. Only valid after the request has
/// been fully read (any further readable byte is either EOF — `Ok(0)` —
/// or pipelined garbage we are free to ignore under `Connection: close`).
/// A half-close (`shutdown(SHUT_WR)`) reads as EOF too and is treated as
/// abandonment — the documented protocol choice (module docs): clients
/// keep the socket fully open until they have read their response.
fn client_gone(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return false;
    }
    let mut probe = [0u8; 1];
    let mut r: &TcpStream = stream;
    // Ok(0) = orderly close/half-close; a read error (ECONNRESET after an
    // abortive close) is every bit as gone. Only WouldBlock means "still
    // connected, nothing to read".
    let gone = match r.read(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) => e.kind() != std::io::ErrorKind::WouldBlock,
    };
    let _ = stream.set_nonblocking(false);
    gone
}

/// Non-streaming `/v1/generate`: drain the token events (the `Done`
/// carries the full list), answering with one JSON object — while
/// periodically probing the socket so a disconnected client cancels the
/// generation instead of pinning its KV lane for up to `max_new_tokens`.
fn collect_response(stream: &mut TcpStream, handle: StreamHandle) {
    const PROBE_EVERY: usize = 32;
    let mut since_probe = 0usize;
    loop {
        match handle.events.recv_timeout(Duration::from_millis(250)) {
            Ok(StreamEvent::Token { .. }) => {
                since_probe += 1;
                if since_probe >= PROBE_EVERY {
                    since_probe = 0;
                    if client_gone(stream) {
                        handle.cancel.cancel();
                        return;
                    }
                }
            }
            Ok(StreamEvent::Done(c)) => {
                let _ = respond(stream, 200, "OK", &completion_json(&c, false).to_json());
                return;
            }
            // no event for a while: generation is slow or idle — a good
            // moment to notice an abandoned connection
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if client_gone(stream) {
                    handle.cancel.cancel();
                    return;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                let _ = respond_error(stream, 500, "generation aborted (batcher exited)");
                return;
            }
        }
    }
}

/// One chunk per sampled token; a write failure means the client is gone,
/// so fire the [`crate::serve::CancelToken`] and free the KV lane. While
/// *waiting* for events (e.g. still queued behind busy lanes, nothing to
/// write yet) the socket is probed for EOF like the non-streaming path,
/// so a client that disconnects before its first token cancels too.
fn stream_response(stream: &mut TcpStream, handle: StreamHandle) {
    let mut head = String::from(
        "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\n\
         Transfer-Encoding: chunked\r\nConnection: close\r\n",
    );
    if let Some(rid) = trace::current_rid() {
        head.push_str("X-Request-Id: ");
        head.push_str(&rid);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    if stream.write_all(head.as_bytes()).and_then(|_| stream.flush()).is_err() {
        handle.cancel.cancel();
        return;
    }
    loop {
        match handle.events.recv_timeout(Duration::from_millis(250)) {
            Ok(StreamEvent::Token { id, index, token }) => {
                let line = json::obj(vec![
                    ("id", json::num(id as f64)),
                    ("index", json::num(index as f64)),
                    ("token", json::num(token as f64)),
                ])
                .to_json()
                    + "\n";
                if write_chunk(stream, line.as_bytes()).is_err() {
                    handle.cancel.cancel();
                    return;
                }
            }
            Ok(StreamEvent::Done(c)) => {
                let line = completion_json(&c, true).to_json() + "\n";
                let _ = write_chunk(stream, line.as_bytes());
                let _ = stream.write_all(b"0\r\n\r\n");
                let _ = stream.flush();
                return;
            }
            // quiet stretch with nothing to write: check the peer is
            // still there before waiting further
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if client_gone(stream) {
                    handle.cancel.cancel();
                    return;
                }
            }
            // sender dropped without Done: the request was cancelled or
            // the batcher died — end the chunked body *without* the 0
            // terminator so the client sees an aborted stream, not a
            // well-formed short one
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        }
    }
}

// ------------------------------------------------------- index endpoints

/// Unwrap the optional index server; absent → 404 (the deployment did
/// not enable index serving, so the path genuinely does not exist).
fn require_index<'a>(
    index: Option<&'a IndexServer>,
    stream: &mut TcpStream,
) -> Option<&'a IndexServer> {
    if index.is_none() {
        let _ = respond_error(stream, 404, "index serving not enabled on this server");
    }
    index
}

/// Map a typed [`IndexError`] to its transport status: missing
/// collections are 404, a full byte budget is 507 (the add was refused,
/// nothing mutated), a durability I/O failure is 500, a store flipped
/// read-only by a durability failure is 503 (the add was refused before
/// touching the store, so retrying cannot duplicate rows), and
/// everything else is a 400-shaped caller error.
fn respond_index_error(stream: &mut TcpStream, e: &IndexError) -> std::io::Result<()> {
    let status = match e {
        IndexError::NoSuchCollection(_) => 404,
        IndexError::BudgetTooSmall { .. } => 507,
        IndexError::Io(_) => 500,
        IndexError::ReadOnly(_) => 503,
        IndexError::Conflict { .. } => 409,
        _ => 400,
    };
    respond_error(stream, status, &e.to_string())
}

/// Parse an i32 array field (token ids — same validation as the
/// generate prompt).
fn parse_i32_array(x: &Value, field: &str) -> Result<Vec<i32>> {
    x.as_arr()
        .ok_or_else(|| anyhow!("'{field}' must be an array of token ids"))?
        .iter()
        .map(|v| {
            v.as_f64()
                .filter(|f| f.fract() == 0.0 && (-2147483648.0..=2147483647.0).contains(f))
                .map(|f| f as i32)
                .ok_or_else(|| anyhow!("'{field}' entries must be integer token ids"))
        })
        .collect()
}

/// Parse an f32 vector field (the JSON parser already rejected
/// non-finite numbers).
pub(crate) fn parse_f32_array(x: &Value, field: &str) -> Result<Vec<f32>> {
    let arr = x
        .as_arr()
        .ok_or_else(|| anyhow!("'{field}' must be an array of numbers"))?;
    anyhow::ensure!(!arr.is_empty(), "'{field}' must be non-empty");
    arr.iter()
        .map(|v| {
            v.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| anyhow!("'{field}' entries must be numbers"))
        })
        .collect()
}

/// One token sequence out of `{"text": "..."}` or `{"tokens": [ints]}`.
fn parse_tokens_or_text(v: &Value) -> Result<Vec<i32>> {
    if let Some(t) = v.get("text") {
        let s = t.as_str().ok_or_else(|| anyhow!("'text' must be a string"))?;
        return Ok(tokenize(s));
    }
    if let Some(t) = v.get("tokens") {
        return parse_i32_array(t, "tokens");
    }
    bail!("need 'text' (a string) or 'tokens' (an array of token ids)")
}

pub(crate) fn hits_json(hits: &[crate::index::SearchHit]) -> Value {
    json::arr(
        hits.iter()
            .map(|h| {
                json::obj(vec![
                    ("id", json::num(h.id as f64)),
                    ("score", json::num(h.score as f64)),
                ])
            })
            .collect(),
    )
}

/// `POST /v1/embed` — embed one text/token sequence.
fn handle_embed(index: Option<&IndexServer>, stream: &mut TcpStream, body: &[u8]) {
    let Some(ix) = require_index(index, stream) else { return };
    let parsed = std::str::from_utf8(body)
        .map_err(|_| anyhow!("body is not UTF-8"))
        .and_then(|t| json::parse(t).map_err(|e| anyhow!("invalid JSON body: {e}")))
        .and_then(|v| parse_tokens_or_text(&v));
    let tokens = match parsed {
        Ok(t) => t,
        Err(e) => {
            let _ = respond_error(stream, 400, &e.to_string());
            return;
        }
    };
    match ix.embed(&tokens) {
        Ok(emb) => {
            let body = json::obj(vec![
                ("dim", json::num(emb.len() as f64)),
                ("tokens", json::num(tokens.len() as f64)),
                (
                    "embedding",
                    json::arr(emb.iter().map(|&x| json::num(x as f64)).collect()),
                ),
            ]);
            let _ = respond(stream, 200, "OK", &body.to_json());
        }
        Err(e) => {
            let _ = respond_index_error(stream, &e);
        }
    }
}

/// The add/query vector payloads: caller-supplied vectors, or texts /
/// token sequences embedded server-side. Returns row-major values plus
/// the row dimension.
fn parse_vectors(ix: &IndexServer, v: &Value) -> Result<(Vec<f32>, usize)> {
    if let Some(vs) = v.get("vectors") {
        let rows = vs
            .as_arr()
            .ok_or_else(|| anyhow!("'vectors' must be an array of number arrays"))?;
        anyhow::ensure!(!rows.is_empty(), "'vectors' must be non-empty");
        let mut flat = Vec::new();
        let mut d = 0usize;
        for (i, row) in rows.iter().enumerate() {
            let r = parse_f32_array(row, "vectors")?;
            if i == 0 {
                d = r.len();
            } else {
                anyhow::ensure!(
                    r.len() == d,
                    "'vectors' rows must share one dimension ({} vs {d})",
                    r.len()
                );
            }
            flat.extend_from_slice(&r);
        }
        return Ok((flat, d));
    }
    // text/token shapes: embed server-side, one row per entry
    let seqs: Vec<Vec<i32>> = if let Some(ts) = v.get("texts") {
        ts.as_arr()
            .ok_or_else(|| anyhow!("'texts' must be an array of strings"))?
            .iter()
            .map(|t| {
                t.as_str()
                    .map(tokenize)
                    .ok_or_else(|| anyhow!("'texts' entries must be strings"))
            })
            .collect::<Result<_>>()?
    } else if let Some(ts) = v.get("tokens") {
        ts.as_arr()
            .ok_or_else(|| anyhow!("'tokens' must be an array of token-id arrays"))?
            .iter()
            .map(|t| parse_i32_array(t, "tokens"))
            .collect::<Result<_>>()?
    } else {
        bail!("need 'vectors', 'texts', or 'tokens'")
    };
    anyhow::ensure!(!seqs.is_empty(), "nothing to add");
    let mut flat = Vec::new();
    let mut d = 0usize;
    for seq in &seqs {
        let emb = ix.embed(seq).map_err(|e| anyhow!("{e}"))?;
        d = emb.len();
        flat.extend_from_slice(&emb);
    }
    Ok((flat, d))
}

/// `POST /v1/collections/{name}/add`. An optional integer
/// `"expect_first_id"` arms the exactly-once guard: the add applies
/// only if the first appended row would get exactly that id, else
/// **409** and nothing mutates (the cluster router's shard-add seam —
/// see [`crate::index::VectorStore::add_expect`]).
fn handle_index_add(index: Option<&IndexServer>, name: &str, stream: &mut TcpStream, body: &[u8]) {
    let Some(ix) = require_index(index, stream) else { return };
    let parsed = std::str::from_utf8(body)
        .map_err(|_| anyhow!("body is not UTF-8"))
        .and_then(|t| json::parse(t).map_err(|e| anyhow!("invalid JSON body: {e}")))
        .and_then(|v| {
            let expect = match v.get("expect_first_id") {
                None => None,
                Some(x) => Some(
                    x.as_f64()
                        .filter(|f| f.fract() == 0.0 && (0.0..=1e15).contains(f))
                        .map(|f| f as usize)
                        .ok_or_else(|| {
                            anyhow!("'expect_first_id' must be a non-negative integer")
                        })?,
                ),
            };
            Ok((parse_vectors(ix, &v)?, expect))
        });
    let ((flat, d), expect) = match parsed {
        Ok(p) => p,
        Err(e) => {
            let _ = respond_error(stream, 400, &e.to_string());
            return;
        }
    };
    let added = match expect {
        Some(e) => ix.add_expect(name, &flat, d, e),
        None => ix.add(name, &flat, d),
    };
    match added {
        Ok((first, count)) => {
            let body = json::obj(vec![
                ("collection", json::s(name)),
                ("count", json::num(count as f64)),
                (
                    "ids",
                    json::arr((first..first + count).map(|i| json::num(i as f64)).collect()),
                ),
            ]);
            let _ = respond(stream, 200, "OK", &body.to_json());
        }
        Err(e) => {
            let _ = respond_index_error(stream, &e);
        }
    }
}

/// `POST /v1/collections/{name}/query`.
fn handle_index_query(
    index: Option<&IndexServer>,
    name: &str,
    stream: &mut TcpStream,
    body: &[u8],
) {
    let Some(ix) = require_index(index, stream) else { return };
    let parsed = std::str::from_utf8(body)
        .map_err(|_| anyhow!("body is not UTF-8"))
        .and_then(|t| json::parse(t).map_err(|e| anyhow!("invalid JSON body: {e}")));
    let v = match parsed {
        Ok(v) => v,
        Err(e) => {
            let _ = respond_error(stream, 400, &e.to_string());
            return;
        }
    };
    let q = if let Some(qv) = v.get("vector") {
        match parse_f32_array(qv, "vector") {
            Ok(q) => q,
            Err(e) => {
                let _ = respond_error(stream, 400, &e.to_string());
                return;
            }
        }
    } else {
        match parse_tokens_or_text(&v).and_then(|t| ix.embed(&t).map_err(|e| anyhow!("{e}"))) {
            Ok(q) => q,
            Err(e) => {
                let _ = respond_error(stream, 400, &e.to_string());
                return;
            }
        }
    };
    let k = match v.get("k") {
        None => 10,
        Some(x) => match x.as_f64().filter(|f| f.fract() == 0.0 && (1.0..=1024.0).contains(f)) {
            Some(f) => f as usize,
            None => {
                let _ = respond_error(stream, 400, "'k' must be an integer in 1..=1024");
                return;
            }
        },
    };
    let rerank_factor = match v.get("rerank_factor") {
        None => DEFAULT_RERANK_FACTOR,
        Some(x) => match x.as_f64().filter(|f| f.fract() == 0.0 && (1.0..=64.0).contains(f)) {
            Some(f) => f as usize,
            None => {
                let _ =
                    respond_error(stream, 400, "'rerank_factor' must be an integer in 1..=64");
                return;
            }
        },
    };
    match ix.query(name, &q, k, rerank_factor) {
        Ok(hits) => {
            let body = json::obj(vec![
                ("collection", json::s(name)),
                ("k", json::num(k as f64)),
                ("rerank_factor", json::num(rerank_factor as f64)),
                ("results", hits_json(&hits)),
            ]);
            let _ = respond(stream, 200, "OK", &body.to_json());
        }
        Err(e) => {
            let _ = respond_index_error(stream, &e);
        }
    }
}

/// `POST /v1/collections/{name}/scan` — phase 1 of a distributed
/// two-phase query (the cluster router's scatter RPC): body
/// `{"vector": [f32...], "take": N}`, answer `{"collection", "rows":
/// local_row_count, "candidates": [{"id","score"}, ...]}` where the
/// candidates are the local top-`take` **estimated** scores, `(est
/// desc, id asc)` like [`crate::index::top_indices`]. `take` is the
/// router-computed global `rerank_factor * k` — see
/// [`crate::index::Collection::scan_candidates`] for why the local
/// top-`take` suffices for a bit-identical global merge.
fn handle_index_scan(index: Option<&IndexServer>, name: &str, stream: &mut TcpStream, body: &[u8]) {
    let Some(ix) = require_index(index, stream) else { return };
    let parsed = std::str::from_utf8(body)
        .map_err(|_| anyhow!("body is not UTF-8"))
        .and_then(|t| json::parse(t).map_err(|e| anyhow!("invalid JSON body: {e}")));
    let v = match parsed {
        Ok(v) => v,
        Err(e) => {
            let _ = respond_error(stream, 400, &e.to_string());
            return;
        }
    };
    let q = match v.get("vector").ok_or_else(|| anyhow!("need 'vector'")).and_then(|qv| {
        parse_f32_array(qv, "vector")
    }) {
        Ok(q) => q,
        Err(e) => {
            let _ = respond_error(stream, 400, &e.to_string());
            return;
        }
    };
    let take = match v.get("take").and_then(|x| {
        x.as_f64().filter(|f| f.fract() == 0.0 && (1.0..=1e9).contains(f))
    }) {
        Some(f) => f as usize,
        None => {
            let _ = respond_error(stream, 400, "'take' must be an integer in 1..=1e9");
            return;
        }
    };
    match ix.scan_candidates(name, &q, take) {
        Ok((rows, cands)) => {
            let body = json::obj(vec![
                ("collection", json::s(name)),
                ("rows", json::num(rows as f64)),
                ("take", json::num(take as f64)),
                ("candidates", hits_json(&cands)),
            ]);
            let _ = respond(stream, 200, "OK", &body.to_json());
        }
        Err(e) => {
            let _ = respond_index_error(stream, &e);
        }
    }
}

/// `POST /v1/collections/{name}/rerank` — phase 2 of a distributed
/// two-phase query: body `{"vector": [f32...], "ids": [ints]}`, answer
/// `{"collection", "results": [{"id","score"}, ...]}` with **exact**
/// scores in input order (the router merges `(score desc, gid asc)`
/// afterwards — see [`crate::index::Collection::exact_scores`]).
fn handle_index_rerank(
    index: Option<&IndexServer>,
    name: &str,
    stream: &mut TcpStream,
    body: &[u8],
) {
    let Some(ix) = require_index(index, stream) else { return };
    let parsed = std::str::from_utf8(body)
        .map_err(|_| anyhow!("body is not UTF-8"))
        .and_then(|t| json::parse(t).map_err(|e| anyhow!("invalid JSON body: {e}")))
        .and_then(|v| {
            let q = parse_f32_array(
                v.get("vector").ok_or_else(|| anyhow!("need 'vector'"))?,
                "vector",
            )?;
            let ids: Vec<usize> = v
                .get("ids")
                .ok_or_else(|| anyhow!("need 'ids'"))?
                .as_arr()
                .ok_or_else(|| anyhow!("'ids' must be an array of row ids"))?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .filter(|f| f.fract() == 0.0 && (0.0..=1e15).contains(f))
                        .map(|f| f as usize)
                        .ok_or_else(|| anyhow!("'ids' entries must be non-negative integers"))
                })
                .collect::<Result<_>>()?;
            anyhow::ensure!(!ids.is_empty(), "'ids' must be non-empty");
            Ok((q, ids))
        });
    let (q, ids) = match parsed {
        Ok(p) => p,
        Err(e) => {
            let _ = respond_error(stream, 400, &e.to_string());
            return;
        }
    };
    match ix.exact_scores(name, &q, &ids) {
        Ok(hits) => {
            let body = json::obj(vec![
                ("collection", json::s(name)),
                ("results", hits_json(&hits)),
            ]);
            let _ = respond(stream, 200, "OK", &body.to_json());
        }
        Err(e) => {
            let _ = respond_index_error(stream, &e);
        }
    }
}

/// `GET /v1/collections` — the index accounting surface.
fn handle_collections_list(index: Option<&IndexServer>, stream: &mut TcpStream) {
    let Some(ix) = require_index(index, stream) else { return };
    let stats = ix.stats();
    let collections = json::arr(
        ix.collections()
            .iter()
            .map(|c| {
                json::obj(vec![
                    ("name", json::s(&c.name)),
                    ("rows", json::num(c.rows as f64)),
                    ("dim", json::num(c.dim as f64)),
                    ("bits", json::num(c.bits as f64)),
                    ("metric", json::s(c.metric.name())),
                    ("bytes_per_row", json::num(c.bytes_per_row as f64)),
                    ("code_bytes", json::num(c.code_bytes as f64)),
                    ("exact_bytes", json::num(c.exact_bytes as f64)),
                    ("segments", json::num(c.segments as f64)),
                    ("head_rows", json::num(c.head_rows as f64)),
                ])
            })
            .collect(),
    );
    let mut fields = vec![
        ("collections", collections),
        ("rows", json::num(stats.rows as f64)),
        ("code_bytes", json::num(stats.code_bytes as f64)),
        ("segments", json::num(stats.segments as f64)),
        ("head_rows", json::num(stats.head_rows as f64)),
        ("compactions", json::num(stats.compactions as f64)),
        ("embeds", json::num(stats.embeds as f64)),
        ("rows_added", json::num(stats.rows_added as f64)),
        ("queries", json::num(stats.queries as f64)),
    ];
    if let Some(d) = ix.embed_dim() {
        fields.push(("embed_dim", json::num(d as f64)));
    }
    let _ = respond(stream, 200, "OK", &json::obj(fields).to_json());
}

fn completion_json(c: &Completion, done_marker: bool) -> Value {
    let mut fields = vec![
        ("id", json::num(c.id as f64)),
        ("tokens", json::arr(c.tokens.iter().map(|&t| json::num(t as f64)).collect())),
        ("latency_secs", json::num(c.latency_secs)),
        ("steps", json::num(c.steps as f64)),
    ];
    if done_marker {
        fields.push(("done", Value::Bool(true)));
    }
    json::obj(fields)
}

/// Build the `/v1/stats` snapshot.
///
/// **Latency-window invariant.** Percentiles here are computed over this
/// node's own bounded window and are *terminal* — they must never be
/// combined across nodes (a mean of p95s is not a fleet p95). What IS
/// safe to combine are the two re-aggregatable forms exposed alongside:
/// `latencies_secs` (the raw window; a router concatenates windows and
/// computes fleet percentiles ONCE) and `latency_bucket_counts`
/// (non-cumulative counts over the shared [`obs::LATENCY_BUCKETS_US`]
/// edges, element-wise summable across workers — the form dashboards
/// re-aggregate without the averaging-percentiles trap).
fn stats_json(server: &Server, index: Option<&IndexServer>) -> Value {
    let s: ServerStats = server.stats();
    let mut fields = vec![
        ("completions", json::num(s.completions as f64)),
        ("tokens_generated", json::num(s.tokens_generated as f64)),
        ("prefill_tokens", json::num(s.prefill_tokens as f64)),
        ("decode_steps", json::num(s.decode_steps as f64)),
        ("window_slides", json::num(s.window_slides as f64)),
        ("batch_steps", json::num(s.batch_steps as f64)),
        ("total_rows", json::num(s.total_rows as f64)),
        ("cancelled", json::num(s.cancelled as f64)),
        // from the snapshot: the batcher republishes it per round, so one
        // stats read reports generate and index load coherently
        ("queue_depth", json::num(s.queue_depth as f64)),
        ("kv_bits", json::num(s.kv_bits)),
        ("kv_bytes_per_lane", json::num(s.kv_bytes_per_lane as f64)),
        ("lanes", json::num(s.lanes as f64)),
        ("lanes_active", json::num(s.lanes_active as f64)),
        ("running", Value::Bool(server.is_running())),
        ("throughput_tok_s", json::num(s.throughput_tok_s())),
        ("p50_latency_secs", json::num(s.p50_latency())),
        ("p95_latency_secs", json::num(s.p95_latency())),
        // the raw (bounded) completion-latency window, so a cluster
        // router can concatenate windows across workers and compute
        // fleet percentiles ONCE — averaging per-worker percentiles is
        // mathematically wrong (a p95 of p95s is not the fleet p95)
        (
            "latencies_secs",
            json::arr(s.latencies.iter().map(|&x| json::num(x)).collect()),
        ),
        // the same window as summable histogram buckets (shared µs edge
        // layout): these MAY be element-wise summed across workers,
        // unlike the percentile fields above — see this fn's rustdoc
        (
            "latency_bucket_le_us",
            json::arr(obs::LATENCY_BUCKETS_US.iter().map(|&e| json::num(e as f64)).collect()),
        ),
        (
            "latency_bucket_counts",
            json::arr(
                obs::bucketize_us(s.latencies.iter().map(|&secs| (secs * 1e6) as u64))
                    .into_iter()
                    .map(|c| json::num(c as f64))
                    .collect(),
            ),
        ),
        ("wall_secs", json::num(s.wall_secs)),
    ];
    if let Some(ix) = index {
        let is = ix.stats();
        fields.push(("index_durable", Value::Bool(is.durable)));
        fields.push(("index_read_only", Value::Bool(is.read_only)));
        fields.push(("index_segments", json::num(is.segments as f64)));
        fields.push(("index_head_rows", json::num(is.head_rows as f64)));
        fields.push(("index_compactions", json::num(is.compactions as f64)));
        if let Some(r) = is.recovered_rows {
            fields.push(("recovered_rows", json::num(r as f64)));
        }
        if let Some(d) = is.dropped_records {
            fields.push(("dropped_records", json::num(d as f64)));
        }
    }
    json::obj(fields)
}

fn respond_admit_error(stream: &mut TcpStream, e: &AdmitError) -> std::io::Result<()> {
    match e {
        AdmitError::QueueFull => respond_error(stream, 429, "admission queue full, retry later"),
        AdmitError::NotAccepting => respond_error(stream, 503, "server is shutting down"),
        AdmitError::InvalidRequest(why) => respond_error(stream, 400, why),
    }
}

pub(crate) fn respond(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    body: &str,
) -> std::io::Result<()> {
    respond_with_headers(stream, status, reason, &[], body)
}

/// [`respond`] with extra response headers (the 405 path's `Allow:`).
pub(crate) fn respond_with_headers(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    extra: &[(&str, &str)],
    body: &str,
) -> std::io::Result<()> {
    respond_full(stream, status, reason, "application/json", extra, body)
}

/// Plain-text response — the `/metrics` exposition body.
pub(crate) fn respond_text(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    body: &str,
) -> std::io::Result<()> {
    respond_full(stream, status, reason, "text/plain; version=0.0.4", &[], body)
}

/// The one response writer every non-streaming path funnels through —
/// which is what makes the `X-Request-Id` echo universal: whenever the
/// serving thread has an ambient request id installed, it is emitted
/// here, on successes and on every error status alike.
fn respond_full(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    extra: &[(&str, &str)],
    body: &str,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    if let Some(rid) = trace::current_rid() {
        head.push_str("X-Request-Id: ");
        head.push_str(&rid);
        head.push_str("\r\n");
    }
    for (k, v) in extra {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

pub(crate) fn respond_error(stream: &mut TcpStream, status: u16, msg: &str) -> std::io::Result<()> {
    obs::metrics().http_errors.inc();
    let reason = match status {
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        507 => "Insufficient Storage",
        _ => "Internal Server Error",
    };
    // 429/503 are transient refusals: advertise a retry hint so clients
    // (including this module's own test client) back off instead of
    // hammering the admission queue.
    let extra: &[(&str, &str)] = match status {
        429 | 503 => &[("Retry-After", "1")],
        _ => &[],
    };
    respond_with_headers(
        stream,
        status,
        reason,
        extra,
        &json::obj(vec![("error", json::s(msg))]).to_json(),
    )
}

/// 405 with the RFC-9110-required `Allow:` header and the same
/// `{"error": ...}` body shape as every other error path.
pub(crate) fn respond_method_not_allowed(
    stream: &mut TcpStream,
    method: &str,
    allow: &str,
) -> std::io::Result<()> {
    obs::metrics().http_errors.inc();
    let body = json::obj(vec![(
        "error",
        json::s(&format!("method {method} not allowed here (allow: {allow})")),
    )])
    .to_json();
    respond_with_headers(
        stream,
        405,
        "Method Not Allowed",
        &[("Allow", allow)],
        &body,
    )
}

pub(crate) fn write_chunk(stream: &mut TcpStream, payload: &[u8]) -> std::io::Result<()> {
    write!(stream, "{:x}\r\n", payload.len())?;
    stream.write_all(payload)?;
    stream.write_all(b"\r\n")?;
    stream.flush()
}

// -------------------------------------------------------------- tiny client

/// A parsed HTTP response, as read by [`http_request`].
#[derive(Debug)]
pub struct HttpResponse {
    /// Status code (200, 429, ...).
    pub status: u16,
    /// Lower-cased header name/value pairs.
    pub headers: Vec<(String, String)>,
    /// Full body (chunked transfer already reassembled).
    pub body: Vec<u8>,
    /// Individual chunk payloads when the response was chunked (one per
    /// stream event for `/v1/generate` streams); empty otherwise.
    pub chunks: Vec<Vec<u8>>,
}

impl HttpResponse {
    /// Body as UTF-8 text.
    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).map_err(|_| anyhow!("non-UTF-8 response body"))
    }

    /// Body parsed as JSON.
    pub fn json(&self) -> Result<Value> {
        json::parse(self.body_str()?)
    }
}

/// Client-side socket deadlines for [`http_request_with`] /
/// [`http_request_retry_with`].
///
/// The bare [`http_request`] keeps the historical behavior (no
/// deadlines), which is fine for loopback tests that own both ends of
/// the socket. Anything that calls *other processes* — the cluster
/// router's health probes and scatter-gather RPCs above all — must set
/// both timeouts: `TcpStream::connect` against a dead-but-routable
/// address can otherwise block for the kernel's SYN-retry budget
/// (minutes), and a wedged worker that accepted the connection but
/// never responds would pin a router thread forever.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClientConfig {
    /// Deadline for the TCP connect; `None` = OS default (unbounded
    /// for practical purposes).
    pub connect_timeout: Option<Duration>,
    /// Per-`read` deadline while parsing the response; `None` = block
    /// forever.
    pub read_timeout: Option<Duration>,
}

impl ClientConfig {
    /// Both deadlines set to `ms` milliseconds — the common case.
    pub fn timeout_ms(ms: u64) -> Self {
        let t = Some(Duration::from_millis(ms));
        ClientConfig { connect_timeout: t, read_timeout: t }
    }
}

/// Minimal blocking HTTP/1.1 client for loopback tests, benches, and the
/// `http_client` example: one request, whole response (chunked responses
/// are reassembled and the individual chunks preserved). Not a general
/// client — it speaks exactly the subset this module's server emits.
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<HttpResponse> {
    http_request_with(addr, method, path, body, ClientConfig::default())
}

/// [`http_request`] with explicit connect/read deadlines (see
/// [`ClientConfig`]). `connect_timeout` requires a resolved
/// `SocketAddr`, so the address is resolved first; the first resolved
/// address is used, matching `TcpStream::connect`'s happy path for the
/// `127.0.0.1:port` strings this crate deals in.
pub fn http_request_with(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    cfg: ClientConfig,
) -> Result<HttpResponse> {
    use std::net::ToSocketAddrs;
    let mut stream = match cfg.connect_timeout {
        Some(t) => {
            let sa = addr
                .to_socket_addrs()
                .with_context(|| format!("resolving {addr}"))?
                .next()
                .ok_or_else(|| anyhow!("address '{addr}' resolved to nothing"))?;
            TcpStream::connect_timeout(&sa, t)
                .with_context(|| format!("connecting to {addr} (timeout {t:?})"))?
        }
        None => TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?,
    };
    stream.set_nodelay(true).ok();
    if cfg.read_timeout.is_some() {
        stream.set_read_timeout(cfg.read_timeout).ok();
    }
    let body_bytes = body.unwrap_or("");
    // propagate the ambient request id: a router thread serving request
    // R forwards R's id on this RPC, so worker-side spans and response
    // headers correlate with the client-facing request
    let rid_line = match trace::current_rid() {
        Some(rid) => format!("X-Request-Id: {rid}\r\n"),
        None => String::new(),
    };
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\
         {rid_line}Connection: close\r\n\r\n{body_bytes}",
        body_bytes.len()
    );
    stream.write_all(req.as_bytes()).context("writing request")?;
    stream.flush().ok();
    read_response(&stream)
}

/// [`http_request`] with bounded retry-with-backoff on transient refusals
/// (429/503) and transport errors. The delay doubles from a 25 ms base,
/// is capped by the server's `Retry-After` hint (when present; 500 ms
/// otherwise), and carries a small deterministic jitter so lockstep
/// clients in a loopback test don't re-collide. After `attempts` tries
/// the last refusal is returned as-is — callers still see the real
/// status — and only a transport error that never produced a response
/// is surfaced as `Err`.
pub fn http_request_retry(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    attempts: usize,
) -> Result<HttpResponse> {
    http_request_retry_with(addr, method, path, body, attempts, ClientConfig::default())
}

/// [`http_request_retry`] with per-attempt connect/read deadlines — the
/// router's RPC primitive. Each attempt gets a fresh socket with the
/// same [`ClientConfig`], so a hung worker costs at most
/// `attempts × (connect_timeout + read_timeout)` instead of forever.
pub fn http_request_retry_with(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    attempts: usize,
    cfg: ClientConfig,
) -> Result<HttpResponse> {
    let attempts = attempts.max(1);
    // every attempt of one logical request must carry the same id so
    // server-side logs/spans correlate the retries; mint one when the
    // calling thread has none, and restore the ambient state after
    let installed = trace::current_rid().is_none();
    if installed {
        trace::set_current_rid(Some(trace::mint_rid()));
    }
    let out = http_request_retry_inner(addr, method, path, body, attempts, cfg);
    if installed {
        trace::set_current_rid(None);
    }
    out
}

fn http_request_retry_inner(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    attempts: usize,
    cfg: ClientConfig,
) -> Result<HttpResponse> {
    let mut last_err = None;
    for attempt in 0..attempts {
        match http_request_with(addr, method, path, body, cfg) {
            Ok(resp) => {
                if !matches!(resp.status, 429 | 503) || attempt + 1 == attempts {
                    return Ok(resp);
                }
                // honor the server's hint, but never sleep a whole
                // advertised second inside a loopback test
                let cap_ms = header(&resp.headers, "retry-after")
                    .and_then(|v| v.parse::<u64>().ok())
                    .map(|secs| (secs * 1000).min(1000))
                    .unwrap_or(500);
                let backoff = 25u64.saturating_mul(1 << attempt.min(5));
                let jitter = (attempt as u64 * 37) % 29;
                thread::sleep(Duration::from_millis(backoff.min(cap_ms) + jitter));
            }
            Err(e) => {
                last_err = Some(e);
                thread::sleep(Duration::from_millis(25 + (attempt as u64 * 37) % 29));
            }
        }
    }
    Err(last_err.unwrap_or_else(|| anyhow!("retry budget of {attempts} attempts exhausted")))
}

/// Parse one HTTP response off `stream` (shared by [`http_request`] and
/// callers that manage the socket themselves).
pub fn read_response(stream: &TcpStream) -> Result<HttpResponse> {
    let mut reader = BufReader::new(stream.try_clone().context("cloning stream")?);
    let mut line = String::new();
    reader.read_line(&mut line).context("reading status line")?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed status line '{}'", line.trim_end()))?;
    let mut headers = Vec::new();
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).context("reading header")?;
        anyhow::ensure!(n > 0, "connection closed inside response head");
        let trimmed = line.trim_end_matches(['\r', '\n']);
        if trimmed.is_empty() {
            break;
        }
        if let Some((k, v)) = trimmed.split_once(':') {
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
    }
    let mut chunks = Vec::new();
    let body = if header(&headers, "transfer-encoding").is_some_and(|v| v.contains("chunked")) {
        let mut all = Vec::new();
        loop {
            let mut size_line = String::new();
            anyhow::ensure!(
                reader.read_line(&mut size_line)? > 0,
                "connection closed mid-stream (chunked body not terminated)"
            );
            let size_str = size_line.trim().split(';').next().unwrap_or("");
            let size = usize::from_str_radix(size_str, 16)
                .map_err(|_| anyhow!("bad chunk size '{size_str}'"))?;
            if size == 0 {
                // trailing CRLF after the last-chunk marker
                let mut end = String::new();
                let _ = reader.read_line(&mut end);
                break;
            }
            let mut chunk = vec![0u8; size];
            reader.read_exact(&mut chunk).context("reading chunk payload")?;
            let mut crlf = [0u8; 2];
            reader.read_exact(&mut crlf).context("reading chunk terminator")?;
            all.extend_from_slice(&chunk);
            chunks.push(chunk);
        }
        all
    } else {
        let len = match header(&headers, "content-length") {
            Some(v) => v.parse::<usize>().map_err(|_| anyhow!("bad content-length"))?,
            None => 0,
        };
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).context("reading response body")?;
        body
    };
    Ok(HttpResponse { status, headers, body, chunks })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_body_parsing_defaults_and_validation() {
        let g = parse_generate(br#"{"prompt":[1,2,3]}"#).unwrap();
        assert_eq!(g.prompt, vec![1, 2, 3]);
        assert_eq!(g.max_new_tokens, 16);
        assert_eq!(g.temperature, 0.0);
        assert_eq!(g.seed, 0);
        assert!(!g.stream);

        let g = parse_generate(
            br#"{"prompt":[],"max_new_tokens":4,"temperature":0.5,"seed":9,"stream":true}"#,
        )
        .unwrap();
        assert!(g.prompt.is_empty());
        assert_eq!(g.max_new_tokens, 4);
        assert!((g.temperature - 0.5).abs() < 1e-6);
        assert_eq!(g.seed, 9);
        assert!(g.stream);

        // empty body is a valid all-defaults request? no: not JSON
        assert!(parse_generate(b"").is_err());
        assert!(parse_generate(b"{}").is_ok());
        // hostile shapes refuse cleanly
        assert!(parse_generate(br#"{"prompt":"abc"}"#).is_err());
        assert!(parse_generate(br#"{"prompt":[1.5]}"#).is_err());
        assert!(parse_generate(br#"{"prompt":[99999999999999]}"#).is_err());
        assert!(parse_generate(br#"{"max_new_tokens":-1}"#).is_err());
        assert!(parse_generate(br#"{"max_new_tokens":1e12}"#).is_err());
        assert!(parse_generate(br#"{"temperature":-0.1}"#).is_err());
        assert!(parse_generate(br#"{"seed":-3}"#).is_err());
        assert!(parse_generate(br#"{"stream":1}"#).is_err());
        assert!(parse_generate(&[0xFF, 0xFE]).is_err());
    }

    #[test]
    fn index_body_parsing_shapes() {
        // tokens-or-text: both shapes, text wins when both present
        let v = json::parse(r#"{"text":"AB"}"#).unwrap();
        assert_eq!(parse_tokens_or_text(&v).unwrap(), vec![65, 66]);
        let v = json::parse(r#"{"tokens":[1,2,3]}"#).unwrap();
        assert_eq!(parse_tokens_or_text(&v).unwrap(), vec![1, 2, 3]);
        assert!(parse_tokens_or_text(&json::parse("{}").unwrap()).is_err());
        assert!(parse_tokens_or_text(&json::parse(r#"{"text":7}"#).unwrap()).is_err());
        assert!(parse_tokens_or_text(&json::parse(r#"{"tokens":[1.5]}"#).unwrap()).is_err());

        let v = json::parse(r#"{"vector":[0.5,-1,2]}"#).unwrap();
        assert_eq!(
            parse_f32_array(v.get("vector").unwrap(), "vector").unwrap(),
            vec![0.5, -1.0, 2.0]
        );
        let v = json::parse(r#"{"vector":[]}"#).unwrap();
        assert!(parse_f32_array(v.get("vector").unwrap(), "vector").is_err());
        let v = json::parse(r#"{"vector":"x"}"#).unwrap();
        assert!(parse_f32_array(v.get("vector").unwrap(), "vector").is_err());
    }

    #[test]
    fn completion_json_shape() {
        let c = Completion { id: 7, tokens: vec![1, 2], latency_secs: 0.5, steps: 2 };
        let v = json::parse(&completion_json(&c, false).to_json()).unwrap();
        assert_eq!(v.req_usize("id").unwrap(), 7);
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("done").is_none());
        let v = json::parse(&completion_json(&c, true).to_json()).unwrap();
        assert_eq!(v.get("done").unwrap().as_bool(), Some(true));
    }
}
