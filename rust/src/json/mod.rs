//! Minimal JSON substrate (the offline vendor set has no serde facade).
//!
//! Supports the full JSON grammar minus exotic escapes; used for artifact
//! manifests, run configs, experiment result dumps — and, since the HTTP
//! front-end ([`crate::net`]) landed, **untrusted network bytes**. The
//! parser is therefore hardened against adversarial input: nesting depth
//! is capped ([`MAX_DEPTH`]) so a `[[[[...` bomb cannot overflow the
//! recursion stack, number literals are length-capped and must be finite,
//! truncated `\u` escapes are errors rather than slice panics, and every
//! malformed input path returns `Err` — `parse` never panics (tested in
//! this module's adversarial suite). Not a speed-critical path.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing JSON key '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| anyhow!("key '{key}' not a number"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow!("key '{key}' not a string"))
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write_to(&mut s);
        s
    }

    fn write_to(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write_to(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

/// Maximum container nesting depth [`parse`] accepts. Hostile inputs like
/// ten thousand `[`s would otherwise recurse once per level and overflow
/// the stack (an unrecoverable abort, not an `Err`); every legitimate
/// document in this repo nests single digits deep.
pub const MAX_DEPTH: usize = 64;

/// Longest number literal [`parse`] accepts, in bytes. JSON numbers this
/// long are either hostile padding or values f64 cannot represent anyway.
pub const MAX_NUMBER_LEN: usize = 256;

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting depth (see [`MAX_DEPTH`]).
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes.get(self.pos).copied().ok_or_else(|| anyhow!("unexpected EOF"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected '{}' at byte {}, found '{}'",
                  b as char, self.pos, self.peek()? as char);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("nesting deeper than {MAX_DEPTH} at byte {}", self.pos);
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        self.enter()?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}' got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        self.enter()?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Value::Arr(v));
                }
                c => bail!("expected ',' or ']' got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            // bounds-checked: a document truncated inside
                            // the escape must error, not slice-panic
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("EOF inside \\u escape"))?;
                            let hex = std::str::from_utf8(hex)?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow!("bad \\u escape '{hex}'"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?);
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                _ => {
                    // consume a full UTF-8 sequence
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        if self.pos - start > MAX_NUMBER_LEN {
            bail!("number literal longer than {MAX_NUMBER_LEN} bytes at byte {start}");
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n = text.parse::<f64>().map_err(|_| anyhow!("bad number '{text}'"))?;
        // "1e999" parses to +inf in Rust; JSON has no infinities or NaN,
        // and downstream consumers assume finite numbers
        if !n.is_finite() {
            bail!("number '{text}' overflows f64");
        }
        Ok(Value::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Value::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":{"x":1,"y":[true,false,"a\nb"]},"n":2.5}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escapes() {
        let v = parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12abc").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" \n{ \"a\" :\t1 , \"b\" : [ ] }\r\n").unwrap();
        assert_eq!(v.req_usize("a").unwrap(), 1);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 0);
    }

    // ---- adversarial inputs: the parser faces raw network bytes via the
    // HTTP front-end; every hostile shape must Err, never panic

    #[test]
    fn truncated_inputs_error_cleanly() {
        let docs = [
            "{\"a\":", "{\"a\"", "{\"a", "{\"", "[1, 2", "[1,", "\"abc", "\"ab\\",
            "tru", "fal", "nul", "-", "1e", "{\"a\": \"b", "[[1, [2, [3",
        ];
        for doc in docs {
            assert!(parse(doc).is_err(), "truncated '{doc}' must not parse");
        }
        // every prefix of a valid document either parses or errors — no
        // index panics anywhere in the byte range
        let full = r#"{"k":[1,-2.5e3,"a\u0041\n",true,null],"m":{"x":[[]]}}"#;
        for cut in 0..full.len() {
            if full.is_char_boundary(cut) {
                let _ = parse(&full[..cut]);
            }
        }
    }

    #[test]
    fn nesting_bomb_is_bounded_not_stack_overflow() {
        // far past MAX_DEPTH: must Err (pre-limit this aborted the process)
        let bomb = "[".repeat(100_000);
        assert!(parse(&bomb).is_err());
        let obj_bomb = "{\"a\":".repeat(100_000);
        assert!(parse(&obj_bomb).is_err());
        // mixed nesting, closed properly but too deep, still refused
        let deep = format!("{}{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(parse(&deep).is_err());
        // at the limit it parses
        let ok = format!("{}{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn overlong_and_overflowing_numbers_error() {
        let long = "9".repeat(MAX_NUMBER_LEN + 1);
        assert!(parse(&long).is_err(), "overlong literal must be refused");
        assert!(parse("1e999").is_err(), "f64 overflow is not a JSON number");
        assert!(parse("-1e999").is_err());
        // at the cap and representable: fine
        assert!(parse(&"9".repeat(64)).is_ok());
    }

    #[test]
    fn invalid_unicode_escapes_error() {
        assert!(parse("\"\\uZZZZ\"").is_err(), "non-hex digits");
        assert!(parse("\"\\u12\"").is_err(), "too few digits");
        assert!(parse("\"\\u12").is_err(), "truncated mid-escape");
        assert!(parse("\"\\u").is_err(), "truncated at escape start");
        assert!(parse("\"\\uD800\"").is_err(), "lone surrogate is not a char");
        assert!(parse("\"\\x41\"").is_err(), "unknown escape letter");
        // valid escape still works
        assert_eq!(parse("\"\\u0041\"").unwrap().as_str(), Some("A"));
    }

    #[test]
    fn manifest_like() {
        let src = r#"{"params":[{"name":"w","shape":[2,3]}],"adam":{"b1":0.9}}"#;
        let v = parse(src).unwrap();
        let p0 = v.get("params").unwrap().idx(0).unwrap();
        assert_eq!(p0.req_str("name").unwrap(), "w");
        let shape: Vec<usize> = p0.get("shape").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![2, 3]);
    }
}
