//! Experiment orchestration shared by the CLI, the examples, and every
//! paper-table bench: environment setup (artifacts + corpora + trained
//! checkpoint), the full RaanA pipeline, and baseline application.

pub mod tables;

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::allocate::{AllocProblem, Allocation};
use crate::baselines;
use crate::calib::{calibrate, CalibMode, CalibResult};
use crate::data::{synthc4, synthwiki, Corpus};
use crate::eval::perplexity;
use crate::model::{artifacts_root, ModelParams};
use crate::quant::TrickConfig;
use crate::runtime::{ModelRuntime, PackedLayers, Runtime};
use crate::train::{train, TrainConfig};
use crate::util::Timer;

/// A ready-to-experiment environment: runtime + corpora + trained weights.
pub struct Env {
    pub rt: Runtime,
    pub mrt: ModelRuntime,
    pub wiki: Corpus,
    pub c4: Corpus,
    pub params: ModelParams,
    pub ckpt_path: PathBuf,
}

/// Corpus sizing: enough test sequences to be meaningful, small enough for
/// CPU evaluation. Overridable via env for quick runs.
fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

impl Env {
    /// Load model artifacts, build corpora, and train (or load) weights.
    pub fn load(model: &str) -> Result<Self> {
        let root = artifacts_root();
        let rt = Runtime::cpu()?;
        let mrt = ModelRuntime::load(&rt, &root, model)
            .with_context(|| format!("loading model '{model}'"))?;
        let seq = mrt.manifest.seq_len;

        let train_seqs = env_usize("RAANA_TRAIN_SEQS", 2000);
        let test_seqs = env_usize("RAANA_TEST_SEQS", 64);
        let total = (train_seqs + test_seqs) * seq;
        let wiki = Corpus::from_text(
            &synthwiki(total, 42),
            seq,
            test_seqs as f64 / (train_seqs + test_seqs) as f64,
        );
        // c4-analog: test-only usage, but keep a small train split for
        // its few-shot calibration variant.
        let c4 = Corpus::from_text(&synthc4((256 + test_seqs) * seq, 43), seq,
            test_seqs as f64 / (256 + test_seqs) as f64);

        let ckpt_path = root.join(model).join("trained.rkpt");
        let params = if ckpt_path.exists() {
            crate::info!("loading checkpoint {}", ckpt_path.display());
            ModelParams::load(&ckpt_path)?
        } else {
            let mut params = mrt.init(7)?;
            let steps = env_usize("RAANA_TRAIN_STEPS", 300);
            crate::info!("no checkpoint; training {steps} steps");
            let cfg = TrainConfig { steps, ..Default::default() };
            train(&mrt, &mut params, &wiki, &cfg)?;
            params.save(&ckpt_path)?;
            params
        };
        Ok(Env { rt, mrt, wiki, c4, params, ckpt_path })
    }

    pub fn perplexity(&self, params: &ModelParams, corpus: &Corpus, cap: usize) -> Result<f64> {
        perplexity(&self.mrt, params, corpus, cap)
    }
}

/// Per-layer record in a quantization report.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub bits: u8,
    pub avg_bits: f64,
    pub recon_rel_err: f64,
}

/// Outcome of quantizing a whole model.
pub struct QuantReport {
    pub layers: Vec<LayerReport>,
    /// Weighted average stored bits per quantizable parameter.
    pub avg_bits: f64,
    /// Wall-clock seconds: (calibration, allocation, quantization).
    pub secs: (f64, f64, f64),
    pub alloc_cost: f64,
}

/// Analytic per-layer side-payload estimate (bits per parameter) so the DP
/// budget can target the *total* average the tables report.
pub fn overhead_bits_per_param(d: usize, c: usize, tricks: &TrickConfig) -> f64 {
    let m = (d * c) as f64;
    let mut bits = c as f64 * 16.0; // rescale r per column (fp16)
    bits += d as f64; // RHT signs (~1 bit per dim; Alg. 5 uses <= 2*d_hat)
    let n_out = (tricks.col_outlier_frac * d as f64).ceil();
    bits += n_out * (c as f64 * 16.0 + 16.0); // fp16 rows + indices
    if tricks.centralization {
        bits += (d + c) as f64 * 16.0; // s_hat + bias correction (fp16)
    }
    bits / m
}

/// The full RaanA pipeline (paper Alg. 1): calibrate -> AllocateBits ->
/// RaBitQ-H each layer -> fold reconstructions back into a param set.
pub fn raana_quantize(
    env: &Env,
    mode: &CalibMode,
    target_avg_bits: f64,
    bit_choices: &[u8],
    tricks: &TrickConfig,
    seed: u64,
    threads: usize,
) -> Result<(ModelParams, QuantReport)> {
    let t0 = Timer::start();
    let calib = calibrate(&env.mrt, &env.params, mode, &env.wiki)?;
    let calib_secs = t0.secs();

    let (qparams, mut report) = raana_quantize_with_calib(
        env, &calib, target_avg_bits, bit_choices, tricks, seed, threads,
    )?;
    report.secs.0 = calib_secs;
    Ok((qparams, report))
}

/// AllocateBits over the calibration alphas: budget the *code* bits =
/// target minus the analytic side-payload overhead, then solve the DP.
fn allocate_layer_bits(
    env: &Env,
    calib: &CalibResult,
    target_avg_bits: f64,
    bit_choices: &[u8],
    tricks: &TrickConfig,
) -> Result<Allocation> {
    let linears = &env.mrt.manifest.linears;
    let total_m: usize = linears.iter().map(|l| l.m).sum();
    let mean_overhead: f64 = linears
        .iter()
        .map(|l| overhead_bits_per_param(l.d, l.c, tricks) * l.m as f64)
        .sum::<f64>()
        / total_m as f64;
    let code_budget_avg = (target_avg_bits - mean_overhead).max(1.0);
    let problem = AllocProblem {
        alphas: calib.alphas.clone(),
        m: linears.iter().map(|l| l.m).collect(),
        bit_choices: bit_choices.to_vec(),
        budget: AllocProblem::budget_for_avg_bits(
            &linears.iter().map(|l| l.m).collect::<Vec<_>>(),
            code_budget_avg,
        ),
    };
    problem.solve()
}

/// Pipeline minus calibration (reuse a [`CalibResult`] across bit targets).
///
/// Folds every layer's dense reconstruction back into a parameter set —
/// the evaluation path. The serving path keeps codes packed instead: see
/// [`raana_quantize_packed_with_calib`].
pub fn raana_quantize_with_calib(
    env: &Env,
    calib: &CalibResult,
    target_avg_bits: f64,
    bit_choices: &[u8],
    tricks: &TrickConfig,
    seed: u64,
    threads: usize,
) -> Result<(ModelParams, QuantReport)> {
    let (packed, report) = raana_quantize_packed_with_calib(
        env, calib, target_avg_bits, bit_choices, tricks, seed, threads,
    )?;
    let linears = &env.mrt.manifest.linears;
    let mut qparams = env.params.clone();
    for (ql, lin) in packed.layers.iter().zip(linears) {
        let (w_hat, corr) = ql.reconstruct();
        qparams.set_matrix(&lin.param, &w_hat)?;
        let bias = qparams.get_mut(&lin.bias)?;
        for (b, c) in bias.iter_mut().zip(&corr) {
            *b += c;
        }
    }
    Ok((qparams, report))
}

/// Pipeline minus calibration, ending in **resident packed weights**:
/// AllocateBits -> RaBitQ-H per layer, with codes kept bit-packed for
/// `ModelRuntime::attach_packed` / `Server::start_native_packed`. The
/// original `env.params` stay untouched (biases included — the packed
/// forward adds its own rank-1 correction), so serving needs no dense
/// dequantized weight copy at all.
pub fn raana_quantize_packed_with_calib(
    env: &Env,
    calib: &CalibResult,
    target_avg_bits: f64,
    bit_choices: &[u8],
    tricks: &TrickConfig,
    seed: u64,
    threads: usize,
) -> Result<(PackedLayers, QuantReport)> {
    let m = &env.mrt.manifest;
    let linears = &m.linears;
    let total_m: usize = linears.iter().map(|l| l.m).sum();

    let t1 = Timer::start();
    let alloc = allocate_layer_bits(env, calib, target_avg_bits, bit_choices, tricks)?;
    let alloc_secs = t1.secs();

    let t2 = Timer::start();
    let packed = PackedLayers::quantize(
        m,
        &env.params,
        &alloc.bits,
        &calib.layer_stats,
        tricks,
        seed,
        threads,
    )?;
    let mut layers = Vec::with_capacity(linears.len());
    let mut bits_acc = 0f64;
    for (k, (ql, lin)) in packed.layers.iter().zip(linears).enumerate() {
        let w = env.params.matrix(&lin.param)?;
        bits_acc += ql.avg_bits() * lin.m as f64;
        layers.push(LayerReport {
            name: lin.name.clone(),
            bits: alloc.bits[k],
            avg_bits: ql.avg_bits(),
            recon_rel_err: ql.recon_rel_err(&w),
        });
    }
    let quant_secs = t2.secs();

    Ok((
        packed,
        QuantReport {
            layers,
            avg_bits: bits_acc / total_m as f64,
            secs: (0.0, alloc_secs, quant_secs),
            alloc_cost: alloc.cost,
        },
    ))
}

/// The full packed pipeline (paper Alg. 1, serving form): calibrate ->
/// AllocateBits -> RaBitQ-H, returning bit-packed layers for the request
/// path.
pub fn raana_quantize_packed(
    env: &Env,
    mode: &CalibMode,
    target_avg_bits: f64,
    bit_choices: &[u8],
    tricks: &TrickConfig,
    seed: u64,
    threads: usize,
) -> Result<(PackedLayers, QuantReport)> {
    let t0 = Timer::start();
    let calib = calibrate(&env.mrt, &env.params, mode, &env.wiki)?;
    let calib_secs = t0.secs();
    let (packed, mut report) = raana_quantize_packed_with_calib(
        env, &calib, target_avg_bits, bit_choices, tricks, seed, threads,
    )?;
    report.secs.0 = calib_secs;
    Ok((packed, report))
}

/// Artifact-free packed-serving fixture shared by the CLI demo
/// (`raana serve` without artifacts), the `generate_kv` example, and
/// `benches/kernels.rs`: a synthetic GPT-2-style manifest (`seq_len` 128,
/// byte vocab, `eval_batch` 8), natively initialized weights, calibration
/// statistics captured with one native forward, and every registered
/// linear RaBitQ-quantized at `bits` with the paper's default tricks.
///
/// `d_model` must be divisible by 4 (the fixture's head count).
pub fn native_demo_packed(
    name: &str,
    d_model: usize,
    n_layers: usize,
    bits: u8,
    seed: u64,
) -> Result<(crate::model::Manifest, ModelParams, PackedLayers)> {
    use crate::model::synthetic_manifest;
    use crate::runtime::native_init;

    anyhow::ensure!((1..=8).contains(&bits), "bits must be in 1..=8, got {bits}");
    let manifest = synthetic_manifest(name, d_model, n_layers, 4, 4 * d_model, 128, 256, 8);
    let params = native_init(&manifest, seed);

    // calibration statistics from one native capture forward, so the
    // packed layers exercise outliers + centralization like a real run
    let probe = ModelRuntime::native(manifest.clone())?;
    let calib_tokens: Vec<i32> = crate::data::tokenize(&crate::data::zero_shot_text())
        .into_iter()
        .cycle()
        .take(manifest.eval_batch * manifest.seq_len)
        .collect();
    let stats = probe
        .native_model
        .capture_layer_stats(&manifest, &params, &calib_tokens, 0)?;
    let packed = PackedLayers::quantize(
        &manifest,
        &params,
        &vec![bits; manifest.linears.len()],
        &stats,
        &TrickConfig::default(),
        seed,
        0,
    )?;
    Ok((manifest, params, packed))
}

/// Baseline method selector for the table benches.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Baseline {
    Rtn,
    Gptq,
    Awq,
    EasyQuant,
}

impl Baseline {
    pub fn name(&self) -> &'static str {
        match self {
            Baseline::Rtn => "RTN",
            Baseline::Gptq => "GPTQ",
            Baseline::Awq => "AWQ",
            Baseline::EasyQuant => "EasyQuant",
        }
    }
}

/// Apply a baseline uniformly at `bits` to every registered linear layer.
pub fn baseline_quantize(
    env: &Env,
    calib: &CalibResult,
    method: Baseline,
    bits: u8,
) -> Result<(ModelParams, f64)> {
    let m = &env.mrt.manifest;
    let group = 128.min(m.d_model);
    let mut qparams = env.params.clone();
    let mut bits_acc = 0f64;
    let mut total_m = 0usize;
    for (k, lin) in m.linears.iter().enumerate() {
        let w = env.params.matrix(&lin.param)?;
        let res = match method {
            Baseline::Rtn => baselines::rtn_quantize(&w, bits, group),
            Baseline::Gptq => {
                baselines::gptq_quantize(&w, bits, group, &calib.hessians[k])?
            }
            Baseline::Awq => baselines::awq_quantize(
                &w,
                bits,
                group,
                &calib.act_mean_abs[k],
                0.5,
            ),
            Baseline::EasyQuant => {
                baselines::easyquant_quantize(&w, bits, group, 0.003)
            }
        };
        qparams.set_matrix(&lin.param, &res.w_hat)?;
        bits_acc += res.avg_bits * lin.m as f64;
        total_m += lin.m;
    }
    Ok((qparams, bits_acc / total_m as f64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_estimate_is_small() {
        let tricks = TrickConfig::default();
        let o = overhead_bits_per_param(256, 256, &tricks);
        assert!(o > 0.0 && o < 0.35, "overhead {o}");
        let o_none = overhead_bits_per_param(256, 256, &TrickConfig::none());
        assert!(o_none < o);
    }

    #[test]
    fn overhead_shrinks_with_layer_size() {
        let tricks = TrickConfig::default();
        let small = overhead_bits_per_param(64, 64, &tricks);
        let large = overhead_bits_per_param(1024, 1024, &tricks);
        assert!(large < small);
    }
}
