//! Paper-table regenerators (DESIGN.md per-experiment index).
//!
//! Each function prints and returns the same row structure the paper
//! reports; `cargo bench --bench tableN` wraps these. Absolute perplexities
//! differ from the paper (tiny byte-level models on synthetic corpora —
//! see DESIGN.md §Substitutions) but the comparison *shape* is the target:
//! who wins at which bit-width, where methods break down, and the
//! few-shot/zero-shot gap.

use anyhow::Result;

use crate::benchlib::{fmt_ppl, Table};
use crate::calib::{calibrate, CalibMode};
use crate::data::Corpus;
use crate::quant::TrickConfig;
use crate::util::Timer;

use super::{
    baseline_quantize, raana_quantize_with_calib, Baseline, Env,
};

/// Which corpus a table evaluates on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Dataset {
    SynthWiki,
    SynthC4,
}

impl Dataset {
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::SynthWiki => "synthwiki (wikitext2 analog)",
            Dataset::SynthC4 => "synthc4 (c4 analog)",
        }
    }

    fn corpus<'a>(&self, env: &'a Env) -> &'a Corpus {
        match self {
            Dataset::SynthWiki => &env.wiki,
            Dataset::SynthC4 => &env.c4,
        }
    }
}

/// Tables 1 & 4: perplexity, methods x bit-widths.
///
/// Baselines run at uniform {2,3,4} bits with grouping (the paper's "2+"
/// rows); RaanA runs at {2.1, 2.3, 3.1, 3.3, 4.1, 4.3} *total* average
/// bits with few-shot calibration.
pub fn method_grid(env: &Env, dataset: Dataset, eval_cap: usize) -> Result<Table> {
    let corpus = dataset.corpus(env);
    let mut table = Table::new(&["Method", "Avg. bits", "ppl"]);

    let ppl_fp = env.perplexity(&env.params, corpus, eval_cap)?;
    table.row(vec!["fp32".into(), "32".into(), fmt_ppl(ppl_fp)]);

    let calib = calibrate(&env.mrt, &env.params, &CalibMode::FewShot(5), &env.wiki)?;

    for bits in [2u8, 3, 4] {
        for method in [
            Baseline::Rtn,
            Baseline::Gptq,
            Baseline::Awq,
            Baseline::EasyQuant,
        ] {
            let (qp, avg) = baseline_quantize(env, &calib, method, bits)?;
            let ppl = env.perplexity(&qp, corpus, eval_cap)?;
            table.row(vec![
                method.name().into(),
                format!("{avg:.2}"),
                fmt_ppl(ppl),
            ]);
        }
        for extra in [0.1f64, 0.3] {
            let target = bits as f64 + extra;
            let (qp, report) = raana_quantize_with_calib(
                env,
                &calib,
                target,
                &(1..=8).collect::<Vec<u8>>(),
                &TrickConfig::default(),
                7,
                0,
            )?;
            let ppl = env.perplexity(&qp, corpus, eval_cap)?;
            table.row(vec![
                "RaanA".into(),
                format!("{:.2}", report.avg_bits),
                fmt_ppl(ppl),
            ]);
        }
    }
    Ok(table)
}

/// Tables 2 & 5: zero-shot vs few-shot calibration.
pub fn calib_comparison(env: &Env, dataset: Dataset, eval_cap: usize) -> Result<Table> {
    let corpus = dataset.corpus(env);
    let mut table = Table::new(&["Method", "Avg. bits", "ppl"]);
    let ppl_fp = env.perplexity(&env.params, corpus, eval_cap)?;
    table.row(vec!["fp32".into(), "32".into(), fmt_ppl(ppl_fp)]);

    let calib_few = calibrate(&env.mrt, &env.params, &CalibMode::FewShot(5), &env.wiki)?;
    let calib_zero = calibrate(&env.mrt, &env.params, &CalibMode::ZeroShot, &env.wiki)?;

    for target in [2.1f64, 3.1, 4.1] {
        for (name, calib) in [("RaanA-few", &calib_few), ("RaanA-zero", &calib_zero)] {
            let (qp, report) = raana_quantize_with_calib(
                env,
                calib,
                target,
                &(1..=8).collect::<Vec<u8>>(),
                &TrickConfig::default(),
                7,
                0,
            )?;
            let ppl = env.perplexity(&qp, corpus, eval_cap)?;
            table.row(vec![
                name.into(),
                format!("{:.2}", report.avg_bits),
                fmt_ppl(ppl),
            ]);
        }
    }
    Ok(table)
}

/// Table 3: quantization wall-clock time vs model size (RaanA @ 2.1 bits,
/// few-shot). Also reports the per-phase split the paper discusses in §6.3.
pub fn quant_time(models: &[&str]) -> Result<Table> {
    let mut table = Table::new(&[
        "Model", "Params", "Total (s)", "Calib (s)", "Alloc (s)", "RaBitQ-H (s)",
    ]);
    for model in models {
        let env = Env::load(model)?;
        let timer = Timer::start();
        let calib = calibrate(&env.mrt, &env.params, &CalibMode::FewShot(5), &env.wiki)?;
        let calib_secs = timer.secs();
        let (_qp, report) = raana_quantize_with_calib(
            &env,
            &calib,
            2.1,
            &(1..=8).collect::<Vec<u8>>(),
            &TrickConfig::default(),
            7,
            0,
        )?;
        table.row(vec![
            model.to_string(),
            format!("{}", env.mrt.manifest.total_params()),
            format!("{:.2}", timer.secs()),
            format!("{calib_secs:.2}"),
            format!("{:.3}", report.secs.1),
            format!("{:.2}", report.secs.2),
        ]);
    }
    Ok(table)
}

/// Ablation A2: tricks on/off (paper App. C.3).
pub fn ablate_tricks(env: &Env, eval_cap: usize) -> Result<Table> {
    let mut table = Table::new(&["Tricks", "Avg. bits", "ppl"]);
    let ppl_fp = env.perplexity(&env.params, &env.wiki, eval_cap)?;
    table.row(vec!["fp32".into(), "32".into(), fmt_ppl(ppl_fp)]);
    let calib = calibrate(&env.mrt, &env.params, &CalibMode::FewShot(5), &env.wiki)?;

    let variants: Vec<(&str, TrickConfig)> = vec![
        ("none", TrickConfig::none()),
        ("centralization", TrickConfig {
            col_outlier_frac: 0.0,
            ..TrickConfig::default()
        }),
        ("col-outliers", TrickConfig {
            centralization: false,
            ..TrickConfig::default()
        }),
        ("both (paper)", TrickConfig::default()),
    ];
    for target in [2.3f64, 3.3] {
        for (name, tricks) in &variants {
            let (qp, report) = raana_quantize_with_calib(
                env,
                &calib,
                target,
                &(1..=8).collect::<Vec<u8>>(),
                tricks,
                7,
                0,
            )?;
            let ppl = env.perplexity(&qp, &env.wiki, eval_cap)?;
            table.row(vec![
                format!("{name} @{target}"),
                format!("{:.2}", report.avg_bits),
                fmt_ppl(ppl),
            ]);
        }
    }
    Ok(table)
}
