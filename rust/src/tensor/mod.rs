//! Dense f32 tensor substrate (no ndarray crate in the offline vendor set).
//!
//! Row-major [`Matrix`] plus the linear algebra the quantizers need:
//! matmul (naive + cache-blocked), transpose, Frobenius/row/column norms,
//! Cholesky decomposition and SPD inversion (for the GPTQ baseline's
//! Hessian), and simple elementwise helpers.

use std::fmt;

/// Row-major dense f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column j (strided gather).
    pub fn col(&self, j: usize) -> Vec<f32> {
        self.col_view(j).to_vec()
    }

    /// Borrowing strided view of column j — no allocation. The quantizer
    /// hot loops gather columns through this into reused buffers instead
    /// of calling [`Matrix::col`] per column.
    #[inline]
    pub fn col_view(&self, j: usize) -> Col<'_> {
        assert!(j < self.cols, "column {j} out of bounds ({})", self.cols);
        Col { data: &self.data, cols: self.cols, rows: self.rows, j }
    }

    /// Iterator over column j's elements (top to bottom).
    #[inline]
    pub fn col_iter(&self, j: usize) -> impl Iterator<Item = f32> + '_ {
        self.col_view(j).iter()
    }

    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            *self.at_mut(i, j) = v[i];
        }
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// `self @ other` via the register-tiled parallel kernel
    /// ([`crate::kernels::gemm`]); `RAANA_THREADS` bounds the worker count.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        self.matmul_threaded(other, 0)
    }

    /// `self @ other` with an explicit thread count (0 = default). The
    /// result is bit-deterministic in `threads`.
    pub fn matmul_threaded(&self, other: &Matrix, threads: usize) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        crate::kernels::gemm(m, k, n, &self.data, &other.data, &mut out.data, threads);
        out
    }

    /// `self @ v` for a vector.
    pub fn matvec(&self, v: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| {
                self.row(i).iter().zip(v).map(|(a, b)| a * b).sum::<f32>()
            })
            .collect()
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// L2 norm of each column.
    pub fn col_norms(&self) -> Vec<f64> {
        let mut acc = vec![0f64; self.cols];
        for i in 0..self.rows {
            for (j, &x) in self.row(i).iter().enumerate() {
                acc[j] += (x as f64) * (x as f64);
            }
        }
        acc.into_iter().map(f64::sqrt).collect()
    }

    /// L2 norm of each row.
    pub fn row_norms(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|i| {
                self.row(i).iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
            })
            .collect()
    }

    /// Mean of each column.
    pub fn col_means(&self) -> Vec<f32> {
        let mut acc = vec![0f64; self.cols];
        for i in 0..self.rows {
            for (j, &x) in self.row(i).iter().enumerate() {
                acc[j] += x as f64;
            }
        }
        acc.into_iter().map(|s| (s / self.rows as f64) as f32).collect()
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// Relative Frobenius error ||self - other||_F / ||other||_F.
    pub fn rel_err(&self, other: &Matrix) -> f64 {
        let denom = other.frobenius_norm().max(1e-30);
        self.sub(other).frobenius_norm() / denom
    }
}

/// Borrowing strided column view into a row-major [`Matrix`].
///
/// Created by [`Matrix::col_view`]; replaces per-call `Vec` gathers in the
/// quantizer hot loops (`rabitq`, `hadamard`) — callers copy into a reused
/// buffer via [`Col::copy_into`] or stream via [`Col::iter`].
#[derive(Clone, Copy)]
pub struct Col<'a> {
    data: &'a [f32],
    cols: usize,
    rows: usize,
    j: usize,
}

impl<'a> Col<'a> {
    #[inline]
    pub fn len(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Element i of the column.
    #[inline]
    pub fn at(&self, i: usize) -> f32 {
        self.data[i * self.cols + self.j]
    }

    /// Iterate the column top to bottom.
    #[inline]
    pub fn iter(&self) -> impl Iterator<Item = f32> + 'a {
        let (data, cols, j) = (self.data, self.cols, self.j);
        (0..self.rows).map(move |i| data[i * cols + j])
    }

    /// Copy the column into `out[..len]` (the reused-buffer hot path).
    pub fn copy_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.rows, "column copy length mismatch");
        let (cols, j) = (self.cols, self.j);
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.data[i * cols + j];
        }
    }

    /// Owned copy (what [`Matrix::col`] returns).
    pub fn to_vec(&self) -> Vec<f32> {
        let mut v = vec![0f32; self.rows];
        self.copy_into(&mut v);
        v
    }
}

/// Cholesky decomposition of a symmetric positive-definite matrix: A = L L^T.
/// Returns the lower-triangular L, or None if A is not SPD (within jitter).
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= (l.at(i, k) as f64) * (l.at(j, k) as f64);
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                *l.at_mut(i, j) = sum.sqrt() as f32;
            } else {
                *l.at_mut(i, j) = (sum / l.at(j, j) as f64) as f32;
            }
        }
    }
    Some(l)
}

/// Inverse of an SPD matrix via Cholesky (A^-1 = L^-T L^-1).
pub fn spd_inverse(a: &Matrix) -> Option<Matrix> {
    let n = a.rows;
    let l = cholesky(a)?;
    // Solve L X = I column by column (forward substitution), then L^T A^-1 = X.
    let mut inv = Matrix::zeros(n, n);
    for col in 0..n {
        // forward: L y = e_col
        let mut y = vec![0f64; n];
        for i in 0..n {
            let mut s = if i == col { 1.0 } else { 0.0 };
            for k in 0..i {
                s -= (l.at(i, k) as f64) * y[k];
            }
            y[i] = s / l.at(i, i) as f64;
        }
        // backward: L^T x = y
        let mut x = vec![0f64; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= (l.at(k, i) as f64) * x[k];
            }
            x[i] = s / l.at(i, i) as f64;
        }
        for i in 0..n {
            *inv.at_mut(i, col) = x[i] as f32;
        }
    }
    Some(inv)
}

/// Dot product of two f32 slices in f64 accumulation.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| (x as f64) * (y as f64)).sum()
}

/// L2 norm of an f32 slice in f64 accumulation.
#[inline]
pub fn norm(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_matrix(r: usize, c: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(r, c, rng.gaussian_vec(r * c))
    }

    #[test]
    fn matmul_identity() {
        let a = random_matrix(5, 5, 1);
        let i = Matrix::eye(5);
        assert!(a.matmul(&i).rel_err(&a) < 1e-6);
        assert!(i.matmul(&a).rel_err(&a) < 1e-6);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_associative_with_transpose() {
        let a = random_matrix(7, 4, 2);
        let b = random_matrix(4, 9, 3);
        let c = a.matmul(&b);
        let ct = b.transpose().matmul(&a.transpose());
        assert!(c.transpose().rel_err(&ct) < 1e-5);
    }

    #[test]
    fn transpose_involution() {
        let a = random_matrix(13, 7, 4);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = random_matrix(6, 8, 5);
        let v: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let got = a.matvec(&v);
        let vm = Matrix::from_vec(8, 1, v);
        let want = a.matmul(&vm);
        for (g, w) in got.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-4);
        }
    }

    #[test]
    fn norms() {
        let a = Matrix::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]);
        assert!((a.frobenius_norm() - 5.0).abs() < 1e-9);
        let cn = a.col_norms();
        assert!((cn[0] - 3.0).abs() < 1e-9 && (cn[1] - 4.0).abs() < 1e-9);
        let rn = a.row_norms();
        assert!((rn[0] - 3.0).abs() < 1e-9 && (rn[1] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn col_means_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 10.0, 3.0, 30.0]);
        let m = a.col_means();
        assert!((m[0] - 2.0).abs() < 1e-6 && (m[1] - 20.0).abs() < 1e-6);
    }

    #[test]
    fn cholesky_reconstructs() {
        // A = B B^T + n*I is SPD
        let b = random_matrix(8, 8, 6);
        let mut a = b.matmul(&b.transpose());
        for i in 0..8 {
            *a.at_mut(i, i) += 8.0;
        }
        let l = cholesky(&a).expect("SPD");
        let rec = l.matmul(&l.transpose());
        assert!(rec.rel_err(&a) < 1e-4);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig -1
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn spd_inverse_works() {
        let b = random_matrix(6, 6, 7);
        let mut a = b.matmul(&b.transpose());
        for i in 0..6 {
            *a.at_mut(i, i) += 6.0;
        }
        let inv = spd_inverse(&a).expect("SPD");
        let prod = a.matmul(&inv);
        assert!(prod.rel_err(&Matrix::eye(6)) < 1e-3);
    }

    #[test]
    fn col_view_matches_col() {
        let a = random_matrix(7, 5, 9);
        for j in 0..5 {
            let v = a.col(j);
            let cv = a.col_view(j);
            assert_eq!(cv.len(), 7);
            assert!(!cv.is_empty());
            for i in 0..7 {
                assert_eq!(cv.at(i), v[i]);
            }
            let streamed: Vec<f32> = a.col_iter(j).collect();
            assert_eq!(streamed, v);
            let mut buf = vec![0f32; 7];
            cv.copy_into(&mut buf);
            assert_eq!(buf, v);
        }
    }

    #[test]
    fn col_view_empty_matrix() {
        let a = Matrix::zeros(0, 3);
        let cv = a.col_view(1);
        assert_eq!(cv.len(), 0);
        assert!(cv.is_empty());
        assert_eq!(cv.to_vec(), Vec::<f32>::new());
        assert_eq!(a.col_iter(2).count(), 0);
    }

    #[test]
    #[should_panic]
    fn col_view_out_of_bounds_panics() {
        let a = Matrix::zeros(2, 2);
        let _ = a.col_view(2);
    }

    #[test]
    fn matmul_threaded_deterministic() {
        let a = random_matrix(33, 21, 10);
        let b = random_matrix(21, 19, 11);
        let c1 = a.matmul_threaded(&b, 1);
        let c8 = a.matmul_threaded(&b, 8);
        assert_eq!(c1.data, c8.data);
    }

    #[test]
    fn set_col_roundtrip() {
        let mut a = Matrix::zeros(4, 3);
        let v = vec![1.0, 2.0, 3.0, 4.0];
        a.set_col(1, &v);
        assert_eq!(a.col(1), v);
        assert_eq!(a.col(0), vec![0.0; 4]);
    }
}
