//! Immutable sealed segments and the manifest that lists them: the
//! on-disk layout that replaced the PR-6 monolithic whole-store
//! snapshot.
//!
//! A collection's rows now live in two places: a **mutable head** (the
//! packed-code/rescale/residual buffers inside
//! [`super::Collection`] that `add` appends to) and a list of
//! **immutable sealed segments** ([`SegmentData`]). Sealing moves the
//! head's buffers wholesale into a new segment and writes them to one
//! per-collection CRC'd segment file — O(head rows), not O(store
//! rows), which is the whole point: the old design re-encoded every
//! row of every collection on each cadence snapshot. A small
//! **manifest** file then lists the live segments plus the sequence
//! cursor; writing the manifest (atomic temp + fsync + rename through
//! the [`super::io::Io`] seam) is the single commit point of a seal or
//! a compaction swap.
//!
//! Because RaBitQ codes are deterministic and recoding is
//! lossless-from-exact, a segment file *is* the exact serving layout:
//! recovery loads the bytes straight back (or requantizes from the
//! residual store when a rebalance changed the collection's width
//! after the segment was written — bit-identical to a fresh encode).
//!
//! ## Segment wire format (all integers little-endian)
//!
//! ```text
//! [magic: "RQSG"] [version: u32 = 1]
//! [name_len: u16] [name] [id: u64]
//! [d: u32] [bits: u8] [metric: u8]          metric: 0 = ip, 1 = cosine
//! [nrows: u32]
//! [codes_len: u32] [codes bytes]
//! [r: nrows * f32]
//! [exact: nrows * d * f32]
//! [crc: u32]                                CRC-32 of every prior byte
//! ```
//!
//! Segment files live in `DIR/segments/<name>-<id, zero-padded>.seg`;
//! ids are store-global and monotone, so a file is written exactly
//! once and never modified (compaction writes *new* ids and deletes
//! the replaced files only after the manifest swap).
//!
//! ## Manifest wire format (all integers little-endian)
//!
//! ```text
//! [magic: "RQMF"] [version: u32 = 1]
//! [gen: u64] [next_seq: u64] [next_seg_id: u64] [rows_at_solve: u64]
//! [n_collections: u32]
//! per collection, name order:
//!   [name_len: u16] [name]
//!   [d: u32] [bits: u8] [metric: u8]
//!   [d_hat: u32] [signs1: d_hat * f32]
//!   [signs2_len: u32] [signs2: signs2_len * f32]
//!   [n_segments: u32]  per segment: [id: u64] [rows: u32] [bits: u8]
//! [crc: u32]
//! ```
//!
//! Manifests are named `manifest-<gen, zero-padded>.mf` with a
//! store-global monotone generation, so the newest decodable manifest
//! wins at recovery and a corrupt one falls back to its kept
//! predecessor. A per-segment `bits` that differs from the
//! collection's records that the file on disk predates a rebalance —
//! recovery requantizes those rows from the segment's residual store.

use super::io::Io;
use super::snapshot::Cur;
use super::wal::crc32;
use super::{IndexError, Metric};
use std::path::{Path, PathBuf};

/// Four-byte magic at offset 0 of every segment file.
pub const SEGMENT_MAGIC: &[u8; 4] = b"RQSG";

/// Current segment format version.
pub const SEGMENT_VERSION: u32 = 1;

/// Four-byte magic at offset 0 of every manifest file.
pub const MANIFEST_MAGIC: &[u8; 4] = b"RQMF";

/// Current manifest format version.
pub const MANIFEST_VERSION: u32 = 1;

/// Subdirectory of the data dir holding the sealed segment files.
pub const SEGMENT_DIR: &str = "segments";

// ------------------------------------------------------------- in-memory

/// One immutable sealed segment of a collection: the head's buffers at
/// the moment it was sealed. Codes are always held at the collection's
/// *current* width (a rebalance recodes sealed segments in memory);
/// `disk_bits` remembers the width of the on-disk file, which stays at
/// its sealed width until compaction rewrites it.
#[derive(Clone, Debug)]
pub struct SegmentData {
    /// Store-global segment id (names the on-disk file).
    pub id: u64,
    /// Width of the codes in the on-disk segment file. Equal to the
    /// collection's width at seal time; stale after a rebalance until
    /// compaction rewrites the file.
    pub disk_bits: u8,
    /// Packed codes at the collection's current width.
    pub codes: Vec<u8>,
    /// Per-row least-squares rescales.
    pub r: Vec<f32>,
    /// Residual f32 rows (metric-normalized), rerank side.
    pub exact: Vec<f32>,
}

impl SegmentData {
    /// Rows stored in this segment.
    pub fn rows(&self) -> usize {
        self.r.len()
    }
}

// ----------------------------------------------------------- file naming

/// File name of collection `name`'s segment `id`.
pub fn segment_file_name(name: &str, id: u64) -> String {
    format!("{name}-{id:020}.seg")
}

/// Parse a segment file name back to `(collection, id)`; `None` for
/// strangers. Collection names may contain `-`, so the id is taken
/// from the end.
pub fn parse_segment_file(file: &str) -> Option<(String, u64)> {
    let body = file.strip_suffix(".seg")?;
    let (name, id) = body.rsplit_once('-')?;
    if name.is_empty() || id.len() != 20 || !id.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    Some((name.to_string(), id.parse().ok()?))
}

/// Full path of a segment file under the data dir.
pub fn segment_path(data_dir: &Path, name: &str, id: u64) -> PathBuf {
    data_dir.join(SEGMENT_DIR).join(segment_file_name(name, id))
}

/// File name of the manifest at generation `gen`.
pub fn manifest_file_name(gen: u64) -> String {
    format!("manifest-{gen:020}.mf")
}

/// Parse a manifest file name back to its generation; `None` for
/// non-manifest names.
pub fn parse_manifest_gen(file: &str) -> Option<u64> {
    let body = file.strip_prefix("manifest-")?.strip_suffix(".mf")?;
    if body.len() != 20 || !body.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    body.parse().ok()
}

/// Full path of a manifest file under the data dir.
pub fn manifest_path(data_dir: &Path, gen: u64) -> PathBuf {
    data_dir.join(manifest_file_name(gen))
}

/// Generations of every manifest file in `data_dir`, newest first.
pub fn list_manifests(io: &mut dyn Io, data_dir: &Path) -> Result<Vec<u64>, IndexError> {
    let names = io
        .list(data_dir)
        .map_err(|e| IndexError::Io(format!("listing {}: {e}", data_dir.display())))?;
    let mut gens: Vec<u64> = names.iter().filter_map(|n| parse_manifest_gen(n)).collect();
    gens.sort_unstable_by(|a, b| b.cmp(a));
    Ok(gens)
}

// --------------------------------------------------------- segment codec

fn push_f32s(out: &mut Vec<u8>, vals: &[f32]) {
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn metric_tag(metric: Metric) -> u8 {
    match metric {
        Metric::InnerProduct => 0,
        Metric::Cosine => 1,
    }
}

fn metric_from_tag(tag: u8) -> Result<Metric, IndexError> {
    match tag {
        0 => Ok(Metric::InnerProduct),
        1 => Ok(Metric::Cosine),
        m => Err(corrupt(&format!("unknown metric tag {m}"))),
    }
}

fn corrupt(what: &str) -> IndexError {
    IndexError::Io(format!("segment store corrupt: {what}"))
}

fn overflow() -> IndexError {
    IndexError::Io("segment length overflow".into())
}

/// Serialize one sealed segment of collection `name` to file bytes.
/// `bits` is the width the codes are packed at (the collection's width
/// at write time — recorded so recovery can tell when a later
/// rebalance made the file stale).
pub fn encode_segment(
    name: &str,
    d: usize,
    bits: u8,
    metric: Metric,
    id: u64,
    codes: &[u8],
    r: &[f32],
    exact: &[f32],
) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(SEGMENT_MAGIC);
    out.extend_from_slice(&SEGMENT_VERSION.to_le_bytes());
    out.extend_from_slice(&(name.len() as u16).to_le_bytes());
    out.extend_from_slice(name.as_bytes());
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&(d as u32).to_le_bytes());
    out.push(bits);
    out.push(metric_tag(metric));
    out.extend_from_slice(&(r.len() as u32).to_le_bytes());
    out.extend_from_slice(&(codes.len() as u32).to_le_bytes());
    out.extend_from_slice(codes);
    push_f32s(&mut out, r);
    push_f32s(&mut out, exact);
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// A decoded segment file, before it is checked against the manifest
/// entry that referenced it.
#[derive(Clone, Debug)]
pub struct DecodedSegment {
    /// Collection the segment belongs to.
    pub name: String,
    /// Store-global segment id.
    pub id: u64,
    /// Row dimension.
    pub d: usize,
    /// Width the codes are packed at.
    pub bits: u8,
    /// Similarity metric.
    pub metric: Metric,
    /// Packed codes.
    pub codes: Vec<u8>,
    /// Per-row rescales.
    pub r: Vec<f32>,
    /// Residual f32 rows.
    pub exact: Vec<f32>,
}

/// Decode segment file bytes. Any structural or checksum violation is
/// a typed error — recovery treats it as "this manifest generation is
/// unusable, fall back", never a panic.
pub fn decode_segment(bytes: &[u8]) -> Result<DecodedSegment, IndexError> {
    if bytes.len() < 4 + 4 + 2 + 8 + 4 + 1 + 1 + 4 + 4 + 4 {
        return Err(corrupt("segment too short for a header"));
    }
    let (body, tail) = bytes.split_at(bytes.len() - 4);
    let stored_crc = u32::from_le_bytes(tail.try_into().unwrap());
    if crc32(body) != stored_crc {
        return Err(corrupt("segment checksum mismatch"));
    }
    let mut cur = Cur::new(body);
    if cur.take(4)? != SEGMENT_MAGIC {
        return Err(corrupt("bad segment magic"));
    }
    let version = cur.u32()?;
    if version != SEGMENT_VERSION {
        return Err(IndexError::Io(format!(
            "segment version {version} unsupported (this build reads {SEGMENT_VERSION})"
        )));
    }
    let name_len = cur.u16()? as usize;
    let name = std::str::from_utf8(cur.take(name_len)?)
        .map_err(|_| corrupt("segment collection name not UTF-8"))?
        .to_string();
    let id = cur.u64()?;
    let d = cur.u32()? as usize;
    let bits = cur.u8()?;
    let metric = metric_from_tag(cur.u8()?)?;
    if d == 0 || !(1..=8).contains(&bits) {
        return Err(corrupt("bad segment dimension or bit-width"));
    }
    let nrows = cur.u32()? as usize;
    let codes_len = cur.u32()? as usize;
    let want_codes = nrows
        .checked_mul(d)
        .and_then(|x| x.checked_mul(bits as usize))
        .ok_or_else(overflow)?
        .div_ceil(8);
    if codes_len != want_codes {
        return Err(corrupt("segment code buffer length inconsistent with rows"));
    }
    let codes = cur.take(codes_len)?.to_vec();
    let r = cur.f32s(nrows)?;
    let exact = cur.f32s(nrows.checked_mul(d).ok_or_else(overflow)?)?;
    if !cur.done() {
        return Err(corrupt("trailing bytes after segment payload"));
    }
    Ok(DecodedSegment { name, id, d, bits, metric, codes, r, exact })
}

// -------------------------------------------------------- manifest codec

/// One segment reference inside a manifest: enough to locate the file,
/// validate it, and decide whether it predates a rebalance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ManifestSegment {
    /// Store-global segment id.
    pub id: u64,
    /// Rows the segment holds (validated against the decoded file).
    pub rows: usize,
    /// Width of the codes in the file. When this differs from the
    /// collection's width, recovery requantizes the segment's rows from
    /// its residual store (lossless-from-exact).
    pub bits: u8,
}

/// One collection's entry in a manifest: identity, rotation signs
/// (serialized so the format is self-contained and the numpy mirror
/// can author byte-exact fixtures), current width, and the ordered
/// list of live sealed segments. Head rows are *not* listed — they are
/// covered by the WAL.
#[derive(Clone, Debug)]
pub struct ManifestCollection {
    /// Collection name.
    pub name: String,
    /// Row dimension.
    pub d: usize,
    /// Current code width of the collection.
    pub bits: u8,
    /// Similarity metric.
    pub metric: Metric,
    /// First Rademacher sign diagonal of the rotation.
    pub signs1: Vec<f32>,
    /// Second sign diagonal (empty for single-window rotations).
    pub signs2: Vec<f32>,
    /// Live sealed segments, in seal order (global row order).
    pub segments: Vec<ManifestSegment>,
}

/// The manifest: the small file whose atomic write commits a seal or a
/// compaction swap. Lists every collection's live segments plus the
/// store-global cursors recovery needs.
#[derive(Clone, Debug)]
pub struct StoreManifest {
    /// Monotone generation (names the file; newest decodable wins).
    pub gen: u64,
    /// WAL replay resumes at this store-global sequence number.
    pub next_seq: u64,
    /// Next unused store-global segment id.
    pub next_seg_id: u64,
    /// Row count at the last AllocateBits solve (the rebalance
    /// throttle's reference point).
    pub rows_at_solve: usize,
    /// Per-collection entries, name order.
    pub collections: Vec<ManifestCollection>,
}

/// Serialize a manifest to file bytes.
pub fn encode_manifest(m: &StoreManifest) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MANIFEST_MAGIC);
    out.extend_from_slice(&MANIFEST_VERSION.to_le_bytes());
    out.extend_from_slice(&m.gen.to_le_bytes());
    out.extend_from_slice(&m.next_seq.to_le_bytes());
    out.extend_from_slice(&m.next_seg_id.to_le_bytes());
    out.extend_from_slice(&(m.rows_at_solve as u64).to_le_bytes());
    out.extend_from_slice(&(m.collections.len() as u32).to_le_bytes());
    for c in &m.collections {
        out.extend_from_slice(&(c.name.len() as u16).to_le_bytes());
        out.extend_from_slice(c.name.as_bytes());
        out.extend_from_slice(&(c.d as u32).to_le_bytes());
        out.push(c.bits);
        out.push(metric_tag(c.metric));
        out.extend_from_slice(&(c.signs1.len() as u32).to_le_bytes());
        push_f32s(&mut out, &c.signs1);
        out.extend_from_slice(&(c.signs2.len() as u32).to_le_bytes());
        push_f32s(&mut out, &c.signs2);
        out.extend_from_slice(&(c.segments.len() as u32).to_le_bytes());
        for s in &c.segments {
            out.extend_from_slice(&s.id.to_le_bytes());
            out.extend_from_slice(&(s.rows as u32).to_le_bytes());
            out.push(s.bits);
        }
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Decode manifest file bytes. Typed errors for every structural or
/// checksum violation (recovery falls back to an older generation).
pub fn decode_manifest(bytes: &[u8]) -> Result<StoreManifest, IndexError> {
    if bytes.len() < 4 + 4 + 8 + 8 + 8 + 8 + 4 + 4 {
        return Err(corrupt("manifest too short for a header"));
    }
    let (body, tail) = bytes.split_at(bytes.len() - 4);
    let stored_crc = u32::from_le_bytes(tail.try_into().unwrap());
    if crc32(body) != stored_crc {
        return Err(corrupt("manifest checksum mismatch"));
    }
    let mut cur = Cur::new(body);
    if cur.take(4)? != MANIFEST_MAGIC {
        return Err(corrupt("bad manifest magic"));
    }
    let version = cur.u32()?;
    if version != MANIFEST_VERSION {
        return Err(IndexError::Io(format!(
            "manifest version {version} unsupported (this build reads {MANIFEST_VERSION})"
        )));
    }
    let gen = cur.u64()?;
    let next_seq = cur.u64()?;
    let next_seg_id = cur.u64()?;
    let rows_at_solve = cur.u64()? as usize;
    let n_collections = cur.u32()? as usize;
    let mut collections = Vec::new();
    let mut prev_name: Option<String> = None;
    for _ in 0..n_collections {
        let name_len = cur.u16()? as usize;
        let name = std::str::from_utf8(cur.take(name_len)?)
            .map_err(|_| corrupt("collection name not UTF-8"))?
            .to_string();
        if prev_name.as_deref().is_some_and(|p| p >= name.as_str()) {
            return Err(corrupt("collections not in strict name order"));
        }
        prev_name = Some(name.clone());
        let d = cur.u32()? as usize;
        let bits = cur.u8()?;
        let metric = metric_from_tag(cur.u8()?)?;
        if d == 0 || !(1..=8).contains(&bits) {
            return Err(corrupt("bad dimension or bit-width"));
        }
        let d_hat = cur.u32()? as usize;
        if d_hat == 0 || d_hat > d {
            return Err(corrupt("rotation window larger than dimension"));
        }
        let signs1 = cur.f32s(d_hat)?;
        let signs2_len = cur.u32()? as usize;
        if signs2_len != 0 && signs2_len != d_hat {
            return Err(corrupt("second sign diagonal length mismatch"));
        }
        let signs2 = cur.f32s(signs2_len)?;
        let n_segments = cur.u32()? as usize;
        let mut segments = Vec::new();
        for _ in 0..n_segments {
            let id = cur.u64()?;
            let rows = cur.u32()? as usize;
            let sbits = cur.u8()?;
            if rows == 0 || !(1..=8).contains(&sbits) || id >= next_seg_id {
                return Err(corrupt("bad segment reference"));
            }
            segments.push(ManifestSegment { id, rows, bits: sbits });
        }
        collections.push(ManifestCollection { name, d, bits, metric, signs1, signs2, segments });
    }
    if !cur.done() {
        return Err(corrupt("trailing bytes after last collection"));
    }
    Ok(StoreManifest { gen, next_seq, next_seg_id, rows_at_solve, collections })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn sample_segment() -> Vec<u8> {
        let (n, d, bits) = (5usize, 8usize, 6u8);
        let codes = vec![0xA5u8; (n * d * bits as usize).div_ceil(8)];
        let r: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
        let exact = Rng::new(1).gaussian_vec(n * d);
        encode_segment("docs", d, bits, Metric::Cosine, 7, &codes, &r, &exact)
    }

    fn sample_manifest() -> StoreManifest {
        StoreManifest {
            gen: 3,
            next_seq: 42,
            next_seg_id: 9,
            rows_at_solve: 17,
            collections: vec![ManifestCollection {
                name: "docs".into(),
                d: 8,
                bits: 6,
                metric: Metric::Cosine,
                signs1: vec![1.0, -1.0, 1.0, 1.0, -1.0, -1.0, 1.0, -1.0],
                signs2: vec![],
                segments: vec![
                    ManifestSegment { id: 2, rows: 5, bits: 6 },
                    ManifestSegment { id: 7, rows: 3, bits: 4 },
                ],
            }],
        }
    }

    #[test]
    fn segment_round_trips_bit_for_bit() {
        let bytes = sample_segment();
        let seg = decode_segment(&bytes).unwrap();
        assert_eq!(seg.name, "docs");
        assert_eq!(seg.id, 7);
        assert_eq!((seg.d, seg.bits, seg.metric), (8, 6, Metric::Cosine));
        assert_eq!(seg.r.len(), 5);
        assert_eq!(seg.exact.len(), 40);
        let re = encode_segment(
            &seg.name, seg.d, seg.bits, seg.metric, seg.id, &seg.codes, &seg.r, &seg.exact,
        );
        assert_eq!(re, bytes);
    }

    #[test]
    fn manifest_round_trips_and_orders_strictly() {
        let m = sample_manifest();
        let bytes = encode_manifest(&m);
        let back = decode_manifest(&bytes).unwrap();
        assert_eq!(back.gen, 3);
        assert_eq!(back.next_seq, 42);
        assert_eq!(back.next_seg_id, 9);
        assert_eq!(back.rows_at_solve, 17);
        assert_eq!(back.collections.len(), 1);
        assert_eq!(back.collections[0].segments, m.collections[0].segments);
        assert_eq!(encode_manifest(&back), bytes);
    }

    #[test]
    fn every_corruption_and_truncation_is_rejected() {
        for bytes in [sample_segment(), encode_manifest(&sample_manifest())] {
            let decode = |b: &[u8]| -> bool {
                decode_segment(b).is_ok() || decode_manifest(b).is_ok()
            };
            assert!(decode(&bytes), "pristine bytes must decode");
            for byte in 0..bytes.len() {
                let mut bad = bytes.clone();
                bad[byte] ^= 0x10;
                assert!(!decode(&bad), "flip at byte {byte} must not decode");
            }
            for cut in 0..bytes.len() {
                assert!(!decode(&bytes[..cut]), "truncation to {cut} must not decode");
            }
        }
    }

    #[test]
    fn file_names_round_trip_and_reject_strangers() {
        assert_eq!(parse_segment_file(&segment_file_name("docs", 7)), Some(("docs".into(), 7)));
        assert_eq!(
            parse_segment_file(&segment_file_name("a-b_c", 123)),
            Some(("a-b_c".into(), 123)),
            "names containing '-' parse from the end"
        );
        assert_eq!(parse_segment_file("docs-42.seg"), None, "unpadded");
        assert_eq!(parse_segment_file("manifest-00000000000000000003.mf"), None);
        assert_eq!(parse_manifest_gen(&manifest_file_name(3)), Some(3));
        assert_eq!(parse_manifest_gen("manifest-3.mf"), None, "unpadded");
        assert_eq!(parse_manifest_gen("docs-00000000000000000007.seg"), None);
        assert!(manifest_file_name(9) < manifest_file_name(10), "lexicographic == numeric");
    }

    #[test]
    fn manifest_rejects_unsorted_collections_and_bad_refs() {
        let mut m = sample_manifest();
        m.collections.push(m.collections[0].clone()); // duplicate name
        assert!(decode_manifest(&encode_manifest(&m)).is_err());
        let mut m = sample_manifest();
        m.collections[0].segments[0].id = 99; // >= next_seg_id
        assert!(decode_manifest(&encode_manifest(&m)).is_err());
        let mut m = sample_manifest();
        m.collections[0].segments[0].rows = 0; // empty segments never exist
        assert!(decode_manifest(&encode_manifest(&m)).is_err());
    }
}
