//! Write-ahead log: length-prefixed, CRC-checksummed add records.
//!
//! One WAL file per collection (`wal/<name>.wal` under the data dir);
//! every acknowledged `add` appends exactly one record. Records carry a
//! **store-global** monotone sequence number so recovery can merge the
//! per-collection files back into the original interleaved add order —
//! the Budget policy's rebalance cadence depends on that total order,
//! and bit-for-bit "recovery ≡ fresh build" only holds if replay
//! preserves it.
//!
//! ## Record wire format (all integers little-endian)
//!
//! ```text
//! [len: u32] [crc: u32] [payload: len bytes]
//! payload = [kind: u8 = 1]
//!           [seq: u64]
//!           [name_len: u16] [name: name_len bytes]
//!           [dim: u32] [nrows: u32]
//!           [nrows * dim * f32]
//! ```
//!
//! `crc` is CRC-32 (IEEE polynomial, zlib-compatible) over the payload
//! bytes — the Python mirror checks it with `zlib.crc32`. The reader is
//! **stop-at-first-corruption**: a short length prefix, a length that
//! overruns the file, a CRC mismatch, or a malformed payload ends that
//! file's replayable prefix; everything before it stands, everything
//! after is reported as a dropped tail. A torn final record — the
//! normal crash shape for an append log — is therefore tolerated by
//! construction, not special-cased.

use super::IndexError;
use std::path::{Path, PathBuf};

/// Record kind tag for an `add` (the only kind in v1).
pub const RECORD_ADD: u8 = 1;

/// Subdirectory of the data dir holding the per-collection WAL files.
pub const WAL_DIR: &str = "wal";

/// CRC-32, IEEE/zlib polynomial (reflected 0xEDB88320), no table —
/// byte-at-a-time is plenty for record-sized payloads and keeps the
/// implementation std-only and trivially mirrorable.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// One decoded WAL record: the add of `rows` (row-major, `dim` wide)
/// to collection `name`, stamped with the store-global `seq`.
#[derive(Clone, Debug, PartialEq)]
pub struct WalRecord {
    /// Store-global sequence number (one per acknowledged add).
    pub seq: u64,
    /// Target collection.
    pub name: String,
    /// Row dimension.
    pub dim: usize,
    /// Row-major f32 payload, a whole number of rows.
    pub rows: Vec<f32>,
}

impl WalRecord {
    /// Rows in this record.
    pub fn nrows(&self) -> usize {
        if self.dim == 0 { 0 } else { self.rows.len() / self.dim }
    }
}

/// Path of a collection's WAL file under `data_dir`.
pub fn wal_path(data_dir: &Path, collection: &str) -> PathBuf {
    data_dir.join(WAL_DIR).join(format!("{collection}.wal"))
}

/// Encode one record (length prefix + CRC + payload).
pub fn encode_record(rec: &WalRecord) -> Result<Vec<u8>, IndexError> {
    if rec.name.len() > u16::MAX as usize {
        return Err(IndexError::Io(format!(
            "collection name of {} bytes too long for a WAL record",
            rec.name.len()
        )));
    }
    let nrows = rec.nrows();
    if rec.dim == 0 || nrows == 0 || rec.rows.len() != nrows * rec.dim {
        return Err(IndexError::Io(format!(
            "WAL record payload of {} values is not a whole number of dimension-{} rows",
            rec.rows.len(),
            rec.dim
        )));
    }
    let mut payload = Vec::with_capacity(1 + 8 + 2 + rec.name.len() + 8 + rec.rows.len() * 4);
    payload.push(RECORD_ADD);
    payload.extend_from_slice(&rec.seq.to_le_bytes());
    payload.extend_from_slice(&(rec.name.len() as u16).to_le_bytes());
    payload.extend_from_slice(rec.name.as_bytes());
    payload.extend_from_slice(&(rec.dim as u32).to_le_bytes());
    payload.extend_from_slice(&(nrows as u32).to_le_bytes());
    for v in &rec.rows {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Why a WAL file's replayable prefix ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WalTail {
    /// The file ended exactly on a record boundary.
    Clean,
    /// Trailing bytes too short for a whole record — a torn final
    /// append (the expected crash shape).
    Torn,
    /// A record whose CRC did not match its payload — bit rot or a
    /// mangled write.
    BadChecksum,
    /// A record whose payload did not parse (unknown kind, inconsistent
    /// lengths) despite a matching CRC.
    Malformed,
}

/// Decode a WAL file's replayable prefix: every whole, checksummed,
/// well-formed record up to the first corruption, plus how the prefix
/// ended. Never errors — corruption is data, not failure, at recovery
/// time.
pub fn decode_records(bytes: &[u8]) -> (Vec<WalRecord>, WalTail) {
    let mut recs = Vec::new();
    let mut off = 0usize;
    while off < bytes.len() {
        if bytes.len() - off < 8 {
            return (recs, WalTail::Torn);
        }
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap());
        if bytes.len() - off - 8 < len {
            return (recs, WalTail::Torn);
        }
        let payload = &bytes[off + 8..off + 8 + len];
        if crc32(payload) != crc {
            return (recs, WalTail::BadChecksum);
        }
        match decode_payload(payload) {
            Some(rec) => recs.push(rec),
            None => return (recs, WalTail::Malformed),
        }
        off += 8 + len;
    }
    (recs, WalTail::Clean)
}

/// Parse one checksummed payload; `None` on any structural violation.
fn decode_payload(p: &[u8]) -> Option<WalRecord> {
    if p.len() < 1 + 8 + 2 || p[0] != RECORD_ADD {
        return None;
    }
    let seq = u64::from_le_bytes(p[1..9].try_into().unwrap());
    let name_len = u16::from_le_bytes(p[9..11].try_into().unwrap()) as usize;
    let mut off = 11usize;
    if p.len() < off + name_len + 8 {
        return None;
    }
    let name = std::str::from_utf8(&p[off..off + name_len]).ok()?.to_string();
    off += name_len;
    let dim = u32::from_le_bytes(p[off..off + 4].try_into().unwrap()) as usize;
    let nrows = u32::from_le_bytes(p[off + 4..off + 8].try_into().unwrap()) as usize;
    off += 8;
    let want = dim.checked_mul(nrows)?.checked_mul(4)?;
    if dim == 0 || nrows == 0 || p.len() != off + want {
        return None;
    }
    let mut rows = Vec::with_capacity(dim * nrows);
    for chunk in p[off..].chunks_exact(4) {
        rows.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Some(WalRecord { seq, name, dim, rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(seq: u64, name: &str, dim: usize, n: usize) -> WalRecord {
        let rows: Vec<f32> = (0..n * dim).map(|i| i as f32 * 0.5 - 1.0).collect();
        WalRecord { seq, name: name.into(), dim, rows }
    }

    #[test]
    fn crc32_matches_zlib_reference_values() {
        // zlib.crc32(b"") == 0, zlib.crc32(b"123456789") == 0xCBF43926
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"hello"), 0x3610_A686);
    }

    #[test]
    fn record_round_trips() {
        let r = rec(7, "docs", 4, 3);
        let bytes = encode_record(&r).unwrap();
        let (recs, tail) = decode_records(&bytes);
        assert_eq!(tail, WalTail::Clean);
        assert_eq!(recs, vec![r]);
    }

    #[test]
    fn multiple_records_concatenate() {
        let a = rec(1, "a", 2, 2);
        let b = rec(2, "b", 3, 1);
        let mut bytes = encode_record(&a).unwrap();
        bytes.extend(encode_record(&b).unwrap());
        let (recs, tail) = decode_records(&bytes);
        assert_eq!(tail, WalTail::Clean);
        assert_eq!(recs, vec![a, b]);
    }

    #[test]
    fn torn_tail_keeps_whole_prefix() {
        let a = rec(1, "a", 2, 2);
        let b = rec(2, "a", 2, 1);
        let mut bytes = encode_record(&a).unwrap();
        let full = encode_record(&b).unwrap();
        // every strict prefix of the final record is a torn tail
        for cut in 1..full.len() {
            let mut torn = bytes.clone();
            torn.extend_from_slice(&full[..cut]);
            let (recs, tail) = decode_records(&torn);
            assert_eq!(recs, vec![a.clone()], "cut={cut}");
            assert_eq!(tail, WalTail::Torn, "cut={cut}");
        }
        bytes.extend(full);
        assert_eq!(decode_records(&bytes).1, WalTail::Clean);
    }

    #[test]
    fn any_flipped_payload_bit_is_caught() {
        let r = rec(3, "docs", 3, 2);
        let clean = encode_record(&r).unwrap();
        for byte in 8..clean.len() {
            let mut bad = clean.clone();
            bad[byte] ^= 0x10;
            let (recs, tail) = decode_records(&bad);
            assert!(recs.is_empty(), "byte={byte}");
            assert_eq!(tail, WalTail::BadChecksum, "byte={byte}");
        }
    }

    #[test]
    fn corruption_mid_file_drops_the_rest() {
        let a = rec(1, "a", 2, 1);
        let b = rec(2, "a", 2, 1);
        let c = rec(3, "a", 2, 1);
        let ea = encode_record(&a).unwrap();
        let mut eb = encode_record(&b).unwrap();
        eb[10] ^= 0x01; // corrupt b's payload
        let ec = encode_record(&c).unwrap();
        let bytes: Vec<u8> = [ea, eb, ec].concat();
        let (recs, tail) = decode_records(&bytes);
        assert_eq!(recs, vec![a], "stop-at-first-corruption");
        assert_eq!(tail, WalTail::BadChecksum);
    }

    #[test]
    fn encode_rejects_ragged_payloads() {
        let bad = WalRecord { seq: 1, name: "x".into(), dim: 3, rows: vec![0.0; 4] };
        assert!(matches!(encode_record(&bad), Err(IndexError::Io(_))));
    }

    #[test]
    fn empty_input_is_a_clean_empty_wal() {
        let (recs, tail) = decode_records(&[]);
        assert!(recs.is_empty());
        assert_eq!(tail, WalTail::Clean);
    }
}
