//! Background compaction: merge small sealed segments, rewrite
//! segments whose on-disk width went stale after a rebalance, and swap
//! the result in atomically via one new manifest generation.
//!
//! Cadence sealing produces segments sized by *when* the seal fired,
//! not by what a scan wants: row-at-a-time ingest under a tight
//! `--snapshot-every` leaves a trail of tiny segments, each a separate
//! scatter-gather target and a separate recovery read. Compaction
//! walks each collection's sealed list in order and greedily merges
//! **adjacent** runs whose combined rows fit the target segment size —
//! adjacency keeps global row ids stable, since ids are assigned by
//! position in the sealed sequence. Merged rows are requantized from
//! the residual store at the collection's current width
//! (deterministic, lossless-from-exact), so a merged segment is
//! bit-identical to what a fresh build would pack for those rows.
//!
//! The same pass re-solves per-collection widths under the byte budget
//! ([`super::VectorStore::rebalance`] — a no-op under the Uniform
//! policy) and rewrites any segment file whose `disk_bits` no longer
//! matches its collection, retiring the requantize-at-recovery debt.
//! Non-empty heads are sealed in the same swap, so the new manifest is
//! a complete checkpoint: it carries the engine's current `next_seq`
//! and the WAL files it subsumes are deleted after the commit.
//!
//! Crash safety is inherited from the seal path: every new segment
//! file is written first, the manifest write is the single commit
//! point, and the in-memory splice happens only after it. A crash at
//! any write ordinal leaves either the old generation (plus intact
//! WALs, if the crash hit before the manifest landed) or the new one —
//! the `rust/tests/segments.rs` wall drives every fault through every
//! ordinal of a seal → compact → swap run and asserts recovery stays
//! bit-identical to a fresh build of the durable prefix.

use super::durability::{prune_files, DurableStore};
use super::segment::{
    encode_manifest, encode_segment, manifest_path, segment_path, ManifestCollection,
    ManifestSegment, SegmentData, StoreManifest,
};
use super::wal::WAL_DIR;
use super::IndexError;
use std::path::PathBuf;
use std::sync::atomic::Ordering;

/// Target rows per merged segment when `--segment-rows` is unset.
const DEFAULT_TARGET_ROWS: usize = 4096;

/// One planned change to a collection's sealed list, indexed into the
/// sealed vector as it stood at plan time (the engine lock is held
/// across plan and apply, so the indices cannot go stale).
enum SegOp {
    /// The segment and its file are untouched.
    Keep { idx: usize },
    /// Same rows, new file at the collection's current width.
    Rewrite { idx: usize, id: u64 },
    /// A merged run; `data` replaces the run's members.
    Merge { data: SegmentData },
}

struct CollectionPlan {
    name: String,
    ops: Vec<SegOp>,
    head_id: Option<u64>,
}

impl DurableStore {
    /// Run one compaction pass: re-solve widths, merge small adjacent
    /// segments, rewrite stale-width files, seal non-empty heads, and
    /// swap the manifest. Returns `Ok(true)` when a merge or rewrite
    /// actually happened (and bumps the `compactions` counter);
    /// `Ok(false)` when there was nothing to do — ephemeral and
    /// read-only stores always report `false`. Queries are never
    /// blocked: all file I/O runs without the store lock, exactly like
    /// a seal.
    pub fn compact_now(&self, threads: usize) -> Result<bool, IndexError> {
        let Some(engine_mx) = &self.engine else {
            return Ok(false);
        };
        let mut engine = engine_mx.lock().expect("index engine lock poisoned");
        if engine.read_only {
            return Ok(false);
        }
        // re-solve widths first so every file written below lands at
        // the final plan (no-op under Uniform)
        self.store
            .write()
            .expect("index store lock poisoned")
            .rebalance(threads)?;
        let target = if engine.segment_rows > 0 {
            engine.segment_rows
        } else {
            DEFAULT_TARGET_ROWS
        };
        // plan under a read lock: decide ops, encode every new file
        let (plans, writes, manifest_bytes, gen, new_next_id, did_work) = {
            let store = self.store.read().expect("index store lock poisoned");
            let mut next_id = engine.next_seg_id;
            let mut writes: Vec<(PathBuf, Vec<u8>)> = Vec::new();
            let mut plans: Vec<CollectionPlan> = Vec::new();
            let mut mcols: Vec<ManifestCollection> = Vec::new();
            let mut did_work = false;
            for (name, c) in &store.collections {
                let mut ops: Vec<SegOp> = Vec::new();
                let mut segs: Vec<ManifestSegment> = Vec::new();
                let mut i = 0usize;
                while i < c.sealed.len() {
                    // longest adjacent run from i that fits the target
                    let mut j = i;
                    let mut run_rows = 0usize;
                    while j < c.sealed.len() && run_rows + c.sealed[j].rows() <= target {
                        run_rows += c.sealed[j].rows();
                        j += 1;
                    }
                    if j > i + 1 {
                        let mut exact = Vec::new();
                        for s in &c.sealed[i..j] {
                            exact.extend_from_slice(&s.exact);
                        }
                        let (codes, r) = super::quantize_rows(&c.rot, c.d, &exact, c.bits);
                        let id = next_id;
                        next_id += 1;
                        let bytes =
                            encode_segment(name, c.d, c.bits, c.metric, id, &codes, &r, &exact);
                        writes.push((segment_path(&engine.data_dir, name, id), bytes));
                        segs.push(ManifestSegment { id, rows: run_rows, bits: c.bits });
                        ops.push(SegOp::Merge {
                            data: SegmentData { id, disk_bits: c.bits, codes, r, exact },
                        });
                        did_work = true;
                        i = j;
                    } else {
                        let s = &c.sealed[i];
                        if s.disk_bits != c.bits {
                            // in-memory codes are already at the current
                            // width (rebalance recodes sealed segments);
                            // only the file needs rewriting
                            let id = next_id;
                            next_id += 1;
                            let bytes = encode_segment(
                                name, c.d, c.bits, c.metric, id, &s.codes, &s.r, &s.exact,
                            );
                            writes.push((segment_path(&engine.data_dir, name, id), bytes));
                            segs.push(ManifestSegment { id, rows: s.rows(), bits: c.bits });
                            ops.push(SegOp::Rewrite { idx: i, id });
                            did_work = true;
                        } else {
                            segs.push(ManifestSegment {
                                id: s.id,
                                rows: s.rows(),
                                bits: s.disk_bits,
                            });
                            ops.push(SegOp::Keep { idx: i });
                        }
                        i += 1;
                    }
                }
                let head_id = if c.r.is_empty() {
                    None
                } else {
                    let id = next_id;
                    next_id += 1;
                    let bytes =
                        encode_segment(name, c.d, c.bits, c.metric, id, &c.codes, &c.r, &c.exact);
                    writes.push((segment_path(&engine.data_dir, name, id), bytes));
                    segs.push(ManifestSegment { id, rows: c.r.len(), bits: c.bits });
                    Some(id)
                };
                plans.push(CollectionPlan { name: name.clone(), ops, head_id });
                mcols.push(ManifestCollection {
                    name: name.clone(),
                    d: c.d,
                    bits: c.bits,
                    metric: c.metric,
                    signs1: c.rot.signs1.clone(),
                    signs2: c.rot.signs2.clone(),
                    segments: segs,
                });
            }
            let gen = engine.next_gen;
            let m = StoreManifest {
                gen,
                next_seq: engine.next_seq,
                next_seg_id: next_id,
                rows_at_solve: store.rows_at_solve,
                collections: mcols,
            };
            (plans, writes, encode_manifest(&m), gen, next_id, did_work)
        };
        if !did_work {
            // nothing to merge or rewrite; leave head sealing to the
            // cadence rather than churn a manifest generation per tick
            return Ok(false);
        }
        // commit: segment files first, then the manifest (the swap)
        for (path, bytes) in &writes {
            engine
                .io
                .write_atomic(path, bytes, true)
                .map_err(|e| IndexError::Io(format!("writing {}: {e}", path.display())))?;
        }
        let mpath = manifest_path(&engine.data_dir, gen);
        engine
            .io
            .write_atomic(&mpath, &manifest_bytes, true)
            .map_err(|e| IndexError::Io(format!("writing {}: {e}", mpath.display())))?;
        engine.next_gen = gen + 1;
        engine.next_seg_id = new_next_id;
        engine.rows_since_seal = 0;
        // the manifest sealed every head, so it covers every logged
        // record: drop the WALs
        let wal_dir = engine.data_dir.join(WAL_DIR);
        for name in engine
            .io
            .list(&wal_dir)
            .map_err(|e| IndexError::Io(format!("listing {}: {e}", wal_dir.display())))?
        {
            if name.ends_with(".wal") {
                let p = wal_dir.join(&name);
                engine
                    .io
                    .remove(&p)
                    .map_err(|e| IndexError::Io(format!("removing {}: {e}", p.display())))?;
            }
        }
        let prev = engine.prev_good_gen.replace(gen);
        prune_files(&mut engine, gen, prev)?;
        // splice the new sealed lists in under a brief write lock
        {
            let mut store = self.store.write().expect("index store lock poisoned");
            for plan in plans {
                let Some(c) = store.collections.get_mut(&plan.name) else {
                    continue;
                };
                let mut old: Vec<Option<SegmentData>> =
                    std::mem::take(&mut c.sealed).into_iter().map(Some).collect();
                let mut new_sealed = Vec::with_capacity(plan.ops.len());
                for op in plan.ops {
                    match op {
                        SegOp::Keep { idx } => {
                            new_sealed.push(old[idx].take().expect("op indices are unique"));
                        }
                        SegOp::Rewrite { idx, id } => {
                            let mut s = old[idx].take().expect("op indices are unique");
                            s.id = id;
                            s.disk_bits = c.bits;
                            new_sealed.push(s);
                        }
                        SegOp::Merge { data } => new_sealed.push(data),
                    }
                }
                c.sealed = new_sealed;
                if let Some(id) = plan.head_id {
                    c.seal_head(id);
                }
            }
        }
        self.compactions.fetch_add(1, Ordering::Relaxed);
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::super::durability::{DurabilityConfig, DurableStore, FsyncPolicy};
    use super::super::io::MemIo;
    use super::super::snapshot::encode_snapshot;
    use super::super::{IndexConfig, IndexPolicy, VectorStore};
    use crate::rng::Rng;
    use std::path::PathBuf;

    fn cfg() -> IndexConfig {
        IndexConfig { policy: IndexPolicy::Uniform(6), ..Default::default() }
    }

    fn dcfg() -> DurabilityConfig {
        DurabilityConfig {
            data_dir: PathBuf::from("/idx"),
            fsync: FsyncPolicy::Never,
            snapshot_every: 0,
            segment_rows: 0,
        }
    }

    fn assert_bit_identical(a: &VectorStore, b: &VectorStore) {
        assert_eq!(encode_snapshot(a, 0), encode_snapshot(b, 0), "stores differ bit-for-bit");
    }

    #[test]
    fn merges_small_segments_and_stays_bit_identical() {
        let d = 8usize;
        let durable = DurableStore::open_with(cfg(), dcfg(), Box::new(MemIo::new())).unwrap();
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for seed in 0..3u64 {
            let v = Rng::new(seed).gaussian_vec(2 * d);
            durable.add("a", &v, d, 1).unwrap();
            fresh.add("a", &v, d, 1).unwrap();
            durable.seal_now().unwrap();
        }
        assert_eq!(durable.store().segments(), 3);
        assert!(durable.compact_now(1).unwrap());
        assert_eq!(durable.compactions(), 1);
        {
            let s = durable.store();
            assert_eq!(s.segments(), 1, "three tiny segments merge into one");
            assert_eq!(s.rows(), 6);
            assert_bit_identical(&s, &fresh);
        }
        // recovery from the swapped manifest is bit-identical too
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(), io).unwrap();
        assert_eq!(reopened.recovery().unwrap().recovered_rows(), 6);
        assert_bit_identical(&reopened.store(), &fresh);
    }

    #[test]
    fn compaction_seals_heads_in_the_same_swap() {
        let d = 8usize;
        let durable = DurableStore::open_with(cfg(), dcfg(), Box::new(MemIo::new())).unwrap();
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for seed in 0..2u64 {
            let v = Rng::new(10 + seed).gaussian_vec(d);
            durable.add("a", &v, d, 1).unwrap();
            fresh.add("a", &v, d, 1).unwrap();
            durable.seal_now().unwrap();
        }
        let v = Rng::new(20).gaussian_vec(d);
        durable.add("a", &v, d, 1).unwrap(); // head row, WAL only
        fresh.add("a", &v, d, 1).unwrap();
        assert!(durable.compact_now(1).unwrap());
        {
            let s = durable.store();
            assert_eq!(s.head_rows(), 0, "the head seals in the same swap");
            assert_eq!(s.segments(), 2, "one merged run + the sealed head");
        }
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.snapshot_rows, 3, "all rows are sealed; nothing replays");
        assert_eq!(rep.replayed_rows, 0);
        assert_bit_identical(&reopened.store(), &fresh);
    }

    #[test]
    fn compaction_is_a_noop_when_nothing_qualifies() {
        let d = 8usize;
        let durable = DurableStore::open_with(cfg(), dcfg(), Box::new(MemIo::new())).unwrap();
        durable.add("a", &Rng::new(1).gaussian_vec(4 * d), d, 1).unwrap();
        durable.seal_now().unwrap();
        // one segment at the current width, empty head: nothing to do
        assert!(!durable.compact_now(1).unwrap());
        assert_eq!(durable.compactions(), 0);
        assert_eq!(durable.store().segments(), 1);
        // a lone head row does not qualify either — cadence owns that
        durable.add("a", &Rng::new(2).gaussian_vec(d), d, 1).unwrap();
        assert!(!durable.compact_now(1).unwrap());
        assert_eq!(durable.store().head_rows(), 1);
        // ephemeral stores always report false
        let eph = DurableStore::ephemeral(cfg()).unwrap();
        assert!(!eph.compact_now(1).unwrap());
    }

    #[test]
    fn rewrite_retires_stale_width_files() {
        // Budget policy: the first segment's file is written at the
        // initial rich width; later growth narrows the collection. A
        // compaction pass must leave every on-disk file at the current
        // width, so the next recovery decodes straight bytes with no
        // requantize debt.
        let d = 16usize;
        let bcfg = IndexConfig {
            policy: IndexPolicy::Budget { bit_choices: vec![2, 4, 8] },
            budget_bytes: 600,
            ..Default::default()
        };
        let durable =
            DurableStore::open_with(bcfg.clone(), dcfg(), Box::new(MemIo::new())).unwrap();
        let mut fresh = VectorStore::new(bcfg.clone()).unwrap();
        let batch = |seed: u64| Rng::new(seed).gaussian_vec(10 * d);
        durable.add("a", &batch(0), d, 1).unwrap();
        fresh.add("a", &batch(0), d, 1).unwrap();
        durable.seal_now().unwrap();
        for seed in 1..5u64 {
            durable.add("a", &batch(seed), d, 1).unwrap();
            fresh.add("a", &batch(seed), d, 1).unwrap();
        }
        {
            let s = durable.store();
            let c = s.get("a").unwrap();
            assert!(c.bits() < 8, "the solver must have narrowed the collection");
            assert!(c.segments().iter().any(|seg| seg.disk_bits != c.bits()));
        }
        assert!(durable.compact_now(1).unwrap());
        {
            let s = durable.store();
            let c = s.get("a").unwrap();
            assert!(
                c.segments().iter().all(|seg| seg.disk_bits == c.bits()),
                "every file must be rewritten at the solved width"
            );
            assert_eq!(s.head_rows(), 0);
        }
        // the store state itself is untouched by compaction
        {
            let s = durable.store();
            // fresh never sealed, so flatten both and compare
            fresh.rebalance(1).unwrap(); // compact re-solved; mirror it
            assert_bit_identical(&s, &fresh);
        }
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(bcfg, dcfg(), io).unwrap();
        assert_bit_identical(&reopened.store(), &fresh);
    }
}
