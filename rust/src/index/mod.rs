//! Retrieval subsystem: a RaBitQ-native vector index with named
//! collections, two-phase top-k search, and per-collection bit-widths
//! (ISSUE 5).
//!
//! RaBitQ is an ANN vector-quantization method first — the paper adapts
//! it to weights, but its unbiased inner-product estimator (Alg. 3) is
//! exactly the primitive an embedding index needs. This module turns the
//! crate's battle-tested rotation + packing + estimator kernels into a
//! second serving workload: embed → add → query, RAG-shaped traffic.
//!
//! * **Storage** ([`Collection`]) — every embedding row is rotated with a
//!   full-dimension practical RHT ([`crate::hadamard::PracticalRht`],
//!   shared Rademacher signs per collection), grid-quantized with
//!   [`crate::rabitq::quantize_column_into`] at [`ScaleMode::MaxAbs`]
//!   (same contract as [`crate::kvq`]: one pass, one f32 rescale per
//!   row), and bit-packed into one shared buffer. A residual f32 store
//!   keeps the (metric-normalized) rows for the rerank phase — reported
//!   separately from the scan payload, the way ANN systems keep raw
//!   vectors beside their compressed index.
//! * **Query** — two phases. Phase 1 scans *codes only*:
//!   [`crate::kernels::scan_scores_q`] estimates every row's inner
//!   product against the rotated query (Alg. 3 per row — no row is ever
//!   reconstructed in f32; enforced by the [`rerank_row_reads`] counter,
//!   the same mechanism as the zero-dequant forward test). Phase 2
//!   fetches the top `rerank_factor * k` candidates from the residual
//!   store and reranks them with exact f32 scores.
//! * **Bit plan** ([`IndexPolicy`]) — collections get a uniform width,
//!   or AllocateBits-solved widths under a total scan-payload byte
//!   budget ([`VectorStore::rebalance`]), driven by **measured recall
//!   sensitivity**: each collection's recall@k gap at a low probe width
//!   becomes its DP alpha, so collections whose rankings collapse under
//!   coarse codes win the bits. Recoding is lossless-from-exact — the
//!   residual store re-encodes rows at the new width with no quality
//!   debt from the old one.
//!
//! The accuracy contract is **recall**, not bit-exactness: phase-1
//! estimates drift ~`2^-bits` (the RaBitQ bound), the rerank snaps the
//! survivors back to exact scores, and the property tests pin a
//! monotone 2 → 4 → 8-bit recall ladder against the brute-force f32
//! baseline plus self-query-ranks-first at >= 4 bits.
//!
//! Durability (ISSUE 6, segmented in ISSUE 8) lives in the child
//! modules: [`wal`] (the per-collection CRC-checksummed append log),
//! [`segment`] (immutable sealed segments + the manifest that lists
//! them — the on-disk layout), [`snapshot`] (the canonical *logical*
//! encoding of a whole store, used for bit-for-bit equality checks and
//! golden fixtures), [`durability`] (the [`durability::DurableStore`]
//! orchestrator: WAL-before-ack, O(head) sealing, crash recovery),
//! [`compact`] (the background compactor that merges small segments
//! and re-solves widths, swapping the manifest atomically), and [`io`]
//! (the filesystem seam with deterministic fault injection).
//!
//! A [`Collection`]'s rows are split between a **mutable head** (the
//! buffers `add` appends to) and a list of immutable **sealed
//! segments**; queries scatter-gather the phase-1 scan across sealed
//! segments plus the head in seal order, which is bit-identical to a
//! monolithic scan because the Alg.-3 estimator is per-row.
#![deny(missing_docs)]

pub mod compact;
pub mod durability;
pub mod io;
pub mod segment;
pub mod snapshot;
pub mod wal;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::allocate::AllocProblem;
use crate::hadamard::PracticalRht;
use crate::kernels;
use crate::kvq::set_codes;
use crate::rabitq::{quantize_column_into, ScaleMode};
use crate::rng::Rng;

/// Default seed for a store's per-collection rotation signs. Any fixed
/// seed works (the rotation only needs to be shared between add and
/// query); a constant keeps index contents reproducible.
pub const DEFAULT_ROT_SEED: u64 = 0x7265_7472;

/// Default phase-1 → phase-2 expansion: the scan hands `rerank_factor *
/// k` candidates to the exact rerank.
pub const DEFAULT_RERANK_FACTOR: usize = 4;

/// Rows sampled as probe queries per collection when measuring recall
/// sensitivity for the budget policy.
const SENSITIVITY_SAMPLES: usize = 16;

/// Process-wide count of residual-store row fetches (one per reranked
/// candidate). The packed-code scan must dequantize **zero** full rows
/// outside rerank, so after a query this counter moves by exactly the
/// candidate count — asserted in `rust/tests/integration.rs` alongside a
/// flat [`crate::rabitq::dequant_calls`], the same counter mechanism as
/// the zero-dequant forward test.
static RERANK_ROW_READS: AtomicUsize = AtomicUsize::new(0);

/// Read the rerank row-fetch counter: total residual-store rows handed
/// to the exact rerank, process-wide. The scan phase never moves it —
/// the zero-rows-outside-rerank acceptance test pins the delta per
/// query to exactly the candidate count.
pub fn rerank_row_reads() -> usize {
    RERANK_ROW_READS.load(Ordering::Relaxed)
}

// ------------------------------------------------------------------ errors

/// Typed errors for the vector index — surfaced at configuration and on
/// the request path so the HTTP layer can map each to a status (404 for
/// missing collections, 400 for shape/argument problems, 507 for a full
/// budget).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum IndexError {
    /// A requested code width outside 1..=8.
    BadBits(u8),
    /// Collection name empty, too long, or outside `[A-Za-z0-9_-]`.
    BadName(String),
    /// No collection of this name exists.
    NoSuchCollection(String),
    /// A vector's dimension does not match the collection's.
    DimMismatch {
        /// The collection whose dimension was violated.
        collection: String,
        /// The collection's row dimension.
        expected: usize,
        /// The offending vector's dimension.
        got: usize,
    },
    /// Malformed query arguments (zero k, empty vector, …).
    BadQuery(String),
    /// The scan-payload byte budget cannot hold the rows even at the
    /// cheapest admissible width — the add is refused, nothing mutates.
    BudgetTooSmall {
        /// The configured budget, in bytes.
        budget_bytes: usize,
        /// Smallest scan payload the rows could fit in.
        min_bytes: usize,
    },
    /// Configuration/shape mismatch (empty bit-choice set, …).
    Shape(String),
    /// A durability-layer I/O failure (WAL append, snapshot write,
    /// data-dir listing) — the HTTP layer maps it to 500.
    Io(String),
    /// The store refused the add because a prior WAL append *and* its
    /// reseal snapshot both failed: accepting more acks would let
    /// recovery silently drop them, so writes are refused until
    /// restart. Reads keep working. The HTTP layer maps it to 503
    /// (with `Retry-After` — but a retry is refused, never applied
    /// twice, so there is no duplicate-on-retry hazard).
    ReadOnly(String),
    /// An add carried `expect_first_id` and the collection's row count
    /// did not match: the caller's view of the collection is stale (or
    /// the add was already applied — the cluster router's exactly-once
    /// retry reads a conflict on its second attempt as success). The
    /// HTTP layer maps it to 409; nothing mutates.
    Conflict {
        /// The collection whose row count was checked.
        collection: String,
        /// Row id the caller expected the first appended row to get.
        expected_first_id: usize,
        /// Rows actually stored (the id the first row would get).
        actual_rows: usize,
    },
}

impl std::fmt::Display for IndexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IndexError::BadBits(b) => write!(f, "index bit-width {b} outside 1..=8"),
            IndexError::BadName(n) => write!(
                f,
                "bad collection name '{n}' (1..=64 chars of [A-Za-z0-9_-])"
            ),
            IndexError::NoSuchCollection(n) => write!(f, "no collection named '{n}'"),
            IndexError::DimMismatch { collection, expected, got } => write!(
                f,
                "vector dimension {got} != collection '{collection}' dimension {expected}"
            ),
            IndexError::BadQuery(msg) => write!(f, "bad query: {msg}"),
            IndexError::BudgetTooSmall { budget_bytes, min_bytes } => write!(
                f,
                "index budget of {budget_bytes} bytes cannot hold the rows \
                 (minimum {min_bytes} bytes at the cheapest width)"
            ),
            IndexError::Shape(msg) => write!(f, "index shape error: {msg}"),
            IndexError::Io(msg) => write!(f, "index durability I/O error: {msg}"),
            IndexError::ReadOnly(msg) => {
                write!(f, "index store is read-only after a durability failure: {msg}")
            }
            IndexError::Conflict { collection, expected_first_id, actual_rows } => write!(
                f,
                "add conflict on collection '{collection}': expected the first \
                 appended row to get id {expected_first_id}, but the collection \
                 holds {actual_rows} rows"
            ),
        }
    }
}

impl std::error::Error for IndexError {}

impl From<IndexError> for anyhow::Error {
    fn from(e: IndexError) -> anyhow::Error {
        anyhow::Error::msg(e.to_string())
    }
}

// ------------------------------------------------------------------ metric

/// Similarity metric of a collection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Raw inner product over the stored rows.
    InnerProduct,
    /// Cosine similarity: rows and queries are L2-normalized at the
    /// door, after which the inner product *is* the cosine — one scan
    /// kernel serves both metrics.
    Cosine,
}

impl Metric {
    /// Stable wire name (`/v1/collections` reports it).
    pub fn name(&self) -> &'static str {
        match self {
            Metric::InnerProduct => "ip",
            Metric::Cosine => "cosine",
        }
    }
}

/// L2-normalize in place (f64 accumulation); zero vectors stay zero.
fn l2_normalize(v: &mut [f32]) {
    let norm: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    if norm > 0.0 {
        let inv = (1.0 / norm) as f32;
        for x in v.iter_mut() {
            *x *= inv;
        }
    }
}

// ----------------------------------------------------------------- results

/// One search result: the row id within its collection and the score
/// under the collection's metric (exact f32 after rerank).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SearchHit {
    /// Row id (0-based insertion order within the collection).
    pub id: usize,
    /// Exact score under the collection's metric.
    pub score: f32,
}

/// Per-collection accounting snapshot (`GET /v1/collections`).
#[derive(Clone, Debug)]
pub struct CollectionInfo {
    /// Collection name.
    pub name: String,
    /// Stored rows.
    pub rows: usize,
    /// Row dimension.
    pub dim: usize,
    /// Current code width.
    pub bits: u8,
    /// Similarity metric.
    pub metric: Metric,
    /// Scan payload per row: packed codes + the f32 rescale.
    pub bytes_per_row: usize,
    /// Total scan payload (codes buffer + rescale table).
    pub code_bytes: usize,
    /// Residual-store footprint (f32 rows the rerank reads).
    pub exact_bytes: usize,
    /// Immutable sealed segments backing this collection.
    pub segments: usize,
    /// Rows still in the mutable head (unsealed).
    pub head_rows: usize,
}

/// Indices of the top `k` scores, descending, ties broken toward the
/// lower index — deterministic for any input. Partial selection first,
/// so the scan's O(n) output is not fully sorted for small k.
pub fn top_indices(scores: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    let cmp = |a: &usize, b: &usize| {
        scores[*b]
            .partial_cmp(&scores[*a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(b))
    };
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_by(cmp);
    idx
}

// -------------------------------------------------------------- collection

/// One named set of embedding rows, stored as packed RaBitQ codes plus a
/// residual f32 store for the exact rerank.
///
/// Rows live in two parts. The **head** (`codes`/`r`/`exact` below) is
/// mutable: `add` appends to it. **Sealed segments** (`sealed`) are
/// immutable copies of earlier heads, each the in-memory twin of one
/// on-disk segment file (see [`segment`]). Global row ids run through
/// the sealed segments in seal order and then the head, so sealing
/// never renumbers a row.
///
/// Within each part, row `i`'s codes occupy elements `[i*d, (i+1)*d)`
/// of that part's LSB-first bit buffer (the
/// [`crate::rabitq::PackedCodes`] layout), `r[i]` is its least-squares
/// rescale, and `exact[i*d..]` holds the metric-normalized row the
/// rerank reads. All rows share one full-dimension rotation, so a
/// query is rotated once per scan regardless of segment count.
#[derive(Clone, Debug)]
pub struct Collection {
    name: String,
    d: usize,
    bits: u8,
    metric: Metric,
    rot: PracticalRht,
    sealed: Vec<segment::SegmentData>,
    codes: Vec<u8>,
    r: Vec<f32>,
    exact: Vec<f32>,
}

impl Collection {
    /// Empty collection of `d`-dimensional rows coded at `bits`.
    pub fn new(
        name: &str,
        d: usize,
        bits: u8,
        metric: Metric,
        rot_seed: u64,
    ) -> Result<Collection, IndexError> {
        if !(1..=8).contains(&bits) {
            return Err(IndexError::BadBits(bits));
        }
        if d == 0 {
            return Err(IndexError::Shape("row dimension must be >= 1".into()));
        }
        let mut rng = Rng::new(rot_seed ^ hash_name(name));
        let rot = PracticalRht::sample(d, &mut rng);
        Ok(Collection {
            name: name.to_string(),
            d,
            bits,
            metric,
            rot,
            sealed: Vec::new(),
            codes: Vec::new(),
            r: Vec::new(),
            exact: Vec::new(),
        })
    }

    /// Collection name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Stored rows (sealed segments + head).
    pub fn len(&self) -> usize {
        self.sealed.iter().map(segment::SegmentData::rows).sum::<usize>() + self.r.len()
    }

    /// True when no rows are stored.
    pub fn is_empty(&self) -> bool {
        self.sealed.is_empty() && self.r.is_empty()
    }

    /// Rows still in the mutable head (unsealed — covered by the WAL,
    /// not by any segment file).
    pub fn head_rows(&self) -> usize {
        self.r.len()
    }

    /// Number of immutable sealed segments.
    pub fn segment_count(&self) -> usize {
        self.sealed.len()
    }

    /// Borrow the sealed segments, seal order (global row order).
    pub fn segments(&self) -> &[segment::SegmentData] {
        &self.sealed
    }

    /// Row dimension.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Current code width.
    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// Similarity metric.
    pub fn metric(&self) -> Metric {
        self.metric
    }

    /// Scan payload per row in bytes: `ceil(d * bits / 8)` of codes plus
    /// one f32 rescale — the quantity the acceptance ratio compares to
    /// the `4 * d` f32 baseline.
    pub fn bytes_per_row(&self) -> usize {
        (self.d * self.bits as usize).div_ceil(8) + 4
    }

    /// Total scan payload: packed code buffers + rescale tables, sealed
    /// segments and head alike.
    pub fn code_bytes(&self) -> usize {
        let sealed: usize =
            self.sealed.iter().map(|s| s.codes.len() + 4 * s.r.len()).sum();
        sealed + self.codes.len() + 4 * self.r.len()
    }

    /// Residual-store footprint (f32 rows, rerank side).
    pub fn exact_bytes(&self) -> usize {
        let sealed: usize = self.sealed.iter().map(|s| s.exact.len() * 4).sum();
        sealed + self.exact.len() * 4
    }

    /// Accounting snapshot.
    pub fn info(&self) -> CollectionInfo {
        CollectionInfo {
            name: self.name.clone(),
            rows: self.len(),
            dim: self.d,
            bits: self.bits,
            metric: self.metric,
            bytes_per_row: self.bytes_per_row(),
            code_bytes: self.code_bytes(),
            exact_bytes: self.exact_bytes(),
            segments: self.sealed.len(),
            head_rows: self.r.len(),
        }
    }

    /// Append `vecs.len() / d` rows (`vecs` is row-major, a whole number
    /// of rows). Under [`Metric::Cosine`] each row is L2-normalized
    /// before storage. Returns the id of the first appended row.
    pub fn add(&mut self, vecs: &[f32]) -> Result<usize, IndexError> {
        if vecs.is_empty() || vecs.len() % self.d != 0 {
            return Err(IndexError::DimMismatch {
                collection: self.name.clone(),
                expected: self.d,
                got: vecs.len(),
            });
        }
        let first = self.len();
        let head_first = self.r.len(); // packing offset is head-local
        let rows = vecs.len() / self.d;
        let d = self.d;
        // grow the head's packed buffer to cover the new rows
        let total = (head_first + rows) * d * self.bits as usize;
        self.codes.resize(total.div_ceil(8), 0);
        let mut seg = vec![0f32; d];
        let mut colcodes: Vec<u8> = Vec::with_capacity(d);
        for i in 0..rows {
            seg.copy_from_slice(&vecs[i * d..(i + 1) * d]);
            if self.metric == Metric::Cosine {
                l2_normalize(&mut seg);
            }
            self.exact.extend_from_slice(&seg);
            self.rot.apply(&mut seg);
            let rr = quantize_column_into(&seg, self.bits, ScaleMode::MaxAbs, &mut colcodes);
            set_codes(&mut self.codes, self.bits, (head_first + i) * d, &colcodes);
            self.r.push(rr);
        }
        Ok(first)
    }

    /// Seal the head: move its buffers wholesale into a new immutable
    /// [`segment::SegmentData`] with store-global id `id`. O(head rows)
    /// — sealed segments are never touched. No-op on an empty head.
    /// The durability layer calls this only after the matching segment
    /// file and manifest are committed.
    pub fn seal_head(&mut self, id: u64) {
        if self.r.is_empty() {
            return;
        }
        self.sealed.push(segment::SegmentData {
            id,
            disk_bits: self.bits,
            codes: std::mem::take(&mut self.codes),
            r: std::mem::take(&mut self.r),
            exact: std::mem::take(&mut self.exact),
        });
    }

    /// The residual store's parts in global row order: every sealed
    /// segment's rows, then the head's.
    fn exact_parts(&self) -> impl Iterator<Item = &[f32]> {
        self.sealed
            .iter()
            .map(|s| s.exact.as_slice())
            .chain(std::iter::once(self.exact.as_slice()))
    }

    /// Residual f32 row at global id `i`, walking the sealed segments
    /// then the head.
    fn row_exact(&self, i: usize) -> &[f32] {
        let mut i = i;
        for s in &self.sealed {
            if i < s.rows() {
                return &s.exact[i * self.d..(i + 1) * self.d];
            }
            i -= s.rows();
        }
        &self.exact[i * self.d..(i + 1) * self.d]
    }

    /// Quantize every stored row (sealed + head, global order) at
    /// `bits` from the residual store into **one contiguous buffer** —
    /// the budget policy's low-width recall probe, and the canonical
    /// flattening the logical snapshot encoding serializes. Because
    /// recoding is lossless-from-exact, the flat result is
    /// bit-identical to the codes of a never-sealed collection.
    fn quantize_all(&self, bits: u8) -> (Vec<u8>, Vec<f32>) {
        let (n, d) = (self.len(), self.d);
        let mut data = vec![0u8; (n * d * bits as usize).div_ceil(8)];
        let mut r = Vec::with_capacity(n);
        let mut seg = vec![0f32; d];
        let mut colcodes: Vec<u8> = Vec::with_capacity(d);
        let mut gi = 0usize;
        for part in self.exact_parts() {
            for row in part.chunks_exact(d) {
                seg.copy_from_slice(row);
                self.rot.apply(&mut seg);
                r.push(quantize_column_into(&seg, bits, ScaleMode::MaxAbs, &mut colcodes));
                set_codes(&mut data, bits, gi * d, &colcodes);
                gi += 1;
            }
        }
        (data, r)
    }

    /// Flat scan payload over all rows, global order: `(codes, r)` at
    /// the collection's current width, as if it had never been sealed.
    /// Borrows the head directly when nothing is sealed; requantizes
    /// (losslessly) otherwise. Used by the canonical logical encoding.
    pub(crate) fn flat_codes_r(&self) -> (Vec<u8>, Vec<f32>) {
        if self.sealed.is_empty() {
            (self.codes.clone(), self.r.clone())
        } else {
            self.quantize_all(self.bits)
        }
    }

    /// Flat residual store over all rows, global order.
    pub(crate) fn flat_exact(&self) -> Vec<f32> {
        if self.sealed.is_empty() {
            self.exact.clone()
        } else {
            let mut out = Vec::with_capacity(self.len() * self.d);
            for part in self.exact_parts() {
                out.extend_from_slice(part);
            }
            out
        }
    }

    /// Re-encode every row at a new width — head *and* sealed segments
    /// (each from its own residual store; segment files on disk keep
    /// their old width until compaction rewrites them, tracked by
    /// [`segment::SegmentData::disk_bits`]). Lossless-from-exact: codes
    /// are regenerated from the residual f32 rows, so repeated recoding
    /// accumulates no error — a recoded collection is bit-identical to
    /// one built at that width from scratch.
    pub fn recode(&mut self, bits: u8) -> Result<(), IndexError> {
        if !(1..=8).contains(&bits) {
            return Err(IndexError::BadBits(bits));
        }
        if bits == self.bits {
            return Ok(());
        }
        let recoded: Vec<(Vec<u8>, Vec<f32>)> = self
            .sealed
            .iter()
            .map(|s| quantize_rows(&self.rot, self.d, &s.exact, bits))
            .collect();
        for (s, (codes, r)) in self.sealed.iter_mut().zip(recoded) {
            s.codes = codes;
            s.r = r;
        }
        let (data, r) = quantize_rows(&self.rot, self.d, &self.exact, bits);
        self.codes = data;
        self.r = r;
        self.bits = bits;
        Ok(())
    }

    /// Metric-adjust a query (cosine normalizes a copy) and rotate it
    /// into the coded basis.
    fn prepare_query(&self, q: &[f32]) -> Result<Vec<f32>, IndexError> {
        if q.len() != self.d {
            return Err(IndexError::DimMismatch {
                collection: self.name.clone(),
                expected: self.d,
                got: q.len(),
            });
        }
        let mut q_rot = q.to_vec();
        if self.metric == Metric::Cosine {
            l2_normalize(&mut q_rot);
        }
        self.rot.apply(&mut q_rot);
        Ok(q_rot)
    }

    /// Two-phase top-k search: estimated scan over codes
    /// ([`crate::kernels::scan_scores_q`] — zero rows reconstructed),
    /// then exact f32 rerank of the top `rerank_factor * k` candidates
    /// from the residual store. Returns up to `k` hits with exact
    /// scores, descending (ties toward the lower id).
    pub fn query(
        &self,
        q: &[f32],
        k: usize,
        rerank_factor: usize,
        threads: usize,
    ) -> Result<Vec<SearchHit>, IndexError> {
        if k == 0 {
            return Err(IndexError::BadQuery("k must be >= 1".into()));
        }
        let q_rot = self.prepare_query(q)?;
        let n = self.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let est = self.scan_est(&q_rot, threads);
        let take = (rerank_factor.max(1).saturating_mul(k)).min(n);
        let candidates = top_indices(&est, take);
        // phase 2: exact rerank — the only place residual rows are read
        let mut hits = self.rerank(q, &candidates);
        hits.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
        hits.truncate(k);
        Ok(hits)
    }

    /// Phase-1 estimates for every stored row: Alg.-3 scores straight
    /// from the packed codes, scatter-gathered across sealed segments
    /// then the head. The estimator is per-row, so scanning each part
    /// into its global offset of `est` is bit-identical to one
    /// monolithic scan — the merge order is fixed (seal order, head
    /// last), keeping results deterministic regardless of segment
    /// boundaries.
    fn scan_est(&self, q_rot: &[f32], threads: usize) -> Vec<f32> {
        let n = self.len();
        let mut est = vec![0f32; n];
        let mut off = 0usize;
        for s in &self.sealed {
            let rows = s.rows();
            kernels::scan_scores_q(
                q_rot,
                &s.codes,
                self.bits,
                0,
                rows,
                &s.r,
                threads,
                &mut est[off..off + rows],
            );
            off += rows;
        }
        let head = self.r.len();
        if head > 0 {
            kernels::scan_scores_q(
                q_rot,
                &self.codes,
                self.bits,
                0,
                head,
                &self.r,
                threads,
                &mut est[off..off + head],
            );
        }
        est
    }

    /// Exact-rerank `candidates` (row ids) against `q`: metric-adjust
    /// the query, read each candidate's residual row (counted by
    /// [`rerank_row_reads`]), and score it exactly. Hits come back in
    /// candidate order, unsorted.
    fn rerank(&self, q: &[f32], candidates: &[usize]) -> Vec<SearchHit> {
        let mut metric_q = q.to_vec();
        if self.metric == Metric::Cosine {
            l2_normalize(&mut metric_q);
        }
        candidates
            .iter()
            .map(|&i| {
                RERANK_ROW_READS.fetch_add(1, Ordering::Relaxed);
                let row = self.row_exact(i);
                let mut dp = 0f32;
                for (x, v) in metric_q.iter().zip(row) {
                    dp += x * v;
                }
                SearchHit { id: i, score: dp }
            })
            .collect()
    }

    /// Phase 1 alone, for a cluster shard: scan every stored row and
    /// return the local top-`take` **estimated** candidates (Alg.-3
    /// scores, not exact), ordered like [`top_indices`] — descending
    /// est, ties toward the lower id. `take` comes from the *global*
    /// row count (`rerank_factor * k` clamped by the router), so a
    /// shard's local top-`take` provably contains every local member
    /// of the global top-`take`: if a local row were missing, `take`
    /// better-ranked local rows would outrank it globally too. The
    /// scan reads zero residual rows.
    pub fn scan_candidates(
        &self,
        q: &[f32],
        take: usize,
        threads: usize,
    ) -> Result<Vec<SearchHit>, IndexError> {
        if take == 0 {
            return Err(IndexError::BadQuery("take must be >= 1".into()));
        }
        let q_rot = self.prepare_query(q)?;
        if self.len() == 0 {
            return Ok(Vec::new());
        }
        let est = self.scan_est(&q_rot, threads);
        Ok(top_indices(&est, take)
            .into_iter()
            .map(|i| SearchHit { id: i, score: est[i] })
            .collect())
    }

    /// Phase 2 alone, for a cluster shard: exact scores of the given
    /// row ids, in input order (the router merges by score afterwards).
    /// Same metric handling and residual-row accounting as the rerank
    /// inside [`Collection::query`] — a distributed two-phase query
    /// that feeds this the router-selected candidates reranks exactly
    /// the rows a single-node query would. Unknown ids are a caller
    /// error (the router only asks for ids a shard reported).
    pub fn exact_scores(&self, q: &[f32], ids: &[usize]) -> Result<Vec<SearchHit>, IndexError> {
        if q.len() != self.d {
            return Err(IndexError::DimMismatch {
                collection: self.name.clone(),
                expected: self.d,
                got: q.len(),
            });
        }
        let n = self.len();
        if let Some(&bad) = ids.iter().find(|&&i| i >= n) {
            return Err(IndexError::BadQuery(format!(
                "rerank id {bad} outside the collection's {n} rows"
            )));
        }
        Ok(self.rerank(q, ids))
    }

    /// Brute-force exact top-k over the residual f32 store — the
    /// baseline the recall properties and `index_scan_f32` bench measure
    /// against. Same metric handling and tie-breaks as [`Collection::query`].
    pub fn brute_force(
        &self,
        q: &[f32],
        k: usize,
        threads: usize,
    ) -> Result<Vec<SearchHit>, IndexError> {
        if k == 0 {
            return Err(IndexError::BadQuery("k must be >= 1".into()));
        }
        if q.len() != self.d {
            return Err(IndexError::DimMismatch {
                collection: self.name.clone(),
                expected: self.d,
                got: q.len(),
            });
        }
        let n = self.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let mut mq = q.to_vec();
        if self.metric == Metric::Cosine {
            l2_normalize(&mut mq);
        }
        let mut scores = vec![0f32; n];
        let mut off = 0usize;
        for part in self.exact_parts() {
            let rows = part.len() / self.d;
            if rows > 0 {
                kernels::scan_scores_f32(&mq, part, rows, threads, &mut scores[off..off + rows]);
            }
            off += rows;
        }
        Ok(top_indices(&scores, k)
            .into_iter()
            .map(|i| SearchHit { id: i, score: scores[i] })
            .collect())
    }
}

/// Quantize a buffer of pre-normalized residual rows at `bits` under
/// `rot`, packed from element 0 of a fresh buffer — the primitive
/// behind head/segment recoding, segment merging, and recovery's
/// requantize-stale-segment path. Deterministic and lossless-from-
/// exact, so every caller gets bytes bit-identical to a fresh encode.
pub(crate) fn quantize_rows(
    rot: &PracticalRht,
    d: usize,
    exact: &[f32],
    bits: u8,
) -> (Vec<u8>, Vec<f32>) {
    let n = exact.len() / d;
    let mut data = vec![0u8; (n * d * bits as usize).div_ceil(8)];
    let mut r = Vec::with_capacity(n);
    let mut seg = vec![0f32; d];
    let mut colcodes: Vec<u8> = Vec::with_capacity(d);
    for (i, row) in exact.chunks_exact(d).enumerate() {
        seg.copy_from_slice(row);
        rot.apply(&mut seg);
        r.push(quantize_column_into(&seg, bits, ScaleMode::MaxAbs, &mut colcodes));
        set_codes(&mut data, bits, i * d, &colcodes);
    }
    (data, r)
}

/// FNV-1a over the collection name: differentiates per-collection
/// rotation streams under one store seed.
fn hash_name(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

// ------------------------------------------------------------------ policy

/// How a store picks code widths for its collections.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum IndexPolicy {
    /// Every collection coded at one width (1..=8).
    Uniform(u8),
    /// Per-collection widths solved by AllocateBits under the store's
    /// total scan-payload byte budget, weighted by measured recall
    /// sensitivity (see [`VectorStore::rebalance`]).
    Budget {
        /// Candidate widths for the DP (e.g. `[2, 4, 8]`).
        bit_choices: Vec<u8>,
    },
}

impl Default for IndexPolicy {
    fn default() -> Self {
        IndexPolicy::Uniform(8)
    }
}

/// Store construction options.
#[derive(Clone, Debug)]
pub struct IndexConfig {
    /// Bit-width policy (uniform, or budget-solved per collection).
    pub policy: IndexPolicy,
    /// Total scan-payload budget in bytes across all collections
    /// (codes + rescales; the residual store is accounted separately,
    /// like the raw vectors an ANN system keeps beside its index).
    /// Required > 0 by [`IndexPolicy::Budget`], ignored otherwise.
    pub budget_bytes: usize,
    /// Metric applied to every collection.
    pub metric: Metric,
    /// Seed for the per-collection rotation signs.
    pub rot_seed: u64,
}

impl Default for IndexConfig {
    fn default() -> Self {
        IndexConfig {
            policy: IndexPolicy::default(),
            budget_bytes: 0,
            metric: Metric::Cosine,
            rot_seed: DEFAULT_ROT_SEED,
        }
    }
}

fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 64
        && name.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'-' || b == b'_')
}

// ------------------------------------------------------------------- store

/// Multiple named [`Collection`]s behind one bit-width policy — what the
/// serving layer ([`crate::serve::index::IndexServer`]) wraps.
#[derive(Clone, Debug)]
pub struct VectorStore {
    cfg: IndexConfig,
    collections: BTreeMap<String, Collection>,
    /// Row count at the last AllocateBits solve — the rebalance
    /// throttle's reference point (Budget policy only).
    rows_at_solve: usize,
}

impl VectorStore {
    /// Empty store. Fails on an invalid policy (bad widths, a Budget
    /// policy without a budget).
    pub fn new(cfg: IndexConfig) -> Result<VectorStore, IndexError> {
        match &cfg.policy {
            IndexPolicy::Uniform(bits) => {
                if !(1..=8).contains(bits) {
                    return Err(IndexError::BadBits(*bits));
                }
            }
            IndexPolicy::Budget { bit_choices } => {
                if bit_choices.is_empty() {
                    return Err(IndexError::Shape("empty index bit-choice set".into()));
                }
                if let Some(&b) = bit_choices.iter().find(|&&b| !(1..=8).contains(&b)) {
                    return Err(IndexError::BadBits(b));
                }
                if cfg.budget_bytes == 0 {
                    return Err(IndexError::Shape(
                        "Budget index policy needs a budget_bytes > 0".into(),
                    ));
                }
            }
        }
        Ok(VectorStore { cfg, collections: BTreeMap::new(), rows_at_solve: 0 })
    }

    /// The store's configuration.
    pub fn config(&self) -> &IndexConfig {
        &self.cfg
    }

    /// Number of collections.
    pub fn len(&self) -> usize {
        self.collections.len()
    }

    /// True when no collections exist.
    pub fn is_empty(&self) -> bool {
        self.collections.is_empty()
    }

    /// Borrow a collection.
    pub fn get(&self, name: &str) -> Result<&Collection, IndexError> {
        self.collections
            .get(name)
            .ok_or_else(|| IndexError::NoSuchCollection(name.to_string()))
    }

    /// Accounting snapshot of every collection, name order.
    pub fn infos(&self) -> Vec<CollectionInfo> {
        self.collections.values().map(Collection::info).collect()
    }

    /// Total scan payload across collections (the budgeted quantity).
    pub fn code_bytes(&self) -> usize {
        self.collections.values().map(Collection::code_bytes).sum()
    }

    /// Total stored rows across collections.
    pub fn rows(&self) -> usize {
        self.collections.values().map(Collection::len).sum()
    }

    /// Total sealed segments across collections.
    pub fn segments(&self) -> usize {
        self.collections.values().map(Collection::segment_count).sum()
    }

    /// Total unsealed head rows across collections (rows covered only
    /// by the WAL).
    pub fn head_rows(&self) -> usize {
        self.collections.values().map(Collection::head_rows).sum()
    }

    /// Cheapest width the policy admits (min bit choice; the uniform
    /// width under Uniform).
    fn min_bits(&self) -> u8 {
        match &self.cfg.policy {
            IndexPolicy::Uniform(b) => *b,
            IndexPolicy::Budget { bit_choices } => *bit_choices.iter().min().unwrap(),
        }
    }

    /// Width a freshly created collection starts at: the richest
    /// admissible (Budget collections are rebalanced down immediately,
    /// so starting rich costs nothing and never under-codes).
    fn initial_bits(&self) -> u8 {
        match &self.cfg.policy {
            IndexPolicy::Uniform(b) => *b,
            IndexPolicy::Budget { bit_choices } => *bit_choices.iter().max().unwrap(),
        }
    }

    /// Scan-payload bytes the store would need at the cheapest width if
    /// `extra_rows` of dimension `extra_d` joined collection `name`
    /// (admission check for the budget policy).
    fn min_bytes_with(&self, name: &str, extra_rows: usize, extra_d: usize) -> usize {
        let min_b = self.min_bits() as usize;
        let mut total = 0usize;
        for (cname, c) in &self.collections {
            let rows = c.len() + if cname == name { extra_rows } else { 0 };
            total += (rows * c.dim() * min_b).div_ceil(8) + 4 * rows;
        }
        if !self.collections.contains_key(name) {
            total += (extra_rows * extra_d * min_b).div_ceil(8) + 4 * extra_rows;
        }
        total
    }

    /// Append rows to `name` (created on first use), `vecs` row-major
    /// with `d` columns. Under [`IndexPolicy::Budget`] the add is
    /// admission-checked against the byte budget first — a store that
    /// cannot fit the rows even at the cheapest width refuses with
    /// [`IndexError::BudgetTooSmall`] and mutates nothing — and the
    /// store is rebalanced afterwards when the payload outgrew the
    /// budget or rows grew >= 25% since the last solve (throttled; see
    /// [`VectorStore::rebalance`]). Returns `(first_id, rows_added)`.
    pub fn add(
        &mut self,
        name: &str,
        vecs: &[f32],
        d: usize,
        threads: usize,
    ) -> Result<(usize, usize), IndexError> {
        if !valid_name(name) {
            return Err(IndexError::BadName(name.to_string()));
        }
        if d == 0 || vecs.is_empty() || vecs.len() % d != 0 {
            return Err(IndexError::BadQuery(format!(
                "vector payload of {} values is not a whole number of dimension-{d} rows",
                vecs.len()
            )));
        }
        let rows = vecs.len() / d;
        // dimension mismatch is a caller error (400) and must win over
        // the budget admission check (507) — check it first, before any
        // byte accounting that would price the rows at the wrong width
        if let Some(c) = self.collections.get(name) {
            if c.dim() != d {
                return Err(IndexError::DimMismatch {
                    collection: name.to_string(),
                    expected: c.dim(),
                    got: d,
                });
            }
        }
        if let IndexPolicy::Budget { .. } = &self.cfg.policy {
            let min_bytes = self.min_bytes_with(name, rows, d);
            if min_bytes > self.cfg.budget_bytes {
                return Err(IndexError::BudgetTooSmall {
                    budget_bytes: self.cfg.budget_bytes,
                    min_bytes,
                });
            }
        }
        if !self.collections.contains_key(name) {
            let c = Collection::new(
                name,
                d,
                self.initial_bits(),
                self.cfg.metric,
                self.cfg.rot_seed,
            )?;
            self.collections.insert(name.to_string(), c);
        }
        let first = self.collections.get_mut(name).expect("just inserted").add(vecs)?;
        if matches!(self.cfg.policy, IndexPolicy::Budget { .. }) {
            self.maybe_rebalance(threads)?;
        }
        Ok((first, rows))
    }

    /// [`VectorStore::add`] guarded by an expected first row id: the add
    /// applies only when the collection currently holds exactly
    /// `expect_first_id` rows (for a missing collection that count is
    /// 0), else it refuses with [`IndexError::Conflict`] and mutates
    /// nothing. The check and the add happen under the caller's single
    /// `&mut self` — one critical section — which is what makes a
    /// cluster router's retry-after-ambiguous-failure exactly-once: a
    /// conflict on the retry means the first attempt already applied.
    pub fn add_expect(
        &mut self,
        name: &str,
        vecs: &[f32],
        d: usize,
        threads: usize,
        expect_first_id: usize,
    ) -> Result<(usize, usize), IndexError> {
        let actual_rows = self.collections.get(name).map(Collection::len).unwrap_or(0);
        if actual_rows != expect_first_id {
            return Err(IndexError::Conflict {
                collection: name.to_string(),
                expected_first_id: expect_first_id,
                actual_rows,
            });
        }
        self.add(name, vecs, d, threads)
    }

    /// Two-phase top-k against one collection (see [`Collection::query`]).
    pub fn query(
        &self,
        name: &str,
        q: &[f32],
        k: usize,
        rerank_factor: usize,
        threads: usize,
    ) -> Result<Vec<SearchHit>, IndexError> {
        self.get(name)?.query(q, k, rerank_factor, threads)
    }

    /// Phase-1 shard scan (see [`Collection::scan_candidates`]):
    /// `(local_rows, local top-take estimated candidates)`.
    pub fn scan_candidates(
        &self,
        name: &str,
        q: &[f32],
        take: usize,
        threads: usize,
    ) -> Result<(usize, Vec<SearchHit>), IndexError> {
        let c = self.get(name)?;
        Ok((c.len(), c.scan_candidates(q, take, threads)?))
    }

    /// Phase-2 shard rerank (see [`Collection::exact_scores`]): exact
    /// scores of `ids`, input order.
    pub fn exact_scores(
        &self,
        name: &str,
        q: &[f32],
        ids: &[usize],
    ) -> Result<Vec<SearchHit>, IndexError> {
        self.get(name)?.exact_scores(q, ids)
    }

    /// Measured recall sensitivity of one collection: recall@k of the
    /// low-width probe scan against the exact scan, sampled over up to
    /// [`SENSITIVITY_SAMPLES`] stored rows used as queries. The DP alpha
    /// is `(gap + eps) * 2^probe * rows` — scaled so a collection whose
    /// ranking collapses at the probe width (`gap` → 1) outweighs one
    /// that survives it, with the `2^probe` factor translating the
    /// observed gap back to the `alpha * 2^-bits` error model and the
    /// row count weighting recall loss by how many rows it affects.
    fn recall_sensitivity(c: &Collection, probe_bits: u8, k: usize, threads: usize) -> f64 {
        let n = c.len();
        let k_eff = k.min(n).max(1);
        let (probe_data, probe_r) = c.quantize_all(probe_bits);
        let stride = (n / SENSITIVITY_SAMPLES).max(1);
        let mut samples = 0usize;
        let mut hits = 0usize;
        let mut est = vec![0f32; n];
        let mut exact = vec![0f32; n];
        let mut i = 0;
        while i < n && samples < SENSITIVITY_SAMPLES {
            let q = c.row_exact(i);
            let mut q_rot = q.to_vec();
            c.rot.apply(&mut q_rot);
            kernels::scan_scores_q(
                &q_rot,
                &probe_data,
                probe_bits,
                0,
                n,
                &probe_r,
                threads,
                &mut est,
            );
            let mut off = 0usize;
            for part in c.exact_parts() {
                let rows = part.len() / c.d;
                if rows > 0 {
                    kernels::scan_scores_f32(q, part, rows, threads, &mut exact[off..off + rows]);
                }
                off += rows;
            }
            let top_e = top_indices(&est, k_eff);
            let top_x = top_indices(&exact, k_eff);
            hits += top_x.iter().filter(|&&t| top_e.contains(&t)).count();
            samples += 1;
            i += stride;
        }
        let gap = 1.0 - hits as f64 / (samples * k_eff).max(1) as f64;
        let eps = 0.25 / (samples * k_eff).max(1) as f64;
        (gap + eps) * 2f64.powi(probe_bits as i32) * n as f64
    }

    /// Rebalance only when it can matter: the store's scan payload at
    /// current widths outgrew the budget (must shrink someone), or the
    /// row count grew >= 25% since the last solve (the DP answer may
    /// have shifted). Sensitivity measurement re-scans every collection,
    /// so an unthrottled per-add rebalance would be O(rows²) cumulative
    /// for row-at-a-time ingest; the growth trigger amortizes it.
    fn maybe_rebalance(&mut self, threads: usize) -> Result<(), IndexError> {
        let over_budget = self.code_bytes() > self.cfg.budget_bytes;
        let grown = self.rows_at_solve == 0
            || self.rows() >= self.rows_at_solve + self.rows_at_solve / 4;
        if over_budget || grown {
            self.rebalance(threads)?;
        }
        Ok(())
    }

    /// Re-solve every collection's width with AllocateBits under the
    /// store's scan-payload byte budget, then recode collections whose
    /// width changed (lossless-from-exact — see [`Collection::recode`]).
    ///
    /// The DP sees one item per non-empty collection, sized `rows * dim`
    /// codes, with the rescale-table overhead subtracted from the budget
    /// up front and alphas from the measured recall sensitivity at the
    /// cheapest candidate width. Called automatically on budget-policy
    /// adds (throttled — see `maybe_rebalance`); callers can force a
    /// re-solve any time. No-op under [`IndexPolicy::Uniform`].
    pub fn rebalance(&mut self, threads: usize) -> Result<(), IndexError> {
        let IndexPolicy::Budget { bit_choices } = self.cfg.policy.clone() else {
            return Ok(());
        };
        let probe = *bit_choices.iter().min().unwrap();
        let names: Vec<String> = self
            .collections
            .iter()
            .filter(|(_, c)| !c.is_empty())
            .map(|(n, _)| n.clone())
            .collect();
        if names.is_empty() {
            return Ok(());
        }
        let mut alphas = Vec::with_capacity(names.len());
        let mut m = Vec::with_capacity(names.len());
        let mut overhead = 0usize;
        for n in &names {
            let c = &self.collections[n];
            alphas.push(VectorStore::recall_sensitivity(c, probe, 10, threads));
            m.push(c.len() * c.dim());
            overhead += 4 * c.len();
        }
        let min_bytes = self.min_bytes_with("", 0, 0);
        if self.cfg.budget_bytes < min_bytes {
            return Err(IndexError::BudgetTooSmall {
                budget_bytes: self.cfg.budget_bytes,
                min_bytes,
            });
        }
        let budget_bits = (self.cfg.budget_bytes - overhead) as u64 * 8;
        let problem = AllocProblem {
            alphas,
            m,
            bit_choices: bit_choices.clone(),
            budget: budget_bits,
        };
        let sol = problem
            .solve()
            .map_err(|e| IndexError::Shape(format!("AllocateBits failed: {e}")))?;
        for (name, &bits) in names.iter().zip(&sol.bits) {
            self.collections
                .get_mut(name)
                .expect("collected above")
                .recode(bits)?;
        }
        self.rows_at_solve = self.rows();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randvecs(n: usize, d: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).gaussian_vec(n * d)
    }

    fn uniform_store(bits: u8) -> VectorStore {
        VectorStore::new(IndexConfig {
            policy: IndexPolicy::Uniform(bits),
            ..Default::default()
        })
        .unwrap()
    }

    /// recall@k of the two-phase query against the brute-force baseline,
    /// averaged over `queries` held-out query vectors.
    fn recall_at_k(
        store: &VectorStore,
        name: &str,
        queries: &[f32],
        d: usize,
        k: usize,
        rerank_factor: usize,
    ) -> f64 {
        let c = store.get(name).unwrap();
        let nq = queries.len() / d;
        let mut hits = 0usize;
        for qi in 0..nq {
            let q = &queries[qi * d..(qi + 1) * d];
            let got = c.query(q, k, rerank_factor, 1).unwrap();
            let want = c.brute_force(q, k, 1).unwrap();
            let want_ids: Vec<usize> = want.iter().map(|h| h.id).collect();
            hits += got.iter().filter(|h| want_ids.contains(&h.id)).count();
        }
        hits as f64 / (nq * k) as f64
    }

    #[test]
    fn add_and_query_basics() {
        let mut store = uniform_store(8);
        let (n, d) = (32usize, 24usize);
        let (first, rows) = store.add("docs", &randvecs(n, d, 1), d, 1).unwrap();
        assert_eq!((first, rows), (0, n));
        let (first, rows) = store.add("docs", &randvecs(4, d, 2), d, 1).unwrap();
        assert_eq!((first, rows), (n, 4));
        let c = store.get("docs").unwrap();
        assert_eq!(c.len(), n + 4);
        assert_eq!(c.dim(), d);
        let q = Rng::new(3).gaussian_vec(d);
        let hits = store.query("docs", &q, 5, 4, 1).unwrap();
        assert_eq!(hits.len(), 5);
        // descending exact scores, ids in range
        for w in hits.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
        assert!(hits.iter().all(|h| h.id < n + 4));
        // k larger than the collection truncates, never pads
        let all = store.query("docs", &q, 1000, 4, 1).unwrap();
        assert_eq!(all.len(), n + 4);
    }

    #[test]
    fn typed_errors_cover_the_request_surface() {
        let mut store = uniform_store(4);
        let d = 16usize;
        store.add("ok", &randvecs(4, d, 5), d, 1).unwrap();
        assert!(matches!(
            store.query("missing", &vec![0.0; d], 3, 4, 1),
            Err(IndexError::NoSuchCollection(_))
        ));
        assert!(matches!(
            store.query("ok", &vec![0.0; d + 1], 3, 4, 1),
            Err(IndexError::DimMismatch { expected: 16, got: 17, .. })
        ));
        assert!(matches!(
            store.query("ok", &vec![0.0; d], 0, 4, 1),
            Err(IndexError::BadQuery(_))
        ));
        assert!(matches!(
            store.add("ok", &randvecs(2, d + 1, 6), d + 1, 1),
            Err(IndexError::DimMismatch { .. })
        ));
        assert!(matches!(
            store.add("bad name!", &randvecs(1, d, 7), d, 1),
            Err(IndexError::BadName(_))
        ));
        assert!(matches!(
            store.add("empty", &[], d, 1),
            Err(IndexError::BadQuery(_))
        ));
        assert!(matches!(
            store.add("ragged", &randvecs(1, d, 8)[..d - 1], d, 1),
            Err(IndexError::BadQuery(_))
        ));
        assert_eq!(
            VectorStore::new(IndexConfig {
                policy: IndexPolicy::Uniform(9),
                ..Default::default()
            })
            .unwrap_err(),
            IndexError::BadBits(9)
        );
        assert!(matches!(
            VectorStore::new(IndexConfig {
                policy: IndexPolicy::Budget { bit_choices: vec![2, 4] },
                budget_bytes: 0,
                ..Default::default()
            }),
            Err(IndexError::Shape(_))
        ));
    }

    #[test]
    fn self_query_ranks_first_at_4_bits_and_up() {
        // the satellite property: add -> query of the identical vector
        // always ranks it first at >= 4 bits after rerank (cosine: the
        // self-score is exactly the metric maximum)
        let (n, d, k) = (128usize, 32usize, 5usize);
        for bits in [4u8, 8] {
            for seed in 0..4u64 {
                let mut store = uniform_store(bits);
                let vecs = randvecs(n, d, 100 + seed);
                store.add("self", &vecs, d, 1).unwrap();
                for probe in [0usize, n / 3, n - 1] {
                    let q = &vecs[probe * d..(probe + 1) * d];
                    let hits = store
                        .query("self", q, k, DEFAULT_RERANK_FACTOR, 1)
                        .unwrap();
                    assert_eq!(
                        hits[0].id, probe,
                        "bits={bits} seed={seed}: own vector must rank first"
                    );
                    assert!(
                        (hits[0].score - 1.0).abs() < 1e-4,
                        "cosine self-score must be ~1, got {}",
                        hits[0].score
                    );
                }
            }
        }
    }

    #[test]
    fn recall_is_nondecreasing_in_bits() {
        // the satellite property: recall@k vs the brute-force baseline,
        // non-decreasing along the 2 -> 4 -> 8 ladder on a seeded fixture
        let (n, d, k) = (256usize, 48usize, 10usize);
        let vecs = randvecs(n, d, 777);
        let queries = randvecs(24, d, 778);
        let mut prev = -1.0f64;
        for bits in [2u8, 4, 8] {
            let mut store = uniform_store(bits);
            store.add("fixture", &vecs, d, 1).unwrap();
            let r = recall_at_k(&store, "fixture", &queries, d, k, DEFAULT_RERANK_FACTOR);
            assert!(
                r >= prev,
                "recall@{k} regressed along the ladder: {r} < {prev} at {bits} bits"
            );
            prev = r;
        }
        assert!(prev >= 0.95, "8-bit recall@10 must clear 0.95, got {prev}");
    }

    #[test]
    fn rerank_rescues_phase1_misses() {
        // a wider rerank pool can only help: recall at rerank_factor 4
        // must be >= rerank_factor 1 (pure phase-1 ranking) at 2 bits
        let (n, d, k) = (256usize, 48usize, 10usize);
        let vecs = randvecs(n, d, 991);
        let queries = randvecs(16, d, 992);
        let mut store = uniform_store(2);
        store.add("fixture", &vecs, d, 1).unwrap();
        let r1 = recall_at_k(&store, "fixture", &queries, d, k, 1);
        let r4 = recall_at_k(&store, "fixture", &queries, d, k, 4);
        assert!(r4 >= r1, "wider rerank must not hurt recall: {r4} < {r1}");
    }

    #[test]
    fn recode_is_lossless_from_exact() {
        // recoding down and back up must equal a fresh build at the
        // final width, bit for bit (codes regenerate from exact rows)
        let (n, d) = (40usize, 20usize);
        let vecs = randvecs(n, d, 55);
        let mut a = Collection::new("a", d, 8, Metric::Cosine, 9).unwrap();
        a.add(&vecs).unwrap();
        a.recode(2).unwrap();
        a.recode(8).unwrap();
        let mut b = Collection::new("a", d, 8, Metric::Cosine, 9).unwrap();
        b.add(&vecs).unwrap();
        assert_eq!(a.codes, b.codes);
        assert_eq!(a.r, b.r);
        assert_eq!(a.bits(), 8);
        assert_eq!(a.recode(9).unwrap_err(), IndexError::BadBits(9));
    }

    #[test]
    fn bytes_per_row_beats_f32_by_3x_at_8_bits() {
        // the acceptance ratio: scan payload <= 1/3 of the f32 rows
        let d = 256usize;
        let c = Collection::new("b", d, 8, Metric::Cosine, 1).unwrap();
        assert_eq!(c.bytes_per_row(), d + 4);
        assert!(3 * c.bytes_per_row() <= 4 * d, "8-bit scan payload must be <= f32/3");
        let c2 = Collection::new("b", d, 2, Metric::Cosine, 1).unwrap();
        assert_eq!(c2.bytes_per_row(), d / 4 + 4);
    }

    #[test]
    fn budget_policy_admits_refuses_and_rebalances() {
        let d = 32usize;
        let rows_bytes = |n: usize, b: usize| (n * d * b).div_ceil(8) + 4 * n;
        // budget sized for 64 rows at 4 bits: an 8-vs-2 DP has room to move
        let budget = rows_bytes(64, 4);
        let mut store = VectorStore::new(IndexConfig {
            policy: IndexPolicy::Budget { bit_choices: vec![2, 4, 8] },
            budget_bytes: budget,
            ..Default::default()
        })
        .unwrap();
        store.add("a", &randvecs(32, d, 21), d, 1).unwrap();
        store.add("b", &randvecs(32, d, 22), d, 1).unwrap();
        // the solved widths fit the budget
        assert!(store.code_bytes() <= budget + store.len());
        for info in store.infos() {
            assert!((2..=8).contains(&info.bits), "{info:?}");
        }
        // an add the budget can never hold (even at 2 bits) is refused
        // atomically: typed error, row counts unchanged
        let before = store.rows();
        let err = store.add("a", &randvecs(4096, d, 23), d, 1).unwrap_err();
        assert!(matches!(err, IndexError::BudgetTooSmall { .. }), "{err:?}");
        assert_eq!(store.rows(), before, "refused add must not mutate");
    }

    #[test]
    fn budget_rebalance_respects_total_and_prefers_sensitive_rows() {
        // two collections, one with tightly clustered rows (rankings
        // collapse at 2 bits -> high measured sensitivity) and one with
        // well-spread rows; under a budget that cannot afford 8 bits
        // everywhere, the clustered collection must not end up below the
        // spread one
        let d = 32usize;
        let n = 48usize;
        let mut clustered = Vec::with_capacity(n * d);
        let base = Rng::new(31).gaussian_vec(d);
        let mut rng = Rng::new(32);
        for _ in 0..n {
            let noise = rng.gaussian_vec(d);
            clustered.extend(base.iter().zip(&noise).map(|(&b, &e)| b + 0.05 * e));
        }
        let spread = randvecs(n, d, 33);
        let rows_bytes = |nn: usize, b: usize| (nn * d * b).div_ceil(8) + 4 * nn;
        let budget = rows_bytes(n, 8) + rows_bytes(n, 2) + 8;
        let mut store = VectorStore::new(IndexConfig {
            policy: IndexPolicy::Budget { bit_choices: vec![2, 4, 8] },
            budget_bytes: budget,
            ..Default::default()
        })
        .unwrap();
        store.add("clustered", &clustered, d, 1).unwrap();
        store.add("spread", &spread, d, 1).unwrap();
        assert!(store.code_bytes() <= budget + store.len());
        let bits_of = |name: &str| store.get(name).unwrap().bits();
        assert!(
            bits_of("clustered") >= bits_of("spread"),
            "clustered {} vs spread {} — measured sensitivity must steer the bits",
            bits_of("clustered"),
            bits_of("spread")
        );
    }

    #[test]
    fn cosine_normalizes_and_ip_does_not() {
        let d = 8usize;
        let mut v = vec![0f32; d];
        v[0] = 4.0;
        let mut cos = Collection::new("c", d, 8, Metric::Cosine, 1).unwrap();
        cos.add(&v).unwrap();
        let hits = cos.query(&v, 1, 1, 1).unwrap();
        assert!((hits[0].score - 1.0).abs() < 1e-6, "cosine self-score is 1");
        let mut ip = Collection::new("i", d, 8, Metric::InnerProduct, 1).unwrap();
        ip.add(&v).unwrap();
        let hits = ip.query(&v, 1, 1, 1).unwrap();
        assert!((hits[0].score - 16.0).abs() < 1e-4, "ip self-score is ||v||^2");
        // zero vectors are storable and queryable (score 0), never NaN
        let z = vec![0f32; d];
        cos.add(&z).unwrap();
        let hits = cos.query(&z, 2, 2, 1).unwrap();
        assert!(hits.iter().all(|h| h.score.is_finite()));
    }

    #[test]
    fn query_deterministic_across_thread_counts() {
        let (n, d) = (300usize, 40usize);
        let mut store = uniform_store(5);
        store.add("t", &randvecs(n, d, 61), d, 1).unwrap();
        let q = Rng::new(62).gaussian_vec(d);
        let a = store.query("t", &q, 7, 4, 1).unwrap();
        let b = store.query("t", &q, 7, 4, 8).unwrap();
        assert_eq!(a, b, "two-phase query must be bit-deterministic in threads");
    }

    #[test]
    fn top_indices_orders_and_breaks_ties_deterministically() {
        let scores = [1.0f32, 3.0, 3.0, -1.0, 2.0];
        assert_eq!(top_indices(&scores, 3), vec![1, 2, 4]);
        assert_eq!(top_indices(&scores, 99), vec![1, 2, 4, 0, 3]);
        assert!(top_indices(&scores, 0).is_empty());
        assert!(top_indices(&[], 3).is_empty());
    }

    #[test]
    fn empty_collection_queries_cleanly() {
        let mut c = Collection::new("e", 8, 4, Metric::Cosine, 1).unwrap();
        assert!(c.is_empty());
        assert!(c.query(&vec![1.0; 8], 3, 4, 1).unwrap().is_empty());
        assert!(c.brute_force(&vec![1.0; 8], 3, 1).unwrap().is_empty());
        c.add(&vec![1.0; 8]).unwrap();
        assert_eq!(c.query(&vec![1.0; 8], 3, 4, 1).unwrap().len(), 1);
    }

    #[test]
    fn info_accounting_is_exact() {
        let (n, d, bits) = (10usize, 12usize, 5u8);
        let mut store = uniform_store(bits);
        store.add("acct", &randvecs(n, d, 71), d, 1).unwrap();
        let info = &store.infos()[0];
        assert_eq!(info.rows, n);
        assert_eq!(info.dim, d);
        assert_eq!(info.bits, bits);
        assert_eq!(info.bytes_per_row, (d * bits as usize).div_ceil(8) + 4);
        assert_eq!(info.code_bytes, (n * d * bits as usize).div_ceil(8) + 4 * n);
        assert_eq!(info.exact_bytes, n * d * 4);
        assert_eq!(store.code_bytes(), info.code_bytes);
        assert_eq!(store.rows(), n);
    }

    #[test]
    fn sealed_collection_queries_bit_identical_to_monolithic() {
        // the tentpole invariant: scatter-gathered phase-1 scans across
        // sealed segments + head merge to exactly the monolithic result
        let (n, d) = (96usize, 24usize);
        let vecs = randvecs(n, d, 4242);
        let mut mono = Collection::new("s", d, 5, Metric::Cosine, 9).unwrap();
        mono.add(&vecs).unwrap();
        let mut seg = Collection::new("s", d, 5, Metric::Cosine, 9).unwrap();
        for (i, chunk) in vecs.chunks(32 * d).enumerate() {
            let first = seg.add(chunk).unwrap();
            assert_eq!(first, i * 32, "global ids must survive sealing");
            seg.seal_head(i as u64);
        }
        assert_eq!(seg.len(), n);
        assert_eq!(seg.segment_count(), 3);
        assert_eq!(seg.head_rows(), 0);
        for qseed in [7u64, 8, 9] {
            let q = Rng::new(qseed).gaussian_vec(d);
            assert_eq!(
                seg.query(&q, 10, 4, 1).unwrap(),
                mono.query(&q, 10, 4, 1).unwrap(),
                "segmented and monolithic queries must agree bit-for-bit"
            );
            assert_eq!(
                seg.brute_force(&q, 10, 1).unwrap(),
                mono.brute_force(&q, 10, 1).unwrap()
            );
        }
        // a half-sealed collection (segments + non-empty head) too
        let mut half = Collection::new("s", d, 5, Metric::Cosine, 9).unwrap();
        half.add(&vecs[..64 * d]).unwrap();
        half.seal_head(0);
        half.add(&vecs[64 * d..]).unwrap();
        assert_eq!(half.head_rows(), 32);
        let q = Rng::new(7).gaussian_vec(d);
        assert_eq!(half.query(&q, 10, 4, 1).unwrap(), mono.query(&q, 10, 4, 1).unwrap());
        // flat views equal the monolithic buffers bit-for-bit
        let (fc, fr) = half.flat_codes_r();
        assert_eq!((fc, fr), (mono.codes.clone(), mono.r.clone()));
        assert_eq!(half.flat_exact(), mono.exact);
        assert_eq!(half.code_bytes(), mono.code_bytes());
        assert_eq!(half.exact_bytes(), mono.exact_bytes());
    }

    #[test]
    fn recode_spans_sealed_segments_and_stays_lossless() {
        let (n, d) = (48usize, 16usize);
        let vecs = randvecs(n, d, 66);
        let mut seg = Collection::new("r", d, 8, Metric::Cosine, 9).unwrap();
        seg.add(&vecs[..24 * d]).unwrap();
        seg.seal_head(0);
        seg.add(&vecs[24 * d..]).unwrap();
        seg.recode(3).unwrap();
        assert_eq!(seg.segments()[0].disk_bits, 8, "disk width is stale after recode");
        let mut mono = Collection::new("r", d, 3, Metric::Cosine, 9).unwrap();
        mono.add(&vecs).unwrap();
        let (fc, fr) = seg.flat_codes_r();
        assert_eq!((fc, fr), (mono.codes.clone(), mono.r.clone()));
        let q = Rng::new(5).gaussian_vec(d);
        assert_eq!(seg.query(&q, 8, 4, 1).unwrap(), mono.query(&q, 8, 4, 1).unwrap());
    }

    #[test]
    fn scan_candidates_and_exact_scores_compose_to_query() {
        // the cluster decomposition over ONE shard: phase-1 candidates
        // (est scores) -> exact rerank -> (score desc, id asc) merge
        // must reproduce Collection::query bit for bit
        let (n, d, k) = (96usize, 24usize, 7usize);
        let mut store = uniform_store(5);
        store.add("c", &randvecs(n, d, 91), d, 1).unwrap();
        let q = Rng::new(92).gaussian_vec(d);
        let take = DEFAULT_RERANK_FACTOR * k;
        let (rows, cands) = store.scan_candidates("c", &q, take, 1).unwrap();
        assert_eq!(rows, n);
        assert_eq!(cands.len(), take.min(n));
        // candidates are (est desc, id asc) like top_indices
        for w in cands.windows(2) {
            assert!(w[0].score > w[1].score || (w[0].score == w[1].score && w[0].id < w[1].id));
        }
        let ids: Vec<usize> = cands.iter().map(|h| h.id).collect();
        let mut hits = store.exact_scores("c", &q, &ids).unwrap();
        hits.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
        hits.truncate(k);
        assert_eq!(hits, store.query("c", &q, k, DEFAULT_RERANK_FACTOR, 1).unwrap());
        // typed edges
        assert!(matches!(
            store.scan_candidates("c", &q, 0, 1),
            Err(IndexError::BadQuery(_))
        ));
        assert!(matches!(
            store.exact_scores("c", &q, &[n]),
            Err(IndexError::BadQuery(_))
        ));
        assert!(matches!(
            store.exact_scores("c", &vec![0.0; d + 1], &[0]),
            Err(IndexError::DimMismatch { .. })
        ));
        assert!(matches!(
            store.scan_candidates("missing", &q, take, 1),
            Err(IndexError::NoSuchCollection(_))
        ));
    }

    #[test]
    fn add_expect_guards_row_position() {
        let mut store = uniform_store(8);
        let d = 8usize;
        // a fresh collection counts as 0 rows for the guard
        assert!(matches!(
            store.add_expect("g", &randvecs(2, d, 1), d, 1, 3),
            Err(IndexError::Conflict { expected_first_id: 3, actual_rows: 0, .. })
        ));
        assert_eq!(store.rows(), 0, "refused add must not mutate");
        store.add_expect("g", &randvecs(2, d, 1), d, 1, 0).unwrap();
        store.add_expect("g", &randvecs(3, d, 2), d, 1, 2).unwrap();
        assert_eq!(store.rows(), 5);
        // a replayed add (same expect) conflicts — the exactly-once seam
        let err = store.add_expect("g", &randvecs(3, d, 2), d, 1, 2).unwrap_err();
        assert!(matches!(
            err,
            IndexError::Conflict { expected_first_id: 2, actual_rows: 5, .. }
        ));
    }

    #[test]
    fn nonpow2_dims_roundtrip() {
        // non-power-of-2 dimension exercises both practical-RHT windows
        let (n, d) = (64usize, 48usize);
        let vecs = randvecs(n, d, 81);
        let mut store = uniform_store(8);
        store.add("np2", &vecs, d, 1).unwrap();
        let q = &vecs[5 * d..6 * d];
        let hits = store.query("np2", q, 3, 4, 1).unwrap();
        assert_eq!(hits[0].id, 5);
    }
}
