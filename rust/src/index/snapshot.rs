//! The canonical **logical** encoding of a whole [`VectorStore`].
//!
//! A snapshot serializes a store — per collection the packed codes,
//! rescales, residual f32 store, current bit-width, and the rotation's
//! Rademacher sign diagonals — plus the store-global `next_seq` and
//! the rebalance throttle's `rows_at_solve`. Because RaBitQ codes are
//! deterministic and recoding is lossless-from-exact, this *is* the
//! live layout: decoding reproduces the store bit-for-bit.
//!
//! Since ISSUE 8 the production on-disk format is segmented (see
//! [`super::segment`]): monolithic `snapshot-<seq>.seg` files are no
//! longer written. This encoding survives as the store's **canonical
//! flattened form** — sealed segments are serialized as one contiguous
//! buffer per collection, exactly the bytes a never-sealed store would
//! produce — which is what makes "recovery ≡ fresh build" testable as
//! plain byte equality: the crash walls and the cross-language golden
//! fixtures compare `encode_snapshot` outputs, independent of where
//! segment boundaries happen to fall.
//!
//! Serializing the sign diagonals (rather than the rotation seed) makes
//! the format self-contained: decoding never re-runs the sampling RNG,
//! and the numpy mirror can author byte-exact fixtures with explicitly
//! chosen signs.
//!
//! ## Wire format (all integers little-endian)
//!
//! ```text
//! [magic: "RQSN"] [version: u32 = 1]
//! [next_seq: u64] [rows_at_solve: u64] [n_collections: u32]
//! per collection, name order:
//!   [name_len: u16] [name]
//!   [d: u32] [bits: u8] [metric: u8]        metric: 0 = ip, 1 = cosine
//!   [d_hat: u32] [signs1: d_hat * f32]
//!   [signs2_len: u32] [signs2: signs2_len * f32]
//!   [nrows: u32]
//!   [codes_len: u32] [codes bytes]
//!   [r: nrows * f32]
//!   [exact: nrows * d * f32]
//! [crc: u32]                               CRC-32 of every prior byte
//! ```

use super::wal::crc32;
use super::{Collection, IndexConfig, IndexError, Metric, VectorStore};
use crate::hadamard::PracticalRht;
use std::collections::BTreeMap;

/// Four-byte magic at offset 0 of every snapshot encoding.
pub const SNAPSHOT_MAGIC: &[u8; 4] = b"RQSN";

/// Current snapshot format version.
pub const SNAPSHOT_VERSION: u32 = 1;

fn push_f32s(out: &mut Vec<u8>, vals: &[f32]) {
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Serialize `store` (durable through `next_seq`) to canonical
/// snapshot bytes. Sealed segments are flattened into one contiguous
/// buffer per collection (lossless requantize from the residual store
/// when segments exist), so the output is independent of segment
/// boundaries: a sealed-and-compacted store and a monolithic build of
/// the same rows encode identically.
pub fn encode_snapshot(store: &VectorStore, next_seq: u64) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(SNAPSHOT_MAGIC);
    out.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
    out.extend_from_slice(&next_seq.to_le_bytes());
    out.extend_from_slice(&(store.rows_at_solve as u64).to_le_bytes());
    out.extend_from_slice(&(store.collections.len() as u32).to_le_bytes());
    for (name, c) in &store.collections {
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&(c.d as u32).to_le_bytes());
        out.push(c.bits);
        out.push(match c.metric {
            Metric::InnerProduct => 0,
            Metric::Cosine => 1,
        });
        out.extend_from_slice(&(c.rot.d_hat as u32).to_le_bytes());
        push_f32s(&mut out, &c.rot.signs1);
        out.extend_from_slice(&(c.rot.signs2.len() as u32).to_le_bytes());
        push_f32s(&mut out, &c.rot.signs2);
        let (codes, r) = c.flat_codes_r();
        let exact = c.flat_exact();
        out.extend_from_slice(&(r.len() as u32).to_le_bytes());
        out.extend_from_slice(&(codes.len() as u32).to_le_bytes());
        out.extend_from_slice(&codes);
        push_f32s(&mut out, &r);
        push_f32s(&mut out, &exact);
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Cursor-style reader over an encoded record; every take is
/// bounds-checked so corrupt lengths surface as typed errors, never
/// panics. Shared by the snapshot, segment, and manifest decoders.
pub(crate) struct Cur<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    /// Reader over `b`, positioned at offset 0.
    pub(crate) fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, off: 0 }
    }

    /// True when every byte has been consumed.
    pub(crate) fn done(&self) -> bool {
        self.off == self.b.len()
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], IndexError> {
        if self.b.len() - self.off < n {
            return Err(IndexError::Io("encoded record truncated".into()));
        }
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, IndexError> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u16(&mut self) -> Result<u16, IndexError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub(crate) fn u32(&mut self) -> Result<u32, IndexError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, IndexError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f32s(&mut self, n: usize) -> Result<Vec<f32>, IndexError> {
        let raw = self.take(n.checked_mul(4).ok_or_else(overflow)?)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

fn overflow() -> IndexError {
    IndexError::Io("snapshot length overflow".into())
}

fn corrupt(what: &str) -> IndexError {
    IndexError::Io(format!("snapshot corrupt: {what}"))
}

/// Decode snapshot bytes into a [`VectorStore`] under `cfg`, returning
/// the store and the `next_seq` the snapshot sealed. Any structural or
/// checksum violation is a typed error — recovery treats it as "this
/// snapshot is unusable, try an older one", never a panic.
pub fn decode_snapshot(
    bytes: &[u8],
    cfg: IndexConfig,
) -> Result<(VectorStore, u64), IndexError> {
    if bytes.len() < 4 + 4 + 8 + 8 + 4 + 4 {
        return Err(corrupt("too short for a header"));
    }
    let (body, tail) = bytes.split_at(bytes.len() - 4);
    let stored_crc = u32::from_le_bytes(tail.try_into().unwrap());
    if crc32(body) != stored_crc {
        return Err(corrupt("checksum mismatch"));
    }
    let mut cur = Cur::new(body);
    if cur.take(4)? != SNAPSHOT_MAGIC {
        return Err(corrupt("bad magic"));
    }
    let version = cur.u32()?;
    if version != SNAPSHOT_VERSION {
        return Err(IndexError::Io(format!(
            "snapshot version {version} unsupported (this build reads {SNAPSHOT_VERSION})"
        )));
    }
    let next_seq = cur.u64()?;
    let rows_at_solve = cur.u64()? as usize;
    let n_collections = cur.u32()? as usize;
    let mut collections = BTreeMap::new();
    for _ in 0..n_collections {
        let name_len = cur.u16()? as usize;
        let name = std::str::from_utf8(cur.take(name_len)?)
            .map_err(|_| corrupt("collection name not UTF-8"))?
            .to_string();
        let d = cur.u32()? as usize;
        let bits = cur.u8()?;
        let metric = match cur.u8()? {
            0 => Metric::InnerProduct,
            1 => Metric::Cosine,
            m => return Err(corrupt(&format!("unknown metric tag {m}"))),
        };
        if d == 0 || !(1..=8).contains(&bits) {
            return Err(corrupt("bad dimension or bit-width"));
        }
        let d_hat = cur.u32()? as usize;
        if d_hat == 0 || d_hat > d {
            return Err(corrupt("rotation window larger than dimension"));
        }
        let signs1 = cur.f32s(d_hat)?;
        let signs2_len = cur.u32()? as usize;
        if signs2_len != 0 && signs2_len != d_hat {
            return Err(corrupt("second sign diagonal length mismatch"));
        }
        let signs2 = cur.f32s(signs2_len)?;
        let nrows = cur.u32()? as usize;
        let codes_len = cur.u32()? as usize;
        let want_codes = nrows
            .checked_mul(d)
            .and_then(|x| x.checked_mul(bits as usize))
            .ok_or_else(overflow)?
            .div_ceil(8);
        if codes_len != want_codes {
            return Err(corrupt("code buffer length inconsistent with rows"));
        }
        let codes = cur.take(codes_len)?.to_vec();
        let r = cur.f32s(nrows)?;
        let exact = cur.f32s(nrows.checked_mul(d).ok_or_else(overflow)?)?;
        let rot = PracticalRht { d, d_hat, signs1, signs2 };
        collections.insert(
            name.clone(),
            Collection { name, d, bits, metric, rot, sealed: Vec::new(), codes, r, exact },
        );
    }
    if !cur.done() {
        return Err(corrupt("trailing bytes after last collection"));
    }
    Ok((VectorStore { cfg, collections, rows_at_solve }, next_seq))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::IndexPolicy;
    use crate::rng::Rng;

    fn built_store() -> VectorStore {
        let mut store = VectorStore::new(IndexConfig {
            policy: IndexPolicy::Uniform(5),
            ..Default::default()
        })
        .unwrap();
        let d = 24usize;
        store.add("alpha", &Rng::new(1).gaussian_vec(8 * d), d, 1).unwrap();
        store.add("beta", &Rng::new(2).gaussian_vec(3 * 48), 48, 1).unwrap();
        store
    }

    fn assert_stores_equal(a: &VectorStore, b: &VectorStore) {
        assert_eq!(a.rows_at_solve, b.rows_at_solve);
        assert_eq!(a.collections.len(), b.collections.len());
        for (name, ca) in &a.collections {
            let cb = &b.collections[name];
            assert_eq!(ca.d, cb.d);
            assert_eq!(ca.bits, cb.bits);
            assert_eq!(ca.metric, cb.metric);
            assert_eq!(ca.rot.signs1, cb.rot.signs1);
            assert_eq!(ca.rot.signs2, cb.rot.signs2);
            assert_eq!(ca.codes, cb.codes);
            assert_eq!(ca.r, cb.r);
            assert_eq!(ca.exact, cb.exact);
        }
    }

    #[test]
    fn snapshot_round_trips_bit_for_bit() {
        let store = built_store();
        let bytes = encode_snapshot(&store, 42);
        let (back, seq) = decode_snapshot(&bytes, store.cfg.clone()).unwrap();
        assert_eq!(seq, 42);
        assert_stores_equal(&store, &back);
    }

    #[test]
    fn every_single_bit_flip_is_rejected() {
        let store = built_store();
        let bytes = encode_snapshot(&store, 7);
        // sample offsets across the file (every byte is covered by the
        // whole-body CRC; stepping keeps the test fast)
        for byte in (0..bytes.len()).step_by(13) {
            let mut bad = bytes.clone();
            bad[byte] ^= 0x04;
            assert!(
                decode_snapshot(&bad, store.cfg.clone()).is_err(),
                "flip at byte {byte} must not decode"
            );
        }
    }

    #[test]
    fn truncations_are_rejected_not_panics() {
        let store = built_store();
        let bytes = encode_snapshot(&store, 7);
        for cut in (0..bytes.len()).step_by(7) {
            assert!(
                decode_snapshot(&bytes[..cut], store.cfg.clone()).is_err(),
                "truncation to {cut} bytes must not decode"
            );
        }
    }

    #[test]
    fn sealed_store_encodes_identically_to_monolithic() {
        // the canonical-flattening property: segment boundaries are
        // invisible in the logical encoding
        let mono = built_store();
        let mut sealed = built_store();
        for c in sealed.collections.values_mut() {
            c.seal_head(7);
        }
        assert_eq!(encode_snapshot(&sealed, 42), encode_snapshot(&mono, 42));
    }

    #[test]
    fn empty_store_snapshots_cleanly() {
        let store = VectorStore::new(IndexConfig::default()).unwrap();
        let bytes = encode_snapshot(&store, 0);
        let (back, seq) = decode_snapshot(&bytes, store.cfg.clone()).unwrap();
        assert_eq!(seq, 0);
        assert!(back.collections.is_empty());
    }
}
