//! Durability orchestration: WAL-before-ack writes, head sealing into
//! immutable segments, and crash recovery for a [`VectorStore`].
//!
//! [`DurableStore`] wraps a store with an optional durability engine.
//! Without one (`DurableStore::ephemeral`) it is a zero-cost
//! pass-through — the serving layer holds one type either way. With one
//! ([`DurableStore::open`]):
//!
//! * **Write path** — an `add` first applies to the in-memory store
//!   (so admission failures, bad names, and budget refusals never
//!   reach the log), then appends one WAL record stamped with the next
//!   store-global sequence number, then acknowledges. Under
//!   [`FsyncPolicy::Always`] the append is flushed before the ack.
//!   A **failed append** consumed a sequence number without logging a
//!   record — left alone that gap would make recovery drop every later
//!   acked record — so the engine immediately reseals: if the seal
//!   lands, the rows are durable and the add is acknowledged normally;
//!   if it also fails, the store flips **read-only**
//!   ([`IndexError::ReadOnly`], HTTP 503) so no further ack can be
//!   issued that recovery would silently void, and a client retry is
//!   refused rather than applied twice.
//! * **Seal path** — after every `snapshot_every` acknowledged *rows*
//!   (not records — a 100-row add moves the store as far from its last
//!   checkpoint as 100 single-row adds), whenever a collection's head
//!   reaches `segment_rows`, and on [`DurableStore::seal_now`], each
//!   non-empty head is written to one immutable CRC'd **segment file**
//!   and a new **manifest** generation listing every live segment is
//!   written (atomic temp + fsync + rename; the manifest write is the
//!   single commit point). Then the WAL files are deleted (their
//!   records are sealed) and stale manifests/segments beyond one spare
//!   generation are pruned. Sealing is O(head rows): sealed segments
//!   are never re-encoded, which is what replaced the PR-6 monolithic
//!   whole-store snapshot (O(store rows) per cadence write).
//! * **Recovery** ([`recover`]) — load the newest fully-decodable
//!   manifest generation (a corrupt manifest *or any corrupt/missing
//!   segment it references* fails the whole generation; older ones are
//!   tried), rebuilding each collection's sealed segments — rows whose
//!   on-disk width predates a rebalance are requantized from the
//!   segment's residual store, bit-identical to a fresh encode. Then
//!   parse every WAL file stop-at-first-corruption, merge the surviving
//!   records by global sequence number, and replay the contiguous run
//!   starting at the manifest's `next_seq` through the normal `add`
//!   path (into the heads). Records already sealed (seq below
//!   `next_seq`) are skipped — replay is idempotent; records after a
//!   sequence gap are dropped — a lost record invalidates everything
//!   that depended on coming after it. The outcome is surfaced as
//!   [`RecoveryReport`] (`/v1/stats` reports `recovered_rows` /
//!   `dropped_records`). When recovery dropped, skipped, or rejected
//!   *anything* (torn tail, checksum failure, sequence gap, stale
//!   duplicate, corrupt generation), the damaged bytes are still on
//!   disk — appending after a corrupt tail would make every new record
//!   unreadable at the next recovery, and reusing post-gap sequence
//!   numbers could resurrect stale records over acknowledged ones. So
//!   [`DurableStore::open_with`] **reseals before accepting writes**:
//!   one immediate seal checkpoints the recovered state, deletes every
//!   WAL file (corrupt tails and stale records included), and prunes
//!   undecodable generations. A second crash right after restart
//!   therefore recovers cleanly.
//!
//! Because replay re-runs the deterministic quantization pipeline and
//! segment files store the exact in-memory layout, a recovered store
//! equals a never-crashed store **bit-for-bit** (codes, rescales,
//! residuals, bit plan) up to the last durable record — the property
//! the fault-injection walls in `rust/tests/durability.rs` and
//! `rust/tests/segments.rs` assert for every fault the
//! [`super::io::FaultIo`] shim can inject, at every write ordinal.
//!
//! ## Locking
//!
//! [`DurableStore`] is internally synchronized and all methods take
//! `&self`, so the serving layer shares it behind an `Arc` with **no
//! outer lock**. The store proper lives in an `RwLock` (queries and
//! stats take read locks; applying an add or moving a sealed head
//! takes a brief write lock), and the engine — WAL cursors, the
//! [`Io`] handle, seal bookkeeping — lives in a `Mutex` that
//! serializes writers only. Seal and segment I/O runs while holding
//! the engine lock but **no store lock**, so a query never waits on a
//! slow disk flush (the PR-8 headline fix — the old design serialized
//! every query behind snapshot I/O). Lock order is engine → store;
//! read paths take only the store lock.

use super::io::{Io, StdIo};
use super::segment::{
    decode_manifest, decode_segment, encode_manifest, encode_segment, list_manifests,
    manifest_path, parse_segment_file, segment_path, ManifestCollection, ManifestSegment,
    SegmentData, StoreManifest, SEGMENT_DIR,
};
use super::wal::{decode_records, encode_record, wal_path, WalRecord, WalTail, WAL_DIR};
use super::{Collection, IndexConfig, IndexError, SearchHit, VectorStore};
use crate::hadamard::PracticalRht;
use crate::obs;
use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, RwLock, RwLockReadGuard};

/// When WAL appends are flushed to stable storage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync every WAL append before acknowledging — an acked add
    /// survives power loss, at one disk flush per add.
    Always,
    /// Leave flushing to the OS page cache — an acked add survives
    /// process death but a power cut may tear the tail (which recovery
    /// tolerates by design).
    Never,
}

/// Durability configuration for [`DurableStore::open`].
#[derive(Clone, Debug)]
pub struct DurabilityConfig {
    /// Directory holding `wal/`, `segments/`, and the manifests.
    pub data_dir: PathBuf,
    /// WAL flush policy.
    pub fsync: FsyncPolicy,
    /// Acknowledged **rows** between automatic seals; `0` disables the
    /// cadence (explicit [`DurableStore::seal_now`] and the
    /// `segment_rows` trigger only). Rows, not records: one bulk add of
    /// `n` rows counts `n` toward the cadence, so WAL replay debt is
    /// bounded by data volume rather than request count.
    pub snapshot_every: usize,
    /// Seal whenever a collection's mutable head reaches this many
    /// rows, bounding per-collection segment size (and hence seal
    /// cost); `0` disables the trigger.
    pub segment_rows: usize,
}

/// What recovery found and did, for `/v1/stats` and the test walls.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Rows restored from the manifest's sealed segments.
    pub snapshot_rows: usize,
    /// Rows replayed from WAL records (into the heads).
    pub replayed_rows: usize,
    /// WAL records dropped: corrupt/torn tails (one per damaged file)
    /// plus whole records lost to a sequence gap.
    pub dropped_records: usize,
    /// WAL records skipped because a sealed segment already holds them
    /// (duplicate replay — idempotence, not loss).
    pub duplicate_records: usize,
    /// Manifest generations that failed to load — a corrupt manifest,
    /// or a referenced segment file that was missing, corrupt, or
    /// inconsistent with its manifest entry — and were skipped.
    pub corrupt_snapshots: usize,
}

impl RecoveryReport {
    /// Total rows the store holds because of recovery (sealed segments
    /// + replay) — the `recovered_rows` stats field.
    pub fn recovered_rows(&self) -> usize {
        self.snapshot_rows + self.replayed_rows
    }
}

/// Everything [`recover`] hands back: the rebuilt store plus the
/// cursors the engine resumes from.
pub struct Recovered {
    /// The recovered store (sealed segments + replayed heads).
    pub store: VectorStore,
    /// WAL sequence number the next add will be stamped with.
    pub next_seq: u64,
    /// Next unused store-global segment id.
    pub next_seg_id: u64,
    /// Generation the next manifest will be written at — strictly
    /// above every manifest file seen on disk, decodable or not, so a
    /// rejected generation is never overwritten (it is evidence).
    pub next_gen: u64,
    /// The manifest generation that was loaded, if any.
    pub loaded_gen: Option<u64>,
    /// What recovery found and did.
    pub report: RecoveryReport,
}

/// Load the newest usable manifest generation and replay the WAL tail.
/// Never fails on *corruption* (that is data, reported in the
/// [`RecoveryReport`]); fails only on an invalid `cfg` or an I/O error
/// outside any particular generation.
pub fn recover(
    io: &mut dyn Io,
    data_dir: &Path,
    cfg: IndexConfig,
) -> Result<Recovered, IndexError> {
    let mut report = RecoveryReport::default();
    let gens = list_manifests(io, data_dir)?;
    let next_gen = gens.first().map_or(1, |g| g + 1);
    // newest fully-loadable generation wins; a generation with a
    // corrupt manifest OR any bad referenced segment is skipped whole —
    // partial loads could mix segments from different swaps
    let mut loaded: Option<(VectorStore, u64, u64, u64)> = None;
    for &gen in &gens {
        match load_manifest_generation(io, data_dir, gen, &cfg) {
            Ok((store, m)) => {
                loaded = Some((store, m.next_seq, m.next_seg_id, gen));
                break;
            }
            Err(_) => report.corrupt_snapshots += 1,
        }
    }
    let (mut store, mut next_seq, next_seg_id, loaded_gen) = match loaded {
        Some((s, seq, seg, gen)) => (s, seq, seg, Some(gen)),
        None => (VectorStore::new(cfg)?, 0, 1, None),
    };
    report.snapshot_rows = store.rows();
    // parse every WAL file stop-at-first-corruption, then merge by the
    // store-global sequence number to reconstruct the original
    // interleaved add order (the Budget policy's rebalance cadence —
    // hence the final bit plan — depends on it)
    let wal_dir = data_dir.join(WAL_DIR);
    let mut records: Vec<WalRecord> = Vec::new();
    for name in io
        .list(&wal_dir)
        .map_err(|e| IndexError::Io(format!("listing {}: {e}", wal_dir.display())))?
    {
        if !name.ends_with(".wal") {
            continue;
        }
        let path = wal_dir.join(&name);
        let bytes = io
            .read(&path)
            .map_err(|e| IndexError::Io(format!("reading {}: {e}", path.display())))?
            .unwrap_or_default();
        let (recs, tail) = decode_records(&bytes);
        if tail != WalTail::Clean {
            report.dropped_records += 1;
        }
        records.extend(recs);
    }
    records.sort_by_key(|r| r.seq);
    // replay the contiguous run from next_seq; duplicates (already
    // sealed into segments) are skipped, anything after a gap dropped
    for rec in records {
        if rec.seq < next_seq {
            report.duplicate_records += 1;
            continue;
        }
        if rec.seq > next_seq {
            report.dropped_records += 1;
            continue;
        }
        match store.add(&rec.name, &rec.rows, rec.dim, 0) {
            Ok((_, rows)) => report.replayed_rows += rows,
            // a record the store now refuses (e.g. budget shrank across
            // restarts) is dropped, not fatal — recovery must finish
            Err(_) => {
                report.dropped_records += 1;
                continue;
            }
        }
        next_seq = rec.seq + 1;
    }
    Ok(Recovered { store, next_seq, next_seg_id, next_gen, loaded_gen, report })
}

/// Rebuild a store from one manifest generation. Any failure — corrupt
/// manifest, missing/corrupt segment file, or a segment inconsistent
/// with the manifest entry that referenced it — rejects the whole
/// generation (the caller falls back to an older one).
fn load_manifest_generation(
    io: &mut dyn Io,
    data_dir: &Path,
    gen: u64,
    cfg: &IndexConfig,
) -> Result<(VectorStore, StoreManifest), IndexError> {
    let corrupt = |what: String| IndexError::Io(format!("manifest generation {gen}: {what}"));
    let path = manifest_path(data_dir, gen);
    let bytes = io
        .read(&path)
        .map_err(|e| corrupt(format!("reading {}: {e}", path.display())))?
        .ok_or_else(|| corrupt("manifest file vanished".into()))?;
    let m = decode_manifest(&bytes)?;
    if m.gen != gen {
        return Err(corrupt(format!("file names gen {gen} but payload says {}", m.gen)));
    }
    let mut collections: BTreeMap<String, Collection> = BTreeMap::new();
    for mc in &m.collections {
        let d_hat = mc.signs1.len();
        if !d_hat.is_power_of_two() {
            return Err(corrupt(format!("rotation window {d_hat} is not a power of two")));
        }
        let rot = PracticalRht {
            d: mc.d,
            d_hat,
            signs1: mc.signs1.clone(),
            signs2: mc.signs2.clone(),
        };
        let mut sealed: Vec<SegmentData> = Vec::new();
        for sref in &mc.segments {
            let spath = segment_path(data_dir, &mc.name, sref.id);
            let sbytes = io
                .read(&spath)
                .map_err(|e| corrupt(format!("reading {}: {e}", spath.display())))?
                .ok_or_else(|| {
                    corrupt(format!("referenced segment {} missing", spath.display()))
                })?;
            let seg = decode_segment(&sbytes)?;
            if seg.name != mc.name
                || seg.id != sref.id
                || seg.d != mc.d
                || seg.metric != mc.metric
                || seg.r.len() != sref.rows
                || seg.bits != sref.bits
            {
                return Err(corrupt(format!(
                    "segment {} disagrees with its manifest entry",
                    spath.display()
                )));
            }
            // a file written before a rebalance holds codes at a stale
            // width — requantize from the residual store (deterministic
            // and lossless-from-exact, so the result is bit-identical
            // to a fresh encode at the current width)
            let (codes, r) = if seg.bits == mc.bits {
                (seg.codes, seg.r)
            } else {
                super::quantize_rows(&rot, mc.d, &seg.exact, mc.bits)
            };
            sealed.push(SegmentData { id: sref.id, disk_bits: sref.bits, codes, r, exact: seg.exact });
        }
        let c = Collection {
            name: mc.name.clone(),
            d: mc.d,
            bits: mc.bits,
            metric: mc.metric,
            rot,
            sealed,
            codes: Vec::new(),
            r: Vec::new(),
            exact: Vec::new(),
        };
        collections.insert(mc.name.clone(), c);
    }
    let store = VectorStore { cfg: cfg.clone(), collections, rows_at_solve: m.rows_at_solve };
    Ok((store, m))
}

/// The durability engine a durable [`DurableStore`] carries, behind a
/// `Mutex` that serializes writers (adds, seals, compactions) without
/// ever blocking readers.
pub(super) struct Engine {
    pub(super) io: Box<dyn Io>,
    pub(super) data_dir: PathBuf,
    pub(super) fsync: FsyncPolicy,
    pub(super) snapshot_every: usize,
    pub(super) segment_rows: usize,
    pub(super) next_seq: u64,
    pub(super) next_seg_id: u64,
    pub(super) next_gen: u64,
    /// The last committed manifest generation — kept on disk as the
    /// fallback against a latent bad write of its successor.
    pub(super) prev_good_gen: Option<u64>,
    /// Acknowledged rows since the last committed seal (the
    /// `snapshot_every` cadence counter).
    pub(super) rows_since_seal: usize,
    pub(super) report: RecoveryReport,
    /// Set when a WAL append failed *and* the reseal also failed: the
    /// store can no longer honor WAL-before-ack, so adds are refused
    /// ([`IndexError::ReadOnly`]) until restart.
    pub(super) read_only: bool,
}

/// A [`VectorStore`] with optional crash-safety, internally
/// synchronized (see the module docs' *Locking* section). All methods
/// take `&self`; the serving layer shares it behind an `Arc`.
pub struct DurableStore {
    pub(super) store: RwLock<VectorStore>,
    pub(super) engine: Option<Mutex<Engine>>,
    /// Completed compaction passes (see [`DurableStore::compact_now`]).
    pub(super) compactions: AtomicUsize,
}

impl DurableStore {
    /// In-memory only store — restart loses everything (the PR-5
    /// behavior, still the default without `--data-dir`).
    pub fn ephemeral(cfg: IndexConfig) -> Result<DurableStore, IndexError> {
        Ok(DurableStore {
            store: RwLock::new(VectorStore::new(cfg)?),
            engine: None,
            compactions: AtomicUsize::new(0),
        })
    }

    /// Open (or create) a durable store at `dcfg.data_dir` on the real
    /// filesystem: recover whatever the directory holds, then log every
    /// subsequent add.
    pub fn open(cfg: IndexConfig, dcfg: DurabilityConfig) -> Result<DurableStore, IndexError> {
        DurableStore::open_with(cfg, dcfg, Box::new(StdIo))
    }

    /// [`DurableStore::open`] over an explicit [`Io`] — the seam the
    /// fault-injection walls use ([`super::io::MemIo`] /
    /// [`super::io::FaultIo`]).
    pub fn open_with(
        cfg: IndexConfig,
        dcfg: DurabilityConfig,
        mut io: Box<dyn Io>,
    ) -> Result<DurableStore, IndexError> {
        let rec = recover(io.as_mut(), &dcfg.data_dir, cfg)?;
        let damaged = rec.report.dropped_records > 0
            || rec.report.duplicate_records > 0
            || rec.report.corrupt_snapshots > 0;
        let opened = DurableStore {
            store: RwLock::new(rec.store),
            engine: Some(Mutex::new(Engine {
                io,
                data_dir: dcfg.data_dir,
                fsync: dcfg.fsync,
                snapshot_every: dcfg.snapshot_every,
                segment_rows: dcfg.segment_rows,
                next_seq: rec.next_seq,
                next_seg_id: rec.next_seg_id,
                next_gen: rec.next_gen,
                prev_good_gen: rec.loaded_gen,
                rows_since_seal: 0,
                report: rec.report,
                read_only: false,
            })),
            compactions: AtomicUsize::new(0),
        };
        // Reseal before accepting writes whenever recovery found damage:
        // a torn/corrupt WAL tail would swallow every record appended
        // after it (stop-at-first-corruption), and records dropped
        // beyond a sequence gap would collide with the reused sequence
        // numbers of new acks. One seal checkpoints the recovered state
        // and deletes all of it. Failing the reseal fails the open —
        // accepting writes over known-damaged logs is the one thing the
        // durability contract cannot do.
        if damaged {
            opened.seal_now()?;
        }
        Ok(opened)
    }

    /// Read access to the underlying store (queries, stats, tests).
    /// The guard holds a read lock — writers wait while it lives, so
    /// callers should keep it brief.
    pub fn store(&self) -> RwLockReadGuard<'_, VectorStore> {
        self.store.read().expect("index store lock poisoned")
    }

    /// True when adds are logged to disk.
    pub fn is_durable(&self) -> bool {
        self.engine.is_some()
    }

    /// True when a durability failure flipped the store read-only
    /// (a WAL append and its reseal both failed): adds are refused
    /// until restart; reads keep working. Always `false` for ephemeral
    /// stores.
    pub fn is_read_only(&self) -> bool {
        self.engine
            .as_ref()
            .is_some_and(|m| m.lock().expect("index engine lock poisoned").read_only)
    }

    /// The recovery outcome of [`DurableStore::open`]; `None` for
    /// ephemeral stores (the stats endpoint omits the fields).
    pub fn recovery(&self) -> Option<RecoveryReport> {
        self.engine
            .as_ref()
            .map(|m| m.lock().expect("index engine lock poisoned").report)
    }

    /// Next store-global WAL sequence number (tests pin the cadence).
    pub fn next_seq(&self) -> u64 {
        self.engine
            .as_ref()
            .map_or(0, |m| m.lock().expect("index engine lock poisoned").next_seq)
    }

    /// Completed compaction passes since open (`/v1/stats`).
    pub fn compactions(&self) -> usize {
        self.compactions.load(Ordering::Relaxed)
    }

    /// Durable add: apply in memory, then append one WAL record, then
    /// acknowledge (see module docs for the ordering argument). The
    /// in-memory apply alone decides admission — a refused add writes
    /// nothing. A WAL append failure consumed a sequence number without
    /// a record — a gap that would void every later ack at recovery —
    /// so the engine immediately reseals: on success the add is durable
    /// (sealed, not logged) and acknowledged normally; if the seal also
    /// fails the store flips read-only and the add returns
    /// [`IndexError::ReadOnly`] (the rows stay in memory but are not
    /// durable, and no later add will be accepted that recovery would
    /// silently drop). A failed *cadence* seal is non-fatal: the add is
    /// already durable in the WAL, so the seal is simply retried on the
    /// next add. The store lock is held only while applying rows in
    /// memory — never across I/O — so queries proceed during appends
    /// and seals.
    pub fn add(
        &self,
        name: &str,
        vecs: &[f32],
        d: usize,
        threads: usize,
    ) -> Result<(usize, usize), IndexError> {
        self.add_with(name, vecs, d, threads, None)
    }

    /// [`DurableStore::add`] guarded by an expected first row id (see
    /// [`VectorStore::add_expect`]): refuses with
    /// [`IndexError::Conflict`] — before any WAL write — when the
    /// collection's row count moved. The position check runs inside the
    /// same store-write critical section as the add (and, on durable
    /// stores, under the engine lock that serializes acks), so the
    /// guard cannot race a concurrent add.
    pub fn add_expect(
        &self,
        name: &str,
        vecs: &[f32],
        d: usize,
        threads: usize,
        expect_first_id: usize,
    ) -> Result<(usize, usize), IndexError> {
        self.add_with(name, vecs, d, threads, Some(expect_first_id))
    }

    fn add_with(
        &self,
        name: &str,
        vecs: &[f32],
        d: usize,
        threads: usize,
        expect_first_id: Option<usize>,
    ) -> Result<(usize, usize), IndexError> {
        let apply = |store: &mut VectorStore| match expect_first_id {
            Some(e) => store.add_expect(name, vecs, d, threads, e),
            None => store.add(name, vecs, d, threads),
        };
        let Some(engine_mx) = &self.engine else {
            return apply(&mut self.store.write().expect("index store lock poisoned"));
        };
        let mut engine = engine_mx.lock().expect("index engine lock poisoned");
        if engine.read_only {
            return Err(IndexError::ReadOnly(
                "a WAL append and its reseal both failed; \
                 the store is read-only until restart"
                    .into(),
            ));
        }
        let out = apply(&mut self.store.write().expect("index store lock poisoned"))?;
        let rec = WalRecord {
            seq: engine.next_seq,
            name: name.to_string(),
            dim: d,
            rows: vecs.to_vec(),
        };
        let bytes = encode_record(&rec)?;
        engine.next_seq += 1;
        engine.rows_since_seal += out.1;
        let path = wal_path(&engine.data_dir, name);
        let fsync = engine.fsync == FsyncPolicy::Always;
        let t0 = obs::trace::tracer().now_us();
        let append_result = engine
            .io
            .append(&path, &bytes, fsync)
            .map_err(|e| format!("WAL append to {}: {e}", path.display()));
        let dur = obs::trace::tracer().now_us().saturating_sub(t0);
        obs::metrics().wal_append_us.observe_us(dur);
        obs::trace::record_ambient("wal_append", t0, dur, bytes.len() as i64);
        if let Err(append_err) = append_result {
            return match self.seal_locked(&mut engine) {
                // the reseal covered the consumed seq (and these rows):
                // the add is durable, ack it
                Ok(()) => Ok(out),
                Err(seal_err) => {
                    engine.read_only = true;
                    Err(IndexError::ReadOnly(format!(
                        "{append_err}; reseal also failed ({seal_err}); \
                         rows applied in memory but NOT durable; \
                         the store is read-only until restart"
                    )))
                }
            };
        }
        let head_full = engine.segment_rows > 0
            && self
                .store
                .read()
                .expect("index store lock poisoned")
                .collections
                .values()
                .any(|c| c.head_rows() >= engine.segment_rows);
        let cadence_due =
            engine.snapshot_every > 0 && engine.rows_since_seal >= engine.snapshot_every;
        if cadence_due || head_full {
            // non-fatal: the add is durable in the WAL either way, and a
            // failed seal left the WAL in place (deletion happens only
            // after the manifest commit), so the next add retries
            if let Err(e) = self.seal_locked(&mut engine) {
                crate::info!("index seal failed (will retry next add): {e}");
            }
        }
        Ok(out)
    }

    /// Seal every non-empty head into an immutable segment and commit a
    /// new manifest generation; then delete the WAL files it subsumes
    /// and prune stale generations. No-op heads still commit a manifest
    /// (recovery needs the current `next_seq`). No-op on ephemeral
    /// stores.
    pub fn seal_now(&self) -> Result<(), IndexError> {
        let Some(engine_mx) = &self.engine else {
            return Ok(());
        };
        let mut engine = engine_mx.lock().expect("index engine lock poisoned");
        self.seal_locked(&mut engine)
    }

    /// The seal itself, with the engine already locked. Three phases:
    /// plan under a store *read* lock (capture which heads to seal and
    /// encode their bytes), write segment files then the manifest with
    /// **no store lock held** (the manifest write is the commit point —
    /// failure before it leaves the previous generation and every WAL
    /// intact), then move the sealed heads in memory under a brief
    /// store write lock.
    pub(super) fn seal_locked(&self, engine: &mut Engine) -> Result<(), IndexError> {
        let t0 = obs::trace::tracer().now_us();
        let out = self.seal_inner(engine);
        let dur = obs::trace::tracer().now_us().saturating_sub(t0);
        obs::metrics().wal_seal_us.observe_us(dur);
        obs::trace::record_ambient("wal_seal", t0, dur, if out.is_ok() { 0 } else { -1 });
        out
    }

    fn seal_inner(&self, engine: &mut Engine) -> Result<(), IndexError> {
        let (writes, manifest_bytes, gen, seals, new_next_id) = {
            let store = self.store.read().expect("index store lock poisoned");
            let mut next_id = engine.next_seg_id;
            let mut writes: Vec<(PathBuf, Vec<u8>)> = Vec::new();
            let mut seals: Vec<(String, u64)> = Vec::new();
            let mut mcols: Vec<ManifestCollection> = Vec::new();
            for (name, c) in &store.collections {
                let mut segs: Vec<ManifestSegment> = c
                    .sealed
                    .iter()
                    .map(|s| ManifestSegment { id: s.id, rows: s.rows(), bits: s.disk_bits })
                    .collect();
                if !c.r.is_empty() {
                    let id = next_id;
                    next_id += 1;
                    let bytes = encode_segment(
                        name, c.d, c.bits, c.metric, id, &c.codes, &c.r, &c.exact,
                    );
                    writes.push((segment_path(&engine.data_dir, name, id), bytes));
                    segs.push(ManifestSegment { id, rows: c.r.len(), bits: c.bits });
                    seals.push((name.clone(), id));
                }
                mcols.push(ManifestCollection {
                    name: name.clone(),
                    d: c.d,
                    bits: c.bits,
                    metric: c.metric,
                    signs1: c.rot.signs1.clone(),
                    signs2: c.rot.signs2.clone(),
                    segments: segs,
                });
            }
            let gen = engine.next_gen;
            let m = StoreManifest {
                gen,
                next_seq: engine.next_seq,
                next_seg_id: next_id,
                rows_at_solve: store.rows_at_solve,
                collections: mcols,
            };
            (writes, encode_manifest(&m), gen, seals, next_id)
        };
        for (path, bytes) in &writes {
            engine
                .io
                .write_atomic(path, bytes, true)
                .map_err(|e| IndexError::Io(format!("writing {}: {e}", path.display())))?;
        }
        let mpath = manifest_path(&engine.data_dir, gen);
        engine
            .io
            .write_atomic(&mpath, &manifest_bytes, true)
            .map_err(|e| IndexError::Io(format!("writing {}: {e}", mpath.display())))?;
        // committed: everything below is cleanup of now-superseded state
        engine.next_gen = gen + 1;
        engine.next_seg_id = new_next_id;
        engine.rows_since_seal = 0;
        // the manifest covers every logged record: drop the WALs
        let wal_dir = engine.data_dir.join(WAL_DIR);
        for name in engine
            .io
            .list(&wal_dir)
            .map_err(|e| IndexError::Io(format!("listing {}: {e}", wal_dir.display())))?
        {
            if name.ends_with(".wal") {
                let p = wal_dir.join(&name);
                engine
                    .io
                    .remove(&p)
                    .map_err(|e| IndexError::Io(format!("removing {}: {e}", p.display())))?;
            }
        }
        let prev = engine.prev_good_gen.replace(gen);
        prune_files(engine, gen, prev)?;
        if !seals.is_empty() {
            let mut store = self.store.write().expect("index store lock poisoned");
            for (name, id) in &seals {
                if let Some(c) = store.collections.get_mut(name) {
                    c.seal_head(*id);
                }
            }
        }
        Ok(())
    }

    /// Pass-through query (see [`VectorStore::query`]); takes only a
    /// store read lock, so queries run concurrently with each other and
    /// with seal/compaction I/O.
    pub fn query(
        &self,
        name: &str,
        q: &[f32],
        k: usize,
        rerank_factor: usize,
        threads: usize,
    ) -> Result<Vec<SearchHit>, IndexError> {
        self.store
            .read()
            .expect("index store lock poisoned")
            .query(name, q, k, rerank_factor, threads)
    }

    /// Phase-1 shard scan (see [`VectorStore::scan_candidates`]); store
    /// read lock only, like [`DurableStore::query`].
    pub fn scan_candidates(
        &self,
        name: &str,
        q: &[f32],
        take: usize,
        threads: usize,
    ) -> Result<(usize, Vec<SearchHit>), IndexError> {
        self.store
            .read()
            .expect("index store lock poisoned")
            .scan_candidates(name, q, take, threads)
    }

    /// Phase-2 shard rerank (see [`VectorStore::exact_scores`]); store
    /// read lock only.
    pub fn exact_scores(
        &self,
        name: &str,
        q: &[f32],
        ids: &[usize],
    ) -> Result<Vec<SearchHit>, IndexError> {
        self.store
            .read()
            .expect("index store lock poisoned")
            .exact_scores(name, q, ids)
    }

    /// Hand back the inner [`Io`] (tests recover from what survived a
    /// faulted run). Ephemeral stores return `None`.
    pub fn into_io(self) -> Option<Box<dyn Io>> {
        self.engine
            .map(|m| m.into_inner().expect("index engine lock poisoned").io)
    }
}

/// Delete every manifest other than the `keep` / `keep_prev`
/// generations and every segment file no kept manifest references. A
/// kept generation that no longer decodes from disk (a mangled write
/// the CRC catches) is deleted too — it could only shadow its good
/// predecessor at recovery.
pub(super) fn prune_files(
    engine: &mut Engine,
    keep: u64,
    keep_prev: Option<u64>,
) -> Result<(), IndexError> {
    let mut referenced: BTreeSet<(String, u64)> = BTreeSet::new();
    let mut kept: Vec<u64> = Vec::new();
    for gen in [Some(keep), keep_prev].into_iter().flatten() {
        let path = manifest_path(&engine.data_dir, gen);
        let decodable = engine
            .io
            .read(&path)
            .ok()
            .flatten()
            .and_then(|b| decode_manifest(&b).ok());
        if let Some(m) = decodable {
            kept.push(gen);
            for c in &m.collections {
                for s in &c.segments {
                    referenced.insert((c.name.clone(), s.id));
                }
            }
        }
    }
    for gen in list_manifests(engine.io.as_mut(), &engine.data_dir)? {
        if !kept.contains(&gen) {
            let p = manifest_path(&engine.data_dir, gen);
            engine
                .io
                .remove(&p)
                .map_err(|e| IndexError::Io(format!("removing {}: {e}", p.display())))?;
        }
    }
    let seg_dir = engine.data_dir.join(SEGMENT_DIR);
    for file in engine
        .io
        .list(&seg_dir)
        .map_err(|e| IndexError::Io(format!("listing {}: {e}", seg_dir.display())))?
    {
        let live = parse_segment_file(&file)
            .is_some_and(|(name, id)| referenced.contains(&(name, id)));
        if !live {
            let p = seg_dir.join(&file);
            engine
                .io
                .remove(&p)
                .map_err(|e| IndexError::Io(format!("removing {}: {e}", p.display())))?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::io::{Fault, FaultIo, MemIo};
    use super::super::snapshot::encode_snapshot;
    use super::*;
    use crate::index::{IndexPolicy, Metric};
    use crate::rng::Rng;

    fn cfg() -> IndexConfig {
        IndexConfig { policy: IndexPolicy::Uniform(6), ..Default::default() }
    }

    fn dcfg(snapshot_every: usize) -> DurabilityConfig {
        DurabilityConfig {
            data_dir: PathBuf::from("/idx"),
            fsync: FsyncPolicy::Never,
            snapshot_every,
            segment_rows: 0,
        }
    }

    /// Byte equality of the canonical flattened encoding: identical
    /// codes, rescales, residuals, and bit plan regardless of how the
    /// rows are split between sealed segments and heads.
    fn assert_bit_identical(a: &VectorStore, b: &VectorStore) {
        assert_eq!(encode_snapshot(a, 0), encode_snapshot(b, 0), "stores differ bit-for-bit");
    }

    #[test]
    fn restart_recovers_wal_only_store_bit_for_bit() {
        let d = 16usize;
        let durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(MemIo::new())).unwrap();
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for seed in 0..5u64 {
            let v = Rng::new(seed).gaussian_vec(3 * d);
            durable.add("docs", &v, d, 1).unwrap();
            fresh.add("docs", &v, d, 1).unwrap();
        }
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.recovered_rows(), 15);
        assert_eq!(rep.dropped_records, 0);
        assert_eq!(reopened.next_seq(), 5);
        assert_bit_identical(&reopened.store(), &fresh);
    }

    #[test]
    fn snapshot_seals_wal_and_recovery_prefers_it() {
        let d = 8usize;
        let durable = DurableStore::open_with(cfg(), dcfg(2), Box::new(MemIo::new())).unwrap();
        for seed in 0..5u64 {
            durable.add("a", &Rng::new(seed).gaussian_vec(d), d, 1).unwrap();
        }
        // snapshot_every=2 rows, 1-row adds: seals after adds 2 and 4;
        // one record (seq 4) still in the WAL
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(2), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.snapshot_rows, 4);
        assert_eq!(rep.replayed_rows, 1);
        assert_eq!(rep.duplicate_records, 0);
        assert_eq!(reopened.next_seq(), 5);
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for seed in 0..5u64 {
            fresh.add("a", &Rng::new(seed).gaussian_vec(d), d, 1).unwrap();
        }
        assert_bit_identical(&reopened.store(), &fresh);
    }

    #[test]
    fn seal_cadence_counts_rows_not_records() {
        let d = 8usize;
        // snapshot_every = 8 ROWS: one 10-row add crosses the cadence
        // by itself (the old record-counting cadence would have waited
        // for 8 records — unbounded replay debt from bulk adds)
        let durable = DurableStore::open_with(cfg(), dcfg(8), Box::new(MemIo::new())).unwrap();
        durable.add("a", &Rng::new(1).gaussian_vec(10 * d), d, 1).unwrap();
        {
            let s = durable.store();
            assert_eq!(s.head_rows(), 0, "a 10-row add must seal immediately");
            assert_eq!(s.segments(), 1);
        }
        // 1-row adds: rows == records, so the cadence fires on the 8th
        for seed in 0..7u64 {
            durable.add("a", &Rng::new(10 + seed).gaussian_vec(d), d, 1).unwrap();
        }
        assert_eq!(durable.store().head_rows(), 7, "7 rows since the seal: not yet");
        durable.add("a", &Rng::new(99).gaussian_vec(d), d, 1).unwrap();
        {
            let s = durable.store();
            assert_eq!(s.head_rows(), 0, "8th row fires the cadence");
            assert_eq!(s.segments(), 2);
        }
    }

    #[test]
    fn full_head_forces_a_seal_when_segment_rows_set() {
        let d = 8usize;
        let dc = DurabilityConfig {
            data_dir: PathBuf::from("/idx"),
            fsync: FsyncPolicy::Never,
            snapshot_every: 0,
            segment_rows: 4,
        };
        let durable = DurableStore::open_with(cfg(), dc, Box::new(MemIo::new())).unwrap();
        durable.add("a", &Rng::new(1).gaussian_vec(3 * d), d, 1).unwrap();
        assert_eq!(durable.store().head_rows(), 3, "3 < 4: head stays");
        durable.add("a", &Rng::new(2).gaussian_vec(d), d, 1).unwrap();
        {
            let s = durable.store();
            assert_eq!(s.head_rows(), 0, "head reached segment_rows: sealed");
            assert_eq!(s.segments(), 1);
        }
    }

    #[test]
    fn duplicate_wal_records_replay_idempotently() {
        // write a manifest *without* clearing the WAL by re-appending a
        // sealed record manually: recovery must skip it
        let d = 8usize;
        let durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(MemIo::new())).unwrap();
        let v = Rng::new(9).gaussian_vec(d);
        durable.add("a", &v, d, 1).unwrap();
        durable.seal_now().unwrap();
        let mut io = durable.into_io().unwrap();
        let stale = encode_record(&WalRecord {
            seq: 0,
            name: "a".into(),
            dim: d,
            rows: v.clone(),
        })
        .unwrap();
        io.append(&wal_path(Path::new("/idx"), "a"), &stale, false).unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.duplicate_records, 1);
        assert_eq!(rep.replayed_rows, 0);
        assert_eq!(reopened.store().rows(), 1, "no double-apply");
    }

    #[test]
    fn seq_gap_stops_replay_and_counts_drops() {
        let d = 4usize;
        let mut io = MemIo::new();
        let mk = |seq: u64| {
            encode_record(&WalRecord {
                seq,
                name: "g".into(),
                dim: d,
                rows: vec![seq as f32; d],
            })
            .unwrap()
        };
        let p = wal_path(Path::new("/idx"), "g");
        io.append(&p, &mk(0), false).unwrap();
        io.append(&p, &mk(1), false).unwrap();
        io.append(&p, &mk(3), false).unwrap(); // 2 lost elsewhere
        io.append(&p, &mk(4), false).unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), Box::new(io)).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.replayed_rows, 2, "seq 0 and 1 only");
        assert_eq!(rep.dropped_records, 2, "seq 3 and 4 are beyond the gap");
        assert_eq!(reopened.next_seq(), 2);
    }

    #[test]
    fn interleaved_collections_recover_in_global_order() {
        // two collections, alternating adds: per-collection WALs must
        // merge back to the original global order
        let d = 8usize;
        let durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(MemIo::new())).unwrap();
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for seed in 0..6u64 {
            let name = if seed % 2 == 0 { "even" } else { "odd" };
            let v = Rng::new(seed).gaussian_vec(2 * d);
            durable.add(name, &v, d, 1).unwrap();
            fresh.add(name, &v, d, 1).unwrap();
        }
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        assert_bit_identical(&reopened.store(), &fresh);
        assert_eq!(reopened.next_seq(), 6);
    }

    #[test]
    fn refused_adds_write_nothing() {
        let d = 8usize;
        let durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(MemIo::new())).unwrap();
        assert!(durable.add("bad name!", &vec![0.0; d], d, 1).is_err());
        assert_eq!(durable.next_seq(), 0, "refused add must not consume a seq");
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        assert_eq!(reopened.store().rows(), 0);
        assert_eq!(reopened.recovery().unwrap(), RecoveryReport::default());
    }

    #[test]
    fn torn_tail_is_resealed_so_a_second_crash_loses_nothing() {
        // the double-crash shape from the review: a torn tail must not
        // leave corrupt bytes that swallow post-restart appends
        let d = 8usize;
        let v0 = Rng::new(20).gaussian_vec(d);
        let v1 = Rng::new(21).gaussian_vec(d);
        let mut io = MemIo::new();
        let p = wal_path(Path::new("/idx"), "a");
        io.append(&p, &encode_record(&WalRecord { seq: 0, name: "a".into(), dim: d, rows: v0.clone() }).unwrap(), false)
            .unwrap();
        let torn = encode_record(&WalRecord { seq: 1, name: "a".into(), dim: d, rows: v1.clone() }).unwrap();
        io.append(&p, &torn[..torn.len() / 2], false).unwrap();
        // first restart: recovery drops the torn tail and reseals
        let durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(io)).unwrap();
        assert_eq!(durable.recovery().unwrap().dropped_records, 1);
        // post-restart acks land after the reseal, not after torn bytes
        let v2 = Rng::new(22).gaussian_vec(d);
        let v3 = Rng::new(23).gaussian_vec(d);
        durable.add("a", &v2, d, 1).unwrap();
        durable.add("a", &v3, d, 1).unwrap();
        // second crash: every ack since the first restart must survive
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.dropped_records, 0, "second recovery must be clean");
        assert_eq!(rep.recovered_rows(), 3);
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for v in [&v0, &v2, &v3] {
            fresh.add("a", v, d, 1).unwrap();
        }
        assert_bit_identical(&reopened.store(), &fresh);
    }

    #[test]
    fn gap_reseal_prevents_stale_records_shadowing_reused_seqs() {
        // review scenario: post-gap records left on disk could replay
        // under a reused seq instead of the newly acknowledged record —
        // the reseal must delete them
        let d = 4usize;
        let mut io = MemIo::new();
        let rec = |seq: u64, name: &str, fill: f32| {
            encode_record(&WalRecord { seq, name: name.into(), dim: d, rows: vec![fill; d] })
                .unwrap()
        };
        io.append(&wal_path(Path::new("/idx"), "a"), &rec(0, "a", 1.0), false).unwrap();
        // seq 1 lost (gap); seq 2 survives in another, clean WAL file
        io.append(&wal_path(Path::new("/idx"), "stale"), &rec(2, "stale", 9.0), false).unwrap();
        let durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(io)).unwrap();
        assert_eq!(durable.recovery().unwrap().dropped_records, 1);
        assert_eq!(durable.next_seq(), 1, "resumes at the gap");
        // new acks reuse seqs 1 and 2; the stale seq-2 record must not
        // resurrect at the next recovery
        let v1 = Rng::new(31).gaussian_vec(d);
        let v2 = Rng::new(32).gaussian_vec(d);
        durable.add("a", &v1, d, 1).unwrap();
        durable.add("a", &v2, d, 1).unwrap();
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.dropped_records, 0);
        assert!(
            !reopened.store().collections.contains_key("stale"),
            "the dropped post-gap record must not reappear"
        );
        let mut fresh = VectorStore::new(cfg()).unwrap();
        fresh.add("a", &vec![1.0; d], d, 1).unwrap();
        fresh.add("a", &v1, d, 1).unwrap();
        fresh.add("a", &v2, d, 1).unwrap();
        assert_bit_identical(&reopened.store(), &fresh);
    }

    #[test]
    fn failed_append_reseals_into_a_segment_and_still_acks() {
        // one transient append failure (review: a brief ENOSPC) must not
        // void later acks via a permanent sequence gap
        let d = 8usize;
        let io = FaultIo::new(MemIo::new(), Fault::FailWrite { nth: 3 });
        let durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(io)).unwrap();
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for seed in 0..4u64 {
            let v = Rng::new(40 + seed).gaussian_vec(d);
            // add 3's append fails and is resealed into a segment — the
            // add is durable either way, so every add must ack
            durable.add("a", &v, d, 1).unwrap();
            fresh.add("a", &v, d, 1).unwrap();
        }
        assert!(!durable.is_read_only());
        assert_eq!(durable.next_seq(), 4);
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.dropped_records, 0, "no gap: the reseal covered the consumed seq");
        assert_eq!(rep.recovered_rows(), 4);
        assert_bit_identical(&reopened.store(), &fresh);
    }

    #[test]
    fn persistent_write_failure_flips_read_only_and_refuses_retries() {
        let d = 8usize;
        // write 1 (add 1's append) succeeds; everything after fails —
        // add 2's append fails AND its reseal fails
        let io = FaultIo::new(MemIo::new(), Fault::FailWritesFrom { nth: 2 });
        let durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(io)).unwrap();
        let v0 = Rng::new(50).gaussian_vec(d);
        durable.add("a", &v0, d, 1).unwrap();
        let err = durable.add("a", &Rng::new(51).gaussian_vec(d), d, 1).unwrap_err();
        assert!(matches!(err, IndexError::ReadOnly(_)), "got {err}");
        assert!(durable.is_read_only());
        // a client retry is refused before touching the store — no
        // duplicate rows, no ack that recovery would void
        let rows_before = durable.store().rows();
        let err = durable.add("a", &Rng::new(51).gaussian_vec(d), d, 1).unwrap_err();
        assert!(matches!(err, IndexError::ReadOnly(_)));
        assert_eq!(durable.store().rows(), rows_before, "refused before apply");
        // reads keep working
        assert_eq!(durable.query("a", &v0, 1, 4, 1).unwrap().len(), 1);
        // recovery sees exactly the durable prefix (add 1)
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        assert_eq!(reopened.recovery().unwrap().recovered_rows(), 1);
        let mut fresh = VectorStore::new(cfg()).unwrap();
        fresh.add("a", &v0, d, 1).unwrap();
        assert_bit_identical(&reopened.store(), &fresh);
    }

    #[test]
    fn stale_width_segments_requantize_at_recovery() {
        // Budget policy: seal at the initial (rich) width, keep adding
        // until the solver shrinks the collection, seal again — the
        // manifest now lists the old segment at its stale on-disk width.
        // Recovery must requantize those rows from the residual store
        // and land bit-identical to a never-sealed, never-crashed build.
        let d = 16usize;
        let bcfg = IndexConfig {
            policy: IndexPolicy::Budget { bit_choices: vec![2, 4, 8] },
            budget_bytes: 600,
            metric: Metric::InnerProduct,
            ..Default::default()
        };
        let durable =
            DurableStore::open_with(bcfg.clone(), dcfg(0), Box::new(MemIo::new())).unwrap();
        let mut fresh = VectorStore::new(bcfg.clone()).unwrap();
        let batch = |seed: u64| Rng::new(seed).gaussian_vec(10 * d);
        durable.add("a", &batch(0), d, 1).unwrap();
        fresh.add("a", &batch(0), d, 1).unwrap();
        durable.seal_now().unwrap(); // segment written at the rich width
        for seed in 1..5u64 {
            durable.add("a", &batch(seed), d, 1).unwrap();
            fresh.add("a", &batch(seed), d, 1).unwrap();
        }
        // 50 rows at 8 bits need 1000 B > 600: the solver must have
        // narrowed the collection below its sealed width
        assert!(durable.store().get("a").unwrap().bits() < 8);
        durable.seal_now().unwrap();
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(bcfg, dcfg(0), io).unwrap();
        let s = reopened.store();
        let c = s.get("a").unwrap();
        assert!(
            c.segments().iter().any(|seg| seg.disk_bits != c.bits()),
            "the stale-width requantize path must actually be exercised"
        );
        assert_bit_identical(&s, &fresh);
    }

    #[test]
    fn ephemeral_store_has_no_engine() {
        let s = DurableStore::ephemeral(cfg()).unwrap();
        s.add("a", &vec![1.0; 8], 8, 1).unwrap();
        assert!(!s.is_durable());
        assert!(s.recovery().is_none());
        s.seal_now().unwrap(); // no-op, not an error
        assert_eq!(s.compactions(), 0);
        assert!(s.into_io().is_none());
    }
}
