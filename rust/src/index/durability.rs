//! Durability orchestration: WAL-before-ack writes, periodic
//! snapshots, and crash recovery for a [`VectorStore`].
//!
//! [`DurableStore`] wraps a store with an optional durability engine.
//! Without one (`DurableStore::ephemeral`) it is a zero-cost
//! pass-through — the serving layer holds one type either way. With one
//! ([`DurableStore::open`]):
//!
//! * **Write path** — an `add` first applies to the in-memory store
//!   (so admission failures, bad names, and budget refusals never
//!   reach the log), then appends one WAL record stamped with the next
//!   store-global sequence number, then acknowledges. Under
//!   [`FsyncPolicy::Always`] the append is flushed before the ack.
//!   A **failed append** consumed a sequence number without logging a
//!   record — left alone that gap would make recovery drop every later
//!   acked record — so the engine immediately reseals by snapshot: if
//!   the snapshot lands, the rows are durable and the add is
//!   acknowledged normally; if it also fails, the store flips
//!   **read-only** ([`IndexError::ReadOnly`], HTTP 503) so no further
//!   ack can be issued that recovery would silently void, and a client
//!   retry is refused rather than applied twice.
//! * **Snapshot path** — after every `snapshot_every` acknowledged
//!   records (and on [`DurableStore::snapshot_now`]) the whole store is
//!   serialized to a versioned segment file (atomic temp + fsync +
//!   rename), the WAL files are deleted (their records are sealed into
//!   the snapshot), and older snapshots beyond one spare are pruned.
//! * **Recovery** ([`recover`]) — load the newest decodable snapshot
//!   (corrupt ones are skipped, older ones tried), parse every WAL
//!   file stop-at-first-corruption, merge the surviving records by
//!   global sequence number, and replay the contiguous run starting at
//!   the snapshot's `next_seq` through the normal `add` path. Records
//!   already sealed in the snapshot (seq below `next_seq`) are skipped
//!   — replay is idempotent; records after a sequence gap are dropped
//!   — a lost record invalidates everything that depended on coming
//!   after it. The outcome is surfaced as [`RecoveryReport`]
//!   (`/v1/stats` reports `recovered_rows` / `dropped_records`).
//!   When recovery dropped, skipped, or rejected *anything* (torn
//!   tail, checksum failure, sequence gap, stale duplicate, corrupt
//!   snapshot), the damaged bytes are still on disk — appending after
//!   a corrupt tail would make every new record unreadable at the next
//!   recovery, and reusing post-gap sequence numbers could resurrect
//!   stale records over acknowledged ones. So [`DurableStore::open_with`]
//!   **reseals before accepting writes**: one immediate snapshot seals
//!   the recovered state, deletes every WAL file (corrupt tails and
//!   stale records included), and prunes undecodable snapshots. A
//!   second crash right after restart therefore recovers cleanly.
//!
//! Because replay re-runs the deterministic quantization pipeline and
//! snapshots store the exact in-memory layout, a recovered store equals
//! a never-crashed store **bit-for-bit** (codes, rescales, residuals,
//! bit plan) up to the last durable record — the property the
//! fault-injection wall in `rust/tests/durability.rs` asserts for every
//! fault the [`super::io::FaultIo`] shim can inject.

use super::io::{Io, StdIo};
use super::snapshot::{
    decode_snapshot, encode_snapshot, list_snapshots, snapshot_path,
};
use super::wal::{decode_records, encode_record, wal_path, WalRecord, WalTail, WAL_DIR};
use super::{IndexConfig, IndexError, SearchHit, VectorStore};
use std::path::{Path, PathBuf};

/// When WAL appends are flushed to stable storage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync every WAL append before acknowledging — an acked add
    /// survives power loss, at one disk flush per add.
    Always,
    /// Leave flushing to the OS page cache — an acked add survives
    /// process death but a power cut may tear the tail (which recovery
    /// tolerates by design).
    Never,
}

/// Durability configuration for [`DurableStore::open`].
#[derive(Clone, Debug)]
pub struct DurabilityConfig {
    /// Directory holding `wal/` and the snapshot segments.
    pub data_dir: PathBuf,
    /// WAL flush policy.
    pub fsync: FsyncPolicy,
    /// Acknowledged records between automatic snapshots; `0` disables
    /// automatic snapshots (explicit [`DurableStore::snapshot_now`]
    /// only).
    pub snapshot_every: usize,
}

/// What recovery found and did, for `/v1/stats` and the test walls.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Rows restored from the snapshot.
    pub snapshot_rows: usize,
    /// Rows replayed from WAL records.
    pub replayed_rows: usize,
    /// WAL records dropped: corrupt/torn tails (one per damaged file)
    /// plus whole records lost to a sequence gap.
    pub dropped_records: usize,
    /// WAL records skipped because the snapshot already sealed them
    /// (duplicate replay — idempotence, not loss).
    pub duplicate_records: usize,
    /// Snapshot files that failed to decode and were skipped.
    pub corrupt_snapshots: usize,
}

impl RecoveryReport {
    /// Total rows the store holds because of recovery (snapshot +
    /// replay) — the `recovered_rows` stats field.
    pub fn recovered_rows(&self) -> usize {
        self.snapshot_rows + self.replayed_rows
    }
}

/// Load the newest usable snapshot and replay the WAL tail. Never
/// fails on *corruption* (that is data, reported in the
/// [`RecoveryReport`]); fails only on genuine I/O errors or an invalid
/// `cfg`.
pub fn recover(
    io: &mut dyn Io,
    data_dir: &Path,
    cfg: IndexConfig,
) -> Result<(VectorStore, u64, RecoveryReport), IndexError> {
    let mut report = RecoveryReport::default();
    // newest decodable snapshot wins; corrupt ones are skipped
    let mut store: Option<(VectorStore, u64)> = None;
    for seq in list_snapshots(io, data_dir)? {
        let path = snapshot_path(data_dir, seq);
        let bytes = io
            .read(&path)
            .map_err(|e| IndexError::Io(format!("reading {}: {e}", path.display())))?
            .unwrap_or_default();
        match decode_snapshot(&bytes, cfg.clone()) {
            Ok(loaded) => {
                store = Some(loaded);
                break;
            }
            Err(_) => report.corrupt_snapshots += 1,
        }
    }
    let (mut store, mut next_seq) = match store {
        Some(s) => s,
        None => (VectorStore::new(cfg)?, 0),
    };
    report.snapshot_rows = store.rows();
    // parse every WAL file stop-at-first-corruption, then merge by the
    // store-global sequence number to reconstruct the original
    // interleaved add order (the Budget policy's rebalance cadence —
    // hence the final bit plan — depends on it)
    let wal_dir = data_dir.join(WAL_DIR);
    let mut records: Vec<WalRecord> = Vec::new();
    for name in io
        .list(&wal_dir)
        .map_err(|e| IndexError::Io(format!("listing {}: {e}", wal_dir.display())))?
    {
        if !name.ends_with(".wal") {
            continue;
        }
        let path = wal_dir.join(&name);
        let bytes = io
            .read(&path)
            .map_err(|e| IndexError::Io(format!("reading {}: {e}", path.display())))?
            .unwrap_or_default();
        let (recs, tail) = decode_records(&bytes);
        if tail != WalTail::Clean {
            report.dropped_records += 1;
        }
        records.extend(recs);
    }
    records.sort_by_key(|r| r.seq);
    // replay the contiguous run from next_seq; duplicates (sealed in
    // the snapshot) are skipped, anything after a gap is dropped
    for rec in records {
        if rec.seq < next_seq {
            report.duplicate_records += 1;
            continue;
        }
        if rec.seq > next_seq {
            report.dropped_records += 1;
            continue;
        }
        match store.add(&rec.name, &rec.rows, rec.dim, 0) {
            Ok((_, rows)) => report.replayed_rows += rows,
            // a record the store now refuses (e.g. budget shrank across
            // restarts) is dropped, not fatal — recovery must finish
            Err(_) => {
                report.dropped_records += 1;
                continue;
            }
        }
        next_seq = rec.seq + 1;
    }
    Ok((store, next_seq, report))
}

/// The durability engine a durable [`DurableStore`] carries.
struct Engine {
    io: Box<dyn Io>,
    data_dir: PathBuf,
    fsync: FsyncPolicy,
    snapshot_every: usize,
    next_seq: u64,
    records_since_snapshot: usize,
    report: RecoveryReport,
    /// Set when a WAL append failed *and* the reseal snapshot failed:
    /// the store can no longer honor WAL-before-ack, so adds are
    /// refused ([`IndexError::ReadOnly`]) until restart.
    read_only: bool,
}

/// A [`VectorStore`] with optional crash-safety. All read paths and
/// the non-durable constructor are zero-overhead pass-throughs, so the
/// serving layer holds one type whether or not `--data-dir` was given.
pub struct DurableStore {
    store: VectorStore,
    engine: Option<Engine>,
}

impl DurableStore {
    /// In-memory only store — restart loses everything (the PR-5
    /// behavior, still the default without `--data-dir`).
    pub fn ephemeral(cfg: IndexConfig) -> Result<DurableStore, IndexError> {
        Ok(DurableStore { store: VectorStore::new(cfg)?, engine: None })
    }

    /// Open (or create) a durable store at `dcfg.data_dir` on the real
    /// filesystem: recover whatever the directory holds, then log every
    /// subsequent add.
    pub fn open(cfg: IndexConfig, dcfg: DurabilityConfig) -> Result<DurableStore, IndexError> {
        DurableStore::open_with(cfg, dcfg, Box::new(StdIo))
    }

    /// [`DurableStore::open`] over an explicit [`Io`] — the seam the
    /// fault-injection wall uses ([`super::io::MemIo`] /
    /// [`super::io::FaultIo`]).
    pub fn open_with(
        cfg: IndexConfig,
        dcfg: DurabilityConfig,
        mut io: Box<dyn Io>,
    ) -> Result<DurableStore, IndexError> {
        let (store, next_seq, report) = recover(io.as_mut(), &dcfg.data_dir, cfg)?;
        let mut opened = DurableStore {
            store,
            engine: Some(Engine {
                io,
                data_dir: dcfg.data_dir,
                fsync: dcfg.fsync,
                snapshot_every: dcfg.snapshot_every,
                next_seq,
                records_since_snapshot: 0,
                report,
                read_only: false,
            }),
        };
        // Reseal before accepting writes whenever recovery found damage:
        // a torn/corrupt WAL tail would swallow every record appended
        // after it (stop-at-first-corruption), and records dropped
        // beyond a sequence gap would collide with the reused sequence
        // numbers of new acks. One snapshot seals the recovered state
        // and deletes all of it. Failing the reseal fails the open —
        // accepting writes over known-damaged logs is the one thing the
        // durability contract cannot do.
        let damaged = report.dropped_records > 0
            || report.duplicate_records > 0
            || report.corrupt_snapshots > 0;
        if damaged {
            opened.snapshot_now()?;
        }
        Ok(opened)
    }

    /// Borrow the underlying store (all read paths).
    pub fn store(&self) -> &VectorStore {
        &self.store
    }

    /// True when adds are logged to disk.
    pub fn is_durable(&self) -> bool {
        self.engine.is_some()
    }

    /// True when a durability failure flipped the store read-only
    /// (a WAL append and its reseal snapshot both failed): adds are
    /// refused until restart; reads keep working. Always `false` for
    /// ephemeral stores.
    pub fn is_read_only(&self) -> bool {
        self.engine.as_ref().is_some_and(|e| e.read_only)
    }

    /// The recovery outcome of [`DurableStore::open`]; `None` for
    /// ephemeral stores (the stats endpoint omits the fields).
    pub fn recovery(&self) -> Option<RecoveryReport> {
        self.engine.as_ref().map(|e| e.report)
    }

    /// Next store-global WAL sequence number (tests pin the cadence).
    pub fn next_seq(&self) -> u64 {
        self.engine.as_ref().map(|e| e.next_seq).unwrap_or(0)
    }

    /// Durable add: apply in memory, then append one WAL record, then
    /// acknowledge (see module docs for the ordering argument). The
    /// in-memory apply alone decides admission — a refused add writes
    /// nothing. A WAL append failure consumed a sequence number without
    /// a record — a gap that would void every later ack at recovery —
    /// so the engine immediately reseals by snapshot: on success the
    /// add is durable (sealed, not logged) and acknowledged normally;
    /// if the snapshot also fails the store flips read-only and the add
    /// returns [`IndexError::ReadOnly`] (the rows stay in memory but
    /// are not durable, and no later add will be accepted that recovery
    /// would silently drop). A failed *cadence* snapshot is non-fatal:
    /// the add is already durable in the WAL, so the snapshot is simply
    /// retried on the next add.
    pub fn add(
        &mut self,
        name: &str,
        vecs: &[f32],
        d: usize,
        threads: usize,
    ) -> Result<(usize, usize), IndexError> {
        if let Some(engine) = &self.engine {
            if engine.read_only {
                return Err(IndexError::ReadOnly(
                    "a WAL append and its reseal snapshot both failed; \
                     the store is read-only until restart"
                        .into(),
                ));
            }
        }
        let out = self.store.add(name, vecs, d, threads)?;
        if self.engine.is_none() {
            return Ok(out);
        }
        let (append_result, cadence_due) = {
            let engine = self.engine.as_mut().expect("checked above");
            let rec = WalRecord {
                seq: engine.next_seq,
                name: name.to_string(),
                dim: d,
                rows: vecs.to_vec(),
            };
            let bytes = encode_record(&rec)?;
            engine.next_seq += 1;
            engine.records_since_snapshot += 1;
            let path = wal_path(&engine.data_dir, name);
            let res = engine
                .io
                .append(&path, &bytes, engine.fsync == FsyncPolicy::Always)
                .map_err(|e| format!("WAL append to {}: {e}", path.display()));
            let due = engine.snapshot_every > 0
                && engine.records_since_snapshot >= engine.snapshot_every;
            (res, due)
        };
        if let Err(append_err) = append_result {
            return match self.snapshot_now() {
                // the reseal sealed the consumed seq (and these rows):
                // the add is durable, ack it
                Ok(()) => Ok(out),
                Err(snap_err) => {
                    self.engine.as_mut().expect("checked above").read_only = true;
                    Err(IndexError::ReadOnly(format!(
                        "{append_err}; reseal snapshot also failed ({snap_err}); \
                         rows applied in memory but NOT durable; \
                         the store is read-only until restart"
                    )))
                }
            };
        }
        if cadence_due {
            // non-fatal: the add is durable in the WAL either way, and a
            // failed snapshot left the WAL in place (deletion is skipped
            // on error), so the next add retries the snapshot
            if let Err(e) = self.snapshot_now() {
                crate::info!("index snapshot failed (will retry next add): {e}");
            }
        }
        Ok(out)
    }

    /// Write a snapshot sealing the current state, delete the WAL files
    /// it subsumes, and prune all but the previous snapshot (kept as a
    /// fallback against a latent bad write). No-op on ephemeral stores.
    pub fn snapshot_now(&mut self) -> Result<(), IndexError> {
        let Some(engine) = &mut self.engine else {
            return Ok(());
        };
        let bytes = encode_snapshot(&self.store, engine.next_seq);
        let path = snapshot_path(&engine.data_dir, engine.next_seq);
        engine
            .io
            .write_atomic(&path, &bytes, true)
            .map_err(|e| IndexError::Io(format!("writing {}: {e}", path.display())))?;
        // the snapshot seals every logged record: drop the WALs
        let wal_dir = engine.data_dir.join(WAL_DIR);
        for name in engine
            .io
            .list(&wal_dir)
            .map_err(|e| IndexError::Io(format!("listing {}: {e}", wal_dir.display())))?
        {
            if name.ends_with(".wal") {
                let p = wal_dir.join(&name);
                engine
                    .io
                    .remove(&p)
                    .map_err(|e| IndexError::Io(format!("removing {}: {e}", p.display())))?;
            }
        }
        // prune: a snapshot with seq > next_seq can only be one recovery
        // rejected as undecodable (a valid one would have been loaded
        // and next_seq would sit at or above it) — delete those so they
        // stop shadowing good snapshots; then keep the new snapshot
        // plus one predecessor
        let seqs = list_snapshots(engine.io.as_mut(), &engine.data_dir)?;
        let sealed = engine.next_seq;
        let stale_new = seqs.iter().filter(|&&s| s > sealed);
        let old_predecessors = seqs.iter().filter(|&&s| s < sealed).skip(1);
        for &old in stale_new.chain(old_predecessors) {
            let p = snapshot_path(&engine.data_dir, old);
            engine
                .io
                .remove(&p)
                .map_err(|e| IndexError::Io(format!("removing {}: {e}", p.display())))?;
        }
        engine.records_since_snapshot = 0;
        Ok(())
    }

    /// Pass-through query (see [`VectorStore::query`]).
    pub fn query(
        &self,
        name: &str,
        q: &[f32],
        k: usize,
        rerank_factor: usize,
        threads: usize,
    ) -> Result<Vec<SearchHit>, IndexError> {
        self.store.query(name, q, k, rerank_factor, threads)
    }

    /// Hand back the inner [`Io`] (tests recover from what survived a
    /// faulted run). Ephemeral stores return `None`.
    pub fn into_io(self) -> Option<Box<dyn Io>> {
        self.engine.map(|e| e.io)
    }
}

#[cfg(test)]
mod tests {
    use super::super::io::{Fault, FaultIo, MemIo};
    use super::*;
    use crate::index::IndexPolicy;
    use crate::rng::Rng;

    fn cfg() -> IndexConfig {
        IndexConfig { policy: IndexPolicy::Uniform(6), ..Default::default() }
    }

    fn dcfg(snapshot_every: usize) -> DurabilityConfig {
        DurabilityConfig {
            data_dir: PathBuf::from("/idx"),
            fsync: FsyncPolicy::Never,
            snapshot_every,
        }
    }

    fn assert_bit_identical(a: &VectorStore, b: &VectorStore) {
        assert_eq!(
            a.collections.keys().collect::<Vec<_>>(),
            b.collections.keys().collect::<Vec<_>>()
        );
        for (name, ca) in &a.collections {
            let cb = &b.collections[name];
            assert_eq!(ca.bits, cb.bits, "{name}: bit plan");
            assert_eq!(ca.codes, cb.codes, "{name}: packed codes");
            assert_eq!(ca.r, cb.r, "{name}: rescales");
            assert_eq!(ca.exact, cb.exact, "{name}: residuals");
        }
    }

    #[test]
    fn restart_recovers_wal_only_store_bit_for_bit() {
        let d = 16usize;
        let mut durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(MemIo::new())).unwrap();
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for seed in 0..5u64 {
            let v = Rng::new(seed).gaussian_vec(3 * d);
            durable.add("docs", &v, d, 1).unwrap();
            fresh.add("docs", &v, d, 1).unwrap();
        }
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.recovered_rows(), 15);
        assert_eq!(rep.dropped_records, 0);
        assert_eq!(reopened.next_seq(), 5);
        assert_bit_identical(reopened.store(), &fresh);
    }

    #[test]
    fn snapshot_seals_wal_and_recovery_prefers_it() {
        let d = 8usize;
        let mut durable = DurableStore::open_with(cfg(), dcfg(2), Box::new(MemIo::new())).unwrap();
        for seed in 0..5u64 {
            durable.add("a", &Rng::new(seed).gaussian_vec(d), d, 1).unwrap();
        }
        // snapshot_every=2: snapshots at seq 2 and 4; one record (seq 4)
        // still in the WAL
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(2), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.snapshot_rows, 4);
        assert_eq!(rep.replayed_rows, 1);
        assert_eq!(rep.duplicate_records, 0);
        assert_eq!(reopened.next_seq(), 5);
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for seed in 0..5u64 {
            fresh.add("a", &Rng::new(seed).gaussian_vec(d), d, 1).unwrap();
        }
        assert_bit_identical(reopened.store(), &fresh);
    }

    #[test]
    fn duplicate_wal_records_replay_idempotently() {
        // write snapshot *without* clearing the WAL by re-appending a
        // sealed record manually: recovery must skip it
        let d = 8usize;
        let mut durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(MemIo::new())).unwrap();
        let v = Rng::new(9).gaussian_vec(d);
        durable.add("a", &v, d, 1).unwrap();
        durable.snapshot_now().unwrap();
        let mut io = durable.into_io().unwrap();
        let stale = encode_record(&WalRecord {
            seq: 0,
            name: "a".into(),
            dim: d,
            rows: v.clone(),
        })
        .unwrap();
        io.append(&wal_path(Path::new("/idx"), "a"), &stale, false).unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.duplicate_records, 1);
        assert_eq!(rep.replayed_rows, 0);
        assert_eq!(reopened.store().rows(), 1, "no double-apply");
    }

    #[test]
    fn seq_gap_stops_replay_and_counts_drops() {
        let d = 4usize;
        let mut io = MemIo::new();
        let mk = |seq: u64| {
            encode_record(&WalRecord {
                seq,
                name: "g".into(),
                dim: d,
                rows: vec![seq as f32; d],
            })
            .unwrap()
        };
        let p = wal_path(Path::new("/idx"), "g");
        io.append(&p, &mk(0), false).unwrap();
        io.append(&p, &mk(1), false).unwrap();
        io.append(&p, &mk(3), false).unwrap(); // 2 lost elsewhere
        io.append(&p, &mk(4), false).unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), Box::new(io)).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.replayed_rows, 2, "seq 0 and 1 only");
        assert_eq!(rep.dropped_records, 2, "seq 3 and 4 are beyond the gap");
        assert_eq!(reopened.next_seq(), 2);
    }

    #[test]
    fn interleaved_collections_recover_in_global_order() {
        // two collections, alternating adds: per-collection WALs must
        // merge back to the original global order
        let d = 8usize;
        let mut durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(MemIo::new())).unwrap();
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for seed in 0..6u64 {
            let name = if seed % 2 == 0 { "even" } else { "odd" };
            let v = Rng::new(seed).gaussian_vec(2 * d);
            durable.add(name, &v, d, 1).unwrap();
            fresh.add(name, &v, d, 1).unwrap();
        }
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        assert_bit_identical(reopened.store(), &fresh);
        assert_eq!(reopened.next_seq(), 6);
    }

    #[test]
    fn refused_adds_write_nothing() {
        let d = 8usize;
        let mut durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(MemIo::new())).unwrap();
        assert!(durable.add("bad name!", &vec![0.0; d], d, 1).is_err());
        assert_eq!(durable.next_seq(), 0, "refused add must not consume a seq");
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        assert_eq!(reopened.store().rows(), 0);
        assert_eq!(reopened.recovery().unwrap(), RecoveryReport::default());
    }

    #[test]
    fn torn_tail_is_resealed_so_a_second_crash_loses_nothing() {
        // the double-crash shape from the review: a torn tail must not
        // leave corrupt bytes that swallow post-restart appends
        let d = 8usize;
        let v0 = Rng::new(20).gaussian_vec(d);
        let v1 = Rng::new(21).gaussian_vec(d);
        let mut io = MemIo::new();
        let p = wal_path(Path::new("/idx"), "a");
        io.append(&p, &encode_record(&WalRecord { seq: 0, name: "a".into(), dim: d, rows: v0.clone() }).unwrap(), false)
            .unwrap();
        let torn = encode_record(&WalRecord { seq: 1, name: "a".into(), dim: d, rows: v1.clone() }).unwrap();
        io.append(&p, &torn[..torn.len() / 2], false).unwrap();
        // first restart: recovery drops the torn tail and reseals
        let mut durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(io)).unwrap();
        assert_eq!(durable.recovery().unwrap().dropped_records, 1);
        // post-restart acks land after the reseal, not after torn bytes
        let v2 = Rng::new(22).gaussian_vec(d);
        let v3 = Rng::new(23).gaussian_vec(d);
        durable.add("a", &v2, d, 1).unwrap();
        durable.add("a", &v3, d, 1).unwrap();
        // second crash: every ack since the first restart must survive
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.dropped_records, 0, "second recovery must be clean");
        assert_eq!(rep.recovered_rows(), 3);
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for v in [&v0, &v2, &v3] {
            fresh.add("a", v, d, 1).unwrap();
        }
        assert_bit_identical(reopened.store(), &fresh);
    }

    #[test]
    fn gap_reseal_prevents_stale_records_shadowing_reused_seqs() {
        // review scenario: post-gap records left on disk could replay
        // under a reused seq instead of the newly acknowledged record —
        // the reseal must delete them
        let d = 4usize;
        let mut io = MemIo::new();
        let rec = |seq: u64, name: &str, fill: f32| {
            encode_record(&WalRecord { seq, name: name.into(), dim: d, rows: vec![fill; d] })
                .unwrap()
        };
        io.append(&wal_path(Path::new("/idx"), "a"), &rec(0, "a", 1.0), false).unwrap();
        // seq 1 lost (gap); seq 2 survives in another, clean WAL file
        io.append(&wal_path(Path::new("/idx"), "stale"), &rec(2, "stale", 9.0), false).unwrap();
        let mut durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(io)).unwrap();
        assert_eq!(durable.recovery().unwrap().dropped_records, 1);
        assert_eq!(durable.next_seq(), 1, "resumes at the gap");
        // new acks reuse seqs 1 and 2; the stale seq-2 record must not
        // resurrect at the next recovery
        let v1 = Rng::new(31).gaussian_vec(d);
        let v2 = Rng::new(32).gaussian_vec(d);
        durable.add("a", &v1, d, 1).unwrap();
        durable.add("a", &v2, d, 1).unwrap();
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.dropped_records, 0);
        assert!(
            !reopened.store().collections.contains_key("stale"),
            "the dropped post-gap record must not reappear"
        );
        let mut fresh = VectorStore::new(cfg()).unwrap();
        fresh.add("a", &vec![1.0; d], d, 1).unwrap();
        fresh.add("a", &v1, d, 1).unwrap();
        fresh.add("a", &v2, d, 1).unwrap();
        assert_bit_identical(reopened.store(), &fresh);
    }

    #[test]
    fn failed_append_reseals_into_a_snapshot_and_still_acks() {
        // one transient append failure (review: a brief ENOSPC) must not
        // void later acks via a permanent sequence gap
        let d = 8usize;
        let io = FaultIo::new(MemIo::new(), Fault::FailWrite { nth: 3 });
        let mut durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(io)).unwrap();
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for seed in 0..4u64 {
            let v = Rng::new(40 + seed).gaussian_vec(d);
            // add 3's append fails and is resealed by snapshot — the add
            // is durable either way, so every add must ack
            durable.add("a", &v, d, 1).unwrap();
            fresh.add("a", &v, d, 1).unwrap();
        }
        assert!(!durable.is_read_only());
        assert_eq!(durable.next_seq(), 4);
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.dropped_records, 0, "no gap: the reseal covered the consumed seq");
        assert_eq!(rep.recovered_rows(), 4);
        assert_bit_identical(reopened.store(), &fresh);
    }

    #[test]
    fn persistent_write_failure_flips_read_only_and_refuses_retries() {
        let d = 8usize;
        // write 1 (add 1's append) succeeds; everything after fails —
        // add 2's append fails AND its reseal snapshot fails
        let io = FaultIo::new(MemIo::new(), Fault::FailWritesFrom { nth: 2 });
        let mut durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(io)).unwrap();
        let v0 = Rng::new(50).gaussian_vec(d);
        durable.add("a", &v0, d, 1).unwrap();
        let err = durable.add("a", &Rng::new(51).gaussian_vec(d), d, 1).unwrap_err();
        assert!(matches!(err, IndexError::ReadOnly(_)), "got {err}");
        assert!(durable.is_read_only());
        // a client retry is refused before touching the store — no
        // duplicate rows, no ack that recovery would void
        let rows_before = durable.store().rows();
        let err = durable.add("a", &Rng::new(51).gaussian_vec(d), d, 1).unwrap_err();
        assert!(matches!(err, IndexError::ReadOnly(_)));
        assert_eq!(durable.store().rows(), rows_before, "refused before apply");
        // reads keep working
        assert_eq!(durable.query("a", &v0, 1, 4, 1).unwrap().len(), 1);
        // recovery sees exactly the durable prefix (add 1)
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        assert_eq!(reopened.recovery().unwrap().recovered_rows(), 1);
        let mut fresh = VectorStore::new(cfg()).unwrap();
        fresh.add("a", &v0, d, 1).unwrap();
        assert_bit_identical(reopened.store(), &fresh);
    }

    #[test]
    fn ephemeral_store_has_no_engine() {
        let mut s = DurableStore::ephemeral(cfg()).unwrap();
        s.add("a", &vec![1.0; 8], 8, 1).unwrap();
        assert!(!s.is_durable());
        assert!(s.recovery().is_none());
        s.snapshot_now().unwrap(); // no-op, not an error
        assert!(s.into_io().is_none());
    }
}
