//! Durability orchestration: WAL-before-ack writes, periodic
//! snapshots, and crash recovery for a [`VectorStore`].
//!
//! [`DurableStore`] wraps a store with an optional durability engine.
//! Without one (`DurableStore::ephemeral`) it is a zero-cost
//! pass-through — the serving layer holds one type either way. With one
//! ([`DurableStore::open`]):
//!
//! * **Write path** — an `add` first applies to the in-memory store
//!   (so admission failures, bad names, and budget refusals never
//!   reach the log), then appends one WAL record stamped with the next
//!   store-global sequence number, then acknowledges. Under
//!   [`FsyncPolicy::Always`] the append is flushed before the ack.
//! * **Snapshot path** — after every `snapshot_every` acknowledged
//!   records (and on [`DurableStore::snapshot_now`]) the whole store is
//!   serialized to a versioned segment file (atomic temp + fsync +
//!   rename), the WAL files are deleted (their records are sealed into
//!   the snapshot), and older snapshots beyond one spare are pruned.
//! * **Recovery** ([`recover`]) — load the newest decodable snapshot
//!   (corrupt ones are skipped, older ones tried), parse every WAL
//!   file stop-at-first-corruption, merge the surviving records by
//!   global sequence number, and replay the contiguous run starting at
//!   the snapshot's `next_seq` through the normal `add` path. Records
//!   already sealed in the snapshot (seq below `next_seq`) are skipped
//!   — replay is idempotent; records after a sequence gap are dropped
//!   — a lost record invalidates everything that depended on coming
//!   after it. The outcome is surfaced as [`RecoveryReport`]
//!   (`/v1/stats` reports `recovered_rows` / `dropped_records`).
//!
//! Because replay re-runs the deterministic quantization pipeline and
//! snapshots store the exact in-memory layout, a recovered store equals
//! a never-crashed store **bit-for-bit** (codes, rescales, residuals,
//! bit plan) up to the last durable record — the property the
//! fault-injection wall in `rust/tests/durability.rs` asserts for every
//! fault the [`super::io::FaultIo`] shim can inject.

use super::io::{Io, StdIo};
use super::snapshot::{
    decode_snapshot, encode_snapshot, list_snapshots, snapshot_path,
};
use super::wal::{decode_records, encode_record, wal_path, WalRecord, WalTail, WAL_DIR};
use super::{IndexConfig, IndexError, SearchHit, VectorStore};
use std::path::{Path, PathBuf};

/// When WAL appends are flushed to stable storage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync every WAL append before acknowledging — an acked add
    /// survives power loss, at one disk flush per add.
    Always,
    /// Leave flushing to the OS page cache — an acked add survives
    /// process death but a power cut may tear the tail (which recovery
    /// tolerates by design).
    Never,
}

/// Durability configuration for [`DurableStore::open`].
#[derive(Clone, Debug)]
pub struct DurabilityConfig {
    /// Directory holding `wal/` and the snapshot segments.
    pub data_dir: PathBuf,
    /// WAL flush policy.
    pub fsync: FsyncPolicy,
    /// Acknowledged records between automatic snapshots; `0` disables
    /// automatic snapshots (explicit [`DurableStore::snapshot_now`]
    /// only).
    pub snapshot_every: usize,
}

/// What recovery found and did, for `/v1/stats` and the test walls.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Rows restored from the snapshot.
    pub snapshot_rows: usize,
    /// Rows replayed from WAL records.
    pub replayed_rows: usize,
    /// WAL records dropped: corrupt/torn tails (one per damaged file)
    /// plus whole records lost to a sequence gap.
    pub dropped_records: usize,
    /// WAL records skipped because the snapshot already sealed them
    /// (duplicate replay — idempotence, not loss).
    pub duplicate_records: usize,
    /// Snapshot files that failed to decode and were skipped.
    pub corrupt_snapshots: usize,
}

impl RecoveryReport {
    /// Total rows the store holds because of recovery (snapshot +
    /// replay) — the `recovered_rows` stats field.
    pub fn recovered_rows(&self) -> usize {
        self.snapshot_rows + self.replayed_rows
    }
}

/// Load the newest usable snapshot and replay the WAL tail. Never
/// fails on *corruption* (that is data, reported in the
/// [`RecoveryReport`]); fails only on genuine I/O errors or an invalid
/// `cfg`.
pub fn recover(
    io: &mut dyn Io,
    data_dir: &Path,
    cfg: IndexConfig,
) -> Result<(VectorStore, u64, RecoveryReport), IndexError> {
    let mut report = RecoveryReport::default();
    // newest decodable snapshot wins; corrupt ones are skipped
    let mut store: Option<(VectorStore, u64)> = None;
    for seq in list_snapshots(io, data_dir)? {
        let path = snapshot_path(data_dir, seq);
        let bytes = io
            .read(&path)
            .map_err(|e| IndexError::Io(format!("reading {}: {e}", path.display())))?
            .unwrap_or_default();
        match decode_snapshot(&bytes, cfg.clone()) {
            Ok(loaded) => {
                store = Some(loaded);
                break;
            }
            Err(_) => report.corrupt_snapshots += 1,
        }
    }
    let (mut store, mut next_seq) = match store {
        Some(s) => s,
        None => (VectorStore::new(cfg)?, 0),
    };
    report.snapshot_rows = store.rows();
    // parse every WAL file stop-at-first-corruption, then merge by the
    // store-global sequence number to reconstruct the original
    // interleaved add order (the Budget policy's rebalance cadence —
    // hence the final bit plan — depends on it)
    let wal_dir = data_dir.join(WAL_DIR);
    let mut records: Vec<WalRecord> = Vec::new();
    for name in io
        .list(&wal_dir)
        .map_err(|e| IndexError::Io(format!("listing {}: {e}", wal_dir.display())))?
    {
        if !name.ends_with(".wal") {
            continue;
        }
        let path = wal_dir.join(&name);
        let bytes = io
            .read(&path)
            .map_err(|e| IndexError::Io(format!("reading {}: {e}", path.display())))?
            .unwrap_or_default();
        let (recs, tail) = decode_records(&bytes);
        if tail != WalTail::Clean {
            report.dropped_records += 1;
        }
        records.extend(recs);
    }
    records.sort_by_key(|r| r.seq);
    // replay the contiguous run from next_seq; duplicates (sealed in
    // the snapshot) are skipped, anything after a gap is dropped
    for rec in records {
        if rec.seq < next_seq {
            report.duplicate_records += 1;
            continue;
        }
        if rec.seq > next_seq {
            report.dropped_records += 1;
            continue;
        }
        match store.add(&rec.name, &rec.rows, rec.dim, 0) {
            Ok((_, rows)) => report.replayed_rows += rows,
            // a record the store now refuses (e.g. budget shrank across
            // restarts) is dropped, not fatal — recovery must finish
            Err(_) => {
                report.dropped_records += 1;
                continue;
            }
        }
        next_seq = rec.seq + 1;
    }
    Ok((store, next_seq, report))
}

/// The durability engine a durable [`DurableStore`] carries.
struct Engine {
    io: Box<dyn Io>,
    data_dir: PathBuf,
    fsync: FsyncPolicy,
    snapshot_every: usize,
    next_seq: u64,
    records_since_snapshot: usize,
    report: RecoveryReport,
}

/// A [`VectorStore`] with optional crash-safety. All read paths and
/// the non-durable constructor are zero-overhead pass-throughs, so the
/// serving layer holds one type whether or not `--data-dir` was given.
pub struct DurableStore {
    store: VectorStore,
    engine: Option<Engine>,
}

impl DurableStore {
    /// In-memory only store — restart loses everything (the PR-5
    /// behavior, still the default without `--data-dir`).
    pub fn ephemeral(cfg: IndexConfig) -> Result<DurableStore, IndexError> {
        Ok(DurableStore { store: VectorStore::new(cfg)?, engine: None })
    }

    /// Open (or create) a durable store at `dcfg.data_dir` on the real
    /// filesystem: recover whatever the directory holds, then log every
    /// subsequent add.
    pub fn open(cfg: IndexConfig, dcfg: DurabilityConfig) -> Result<DurableStore, IndexError> {
        DurableStore::open_with(cfg, dcfg, Box::new(StdIo))
    }

    /// [`DurableStore::open`] over an explicit [`Io`] — the seam the
    /// fault-injection wall uses ([`super::io::MemIo`] /
    /// [`super::io::FaultIo`]).
    pub fn open_with(
        cfg: IndexConfig,
        dcfg: DurabilityConfig,
        mut io: Box<dyn Io>,
    ) -> Result<DurableStore, IndexError> {
        let (store, next_seq, report) = recover(io.as_mut(), &dcfg.data_dir, cfg)?;
        Ok(DurableStore {
            store,
            engine: Some(Engine {
                io,
                data_dir: dcfg.data_dir,
                fsync: dcfg.fsync,
                snapshot_every: dcfg.snapshot_every,
                next_seq,
                records_since_snapshot: 0,
                report,
            }),
        })
    }

    /// Borrow the underlying store (all read paths).
    pub fn store(&self) -> &VectorStore {
        &self.store
    }

    /// True when adds are logged to disk.
    pub fn is_durable(&self) -> bool {
        self.engine.is_some()
    }

    /// The recovery outcome of [`DurableStore::open`]; `None` for
    /// ephemeral stores (the stats endpoint omits the fields).
    pub fn recovery(&self) -> Option<RecoveryReport> {
        self.engine.as_ref().map(|e| e.report)
    }

    /// Next store-global WAL sequence number (tests pin the cadence).
    pub fn next_seq(&self) -> u64 {
        self.engine.as_ref().map(|e| e.next_seq).unwrap_or(0)
    }

    /// Durable add: apply in memory, then append one WAL record, then
    /// acknowledge (see module docs for the ordering argument). The
    /// in-memory apply alone decides admission — a refused add writes
    /// nothing. A WAL append failure is returned as
    /// [`IndexError::Io`]; the in-memory rows stay (they are valid,
    /// merely not yet durable) and the sequence number is still
    /// consumed so a later snapshot reseals them.
    pub fn add(
        &mut self,
        name: &str,
        vecs: &[f32],
        d: usize,
        threads: usize,
    ) -> Result<(usize, usize), IndexError> {
        let out = self.store.add(name, vecs, d, threads)?;
        let Some(engine) = &mut self.engine else {
            return Ok(out);
        };
        let rec = WalRecord {
            seq: engine.next_seq,
            name: name.to_string(),
            dim: d,
            rows: vecs.to_vec(),
        };
        engine.next_seq += 1;
        engine.records_since_snapshot += 1;
        let bytes = encode_record(&rec)?;
        let path = wal_path(&engine.data_dir, name);
        engine
            .io
            .append(&path, &bytes, engine.fsync == FsyncPolicy::Always)
            .map_err(|e| IndexError::Io(format!("WAL append to {}: {e}", path.display())))?;
        if engine.snapshot_every > 0 && engine.records_since_snapshot >= engine.snapshot_every {
            self.snapshot_now()?;
        }
        Ok(out)
    }

    /// Write a snapshot sealing the current state, delete the WAL files
    /// it subsumes, and prune all but the previous snapshot (kept as a
    /// fallback against a latent bad write). No-op on ephemeral stores.
    pub fn snapshot_now(&mut self) -> Result<(), IndexError> {
        let Some(engine) = &mut self.engine else {
            return Ok(());
        };
        let bytes = encode_snapshot(&self.store, engine.next_seq);
        let path = snapshot_path(&engine.data_dir, engine.next_seq);
        engine
            .io
            .write_atomic(&path, &bytes, true)
            .map_err(|e| IndexError::Io(format!("writing {}: {e}", path.display())))?;
        // the snapshot seals every logged record: drop the WALs
        let wal_dir = engine.data_dir.join(WAL_DIR);
        for name in engine
            .io
            .list(&wal_dir)
            .map_err(|e| IndexError::Io(format!("listing {}: {e}", wal_dir.display())))?
        {
            if name.ends_with(".wal") {
                let p = wal_dir.join(&name);
                engine
                    .io
                    .remove(&p)
                    .map_err(|e| IndexError::Io(format!("removing {}: {e}", p.display())))?;
            }
        }
        // keep the new snapshot plus one predecessor
        let seqs = list_snapshots(engine.io.as_mut(), &engine.data_dir)?;
        for &old in seqs.iter().skip(2) {
            let p = snapshot_path(&engine.data_dir, old);
            engine
                .io
                .remove(&p)
                .map_err(|e| IndexError::Io(format!("removing {}: {e}", p.display())))?;
        }
        engine.records_since_snapshot = 0;
        Ok(())
    }

    /// Pass-through query (see [`VectorStore::query`]).
    pub fn query(
        &self,
        name: &str,
        q: &[f32],
        k: usize,
        rerank_factor: usize,
        threads: usize,
    ) -> Result<Vec<SearchHit>, IndexError> {
        self.store.query(name, q, k, rerank_factor, threads)
    }

    /// Hand back the inner [`Io`] (tests recover from what survived a
    /// faulted run). Ephemeral stores return `None`.
    pub fn into_io(self) -> Option<Box<dyn Io>> {
        self.engine.map(|e| e.io)
    }
}

#[cfg(test)]
mod tests {
    use super::super::io::MemIo;
    use super::*;
    use crate::index::IndexPolicy;
    use crate::rng::Rng;

    fn cfg() -> IndexConfig {
        IndexConfig { policy: IndexPolicy::Uniform(6), ..Default::default() }
    }

    fn dcfg(snapshot_every: usize) -> DurabilityConfig {
        DurabilityConfig {
            data_dir: PathBuf::from("/idx"),
            fsync: FsyncPolicy::Never,
            snapshot_every,
        }
    }

    fn assert_bit_identical(a: &VectorStore, b: &VectorStore) {
        assert_eq!(
            a.collections.keys().collect::<Vec<_>>(),
            b.collections.keys().collect::<Vec<_>>()
        );
        for (name, ca) in &a.collections {
            let cb = &b.collections[name];
            assert_eq!(ca.bits, cb.bits, "{name}: bit plan");
            assert_eq!(ca.codes, cb.codes, "{name}: packed codes");
            assert_eq!(ca.r, cb.r, "{name}: rescales");
            assert_eq!(ca.exact, cb.exact, "{name}: residuals");
        }
    }

    #[test]
    fn restart_recovers_wal_only_store_bit_for_bit() {
        let d = 16usize;
        let mut durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(MemIo::new())).unwrap();
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for seed in 0..5u64 {
            let v = Rng::new(seed).gaussian_vec(3 * d);
            durable.add("docs", &v, d, 1).unwrap();
            fresh.add("docs", &v, d, 1).unwrap();
        }
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.recovered_rows(), 15);
        assert_eq!(rep.dropped_records, 0);
        assert_eq!(reopened.next_seq(), 5);
        assert_bit_identical(reopened.store(), &fresh);
    }

    #[test]
    fn snapshot_seals_wal_and_recovery_prefers_it() {
        let d = 8usize;
        let mut durable = DurableStore::open_with(cfg(), dcfg(2), Box::new(MemIo::new())).unwrap();
        for seed in 0..5u64 {
            durable.add("a", &Rng::new(seed).gaussian_vec(d), d, 1).unwrap();
        }
        // snapshot_every=2: snapshots at seq 2 and 4; one record (seq 4)
        // still in the WAL
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(2), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.snapshot_rows, 4);
        assert_eq!(rep.replayed_rows, 1);
        assert_eq!(rep.duplicate_records, 0);
        assert_eq!(reopened.next_seq(), 5);
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for seed in 0..5u64 {
            fresh.add("a", &Rng::new(seed).gaussian_vec(d), d, 1).unwrap();
        }
        assert_bit_identical(reopened.store(), &fresh);
    }

    #[test]
    fn duplicate_wal_records_replay_idempotently() {
        // write snapshot *without* clearing the WAL by re-appending a
        // sealed record manually: recovery must skip it
        let d = 8usize;
        let mut durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(MemIo::new())).unwrap();
        let v = Rng::new(9).gaussian_vec(d);
        durable.add("a", &v, d, 1).unwrap();
        durable.snapshot_now().unwrap();
        let mut io = durable.into_io().unwrap();
        let stale = encode_record(&WalRecord {
            seq: 0,
            name: "a".into(),
            dim: d,
            rows: v.clone(),
        })
        .unwrap();
        io.append(&wal_path(Path::new("/idx"), "a"), &stale, false).unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.duplicate_records, 1);
        assert_eq!(rep.replayed_rows, 0);
        assert_eq!(reopened.store().rows(), 1, "no double-apply");
    }

    #[test]
    fn seq_gap_stops_replay_and_counts_drops() {
        let d = 4usize;
        let mut io = MemIo::new();
        let mk = |seq: u64| {
            encode_record(&WalRecord {
                seq,
                name: "g".into(),
                dim: d,
                rows: vec![seq as f32; d],
            })
            .unwrap()
        };
        let p = wal_path(Path::new("/idx"), "g");
        io.append(&p, &mk(0), false).unwrap();
        io.append(&p, &mk(1), false).unwrap();
        io.append(&p, &mk(3), false).unwrap(); // 2 lost elsewhere
        io.append(&p, &mk(4), false).unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), Box::new(io)).unwrap();
        let rep = reopened.recovery().unwrap();
        assert_eq!(rep.replayed_rows, 2, "seq 0 and 1 only");
        assert_eq!(rep.dropped_records, 2, "seq 3 and 4 are beyond the gap");
        assert_eq!(reopened.next_seq(), 2);
    }

    #[test]
    fn interleaved_collections_recover_in_global_order() {
        // two collections, alternating adds: per-collection WALs must
        // merge back to the original global order
        let d = 8usize;
        let mut durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(MemIo::new())).unwrap();
        let mut fresh = VectorStore::new(cfg()).unwrap();
        for seed in 0..6u64 {
            let name = if seed % 2 == 0 { "even" } else { "odd" };
            let v = Rng::new(seed).gaussian_vec(2 * d);
            durable.add(name, &v, d, 1).unwrap();
            fresh.add(name, &v, d, 1).unwrap();
        }
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        assert_bit_identical(reopened.store(), &fresh);
        assert_eq!(reopened.next_seq(), 6);
    }

    #[test]
    fn refused_adds_write_nothing() {
        let d = 8usize;
        let mut durable = DurableStore::open_with(cfg(), dcfg(0), Box::new(MemIo::new())).unwrap();
        assert!(durable.add("bad name!", &vec![0.0; d], d, 1).is_err());
        assert_eq!(durable.next_seq(), 0, "refused add must not consume a seq");
        let io = durable.into_io().unwrap();
        let reopened = DurableStore::open_with(cfg(), dcfg(0), io).unwrap();
        assert_eq!(reopened.store().rows(), 0);
        assert_eq!(reopened.recovery().unwrap(), RecoveryReport::default());
    }

    #[test]
    fn ephemeral_store_has_no_engine() {
        let mut s = DurableStore::ephemeral(cfg()).unwrap();
        s.add("a", &vec![1.0; 8], 8, 1).unwrap();
        assert!(!s.is_durable());
        assert!(s.recovery().is_none());
        s.snapshot_now().unwrap(); // no-op, not an error
        assert!(s.into_io().is_none());
    }
}
