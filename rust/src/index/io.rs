//! Byte-level I/O behind the durability layer, with deterministic fault
//! injection.
//!
//! Everything the WAL and snapshot writers touch on disk goes through
//! the [`Io`] trait: whole-file reads, appends, atomic replaces,
//! removals, directory listings. Three implementations:
//!
//! * [`StdIo`] — the real filesystem (what `--data-dir` uses). Atomic
//!   replace is write-temp + fsync + rename, so a crash mid-snapshot
//!   leaves either the old file or the new one, never a torn hybrid.
//! * [`MemIo`] — an in-memory map, for tests that build, corrupt, and
//!   recover stores without touching disk.
//! * [`FaultIo`] — wraps any [`Io`] and applies one [`Fault`] from a
//!   deterministic plan: fail the Nth write outright, persist only the
//!   first N bytes of it (a torn write), or flip one bit of it
//!   (silent media corruption). The recovery property wall drives every
//!   fault through this shim and asserts recovery ≡ fresh build up to
//!   the last durable record.

use std::collections::BTreeMap;
use std::fs;
use std::io::{self, Write as _};
use std::path::{Path, PathBuf};

/// Filesystem surface of the durability layer. Object-safe so stores
/// can hold a `Box<dyn Io + Send>` and tests can swap in [`MemIo`] /
/// [`FaultIo`].
pub trait Io: Send {
    /// Read a whole file. `Ok(None)` when it does not exist.
    fn read(&mut self, path: &Path) -> io::Result<Option<Vec<u8>>>;

    /// Append `bytes` to `path`, creating it (and parent directories)
    /// if missing. With `fsync`, flush to stable storage before
    /// returning — the WAL's ack-after-durable knob.
    fn append(&mut self, path: &Path, bytes: &[u8], fsync: bool) -> io::Result<()>;

    /// Atomically replace `path` with `bytes`: the file observably holds
    /// either its previous content or all of `bytes`, never a prefix.
    /// With `fsync`, the new content is flushed before the swap.
    fn write_atomic(&mut self, path: &Path, bytes: &[u8], fsync: bool) -> io::Result<()>;

    /// Delete a file; missing files are a no-op.
    fn remove(&mut self, path: &Path) -> io::Result<()>;

    /// File names (not full paths) directly inside `dir`, sorted.
    /// A missing directory lists as empty.
    fn list(&mut self, dir: &Path) -> io::Result<Vec<String>>;
}

// ------------------------------------------------------------------- StdIo

/// Real-filesystem [`Io`].
#[derive(Debug, Default)]
pub struct StdIo;

impl Io for StdIo {
    fn read(&mut self, path: &Path) -> io::Result<Option<Vec<u8>>> {
        match fs::read(path) {
            Ok(b) => Ok(Some(b)),
            Err(e) if e.kind() == io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e),
        }
    }

    fn append(&mut self, path: &Path, bytes: &[u8], fsync: bool) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(bytes)?;
        if fsync {
            f.sync_data()?;
        }
        Ok(())
    }

    fn write_atomic(&mut self, path: &Path, bytes: &[u8], fsync: bool) -> io::Result<()> {
        let parent = path.parent().unwrap_or_else(|| Path::new("."));
        fs::create_dir_all(parent)?;
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = PathBuf::from(tmp);
        {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(bytes)?;
            if fsync {
                f.sync_data()?;
            }
        }
        fs::rename(&tmp, path)?;
        if fsync {
            // persist the rename itself (directory entry)
            if let Ok(dir) = fs::File::open(parent) {
                let _ = dir.sync_data();
            }
        }
        Ok(())
    }

    fn remove(&mut self, path: &Path) -> io::Result<()> {
        match fs::remove_file(path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e),
        }
    }

    fn list(&mut self, dir: &Path) -> io::Result<Vec<String>> {
        let rd = match fs::read_dir(dir) {
            Ok(rd) => rd,
            Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => Err(e)?,
        };
        let mut names = Vec::new();
        for entry in rd {
            let entry = entry?;
            if entry.file_type()?.is_file() {
                if let Some(n) = entry.file_name().to_str() {
                    names.push(n.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }
}

// ------------------------------------------------------------------- MemIo

/// In-memory [`Io`]: a path → bytes map. Deterministic, no disk, and
/// the test walls can inspect or corrupt "files" directly via
/// [`MemIo::get`] / [`MemIo::put`].
#[derive(Debug, Default, Clone)]
pub struct MemIo {
    files: BTreeMap<PathBuf, Vec<u8>>,
}

impl MemIo {
    /// Empty in-memory filesystem.
    pub fn new() -> MemIo {
        MemIo::default()
    }

    /// Borrow a file's bytes, if present.
    pub fn get(&self, path: &Path) -> Option<&Vec<u8>> {
        self.files.get(path)
    }

    /// Insert or replace a file wholesale (fixture loading, manual
    /// corruption).
    pub fn put(&mut self, path: &Path, bytes: Vec<u8>) {
        self.files.insert(path.to_path_buf(), bytes);
    }
}

impl Io for MemIo {
    fn read(&mut self, path: &Path) -> io::Result<Option<Vec<u8>>> {
        Ok(self.files.get(path).cloned())
    }

    fn append(&mut self, path: &Path, bytes: &[u8], _fsync: bool) -> io::Result<()> {
        self.files.entry(path.to_path_buf()).or_default().extend_from_slice(bytes);
        Ok(())
    }

    fn write_atomic(&mut self, path: &Path, bytes: &[u8], _fsync: bool) -> io::Result<()> {
        self.files.insert(path.to_path_buf(), bytes.to_vec());
        Ok(())
    }

    fn remove(&mut self, path: &Path) -> io::Result<()> {
        self.files.remove(path);
        Ok(())
    }

    fn list(&mut self, dir: &Path) -> io::Result<Vec<String>> {
        let mut names = Vec::new();
        for p in self.files.keys() {
            if p.parent() == Some(dir) {
                if let Some(n) = p.file_name().and_then(|n| n.to_str()) {
                    names.push(n.to_string());
                }
            }
        }
        Ok(names)
    }
}

// ----------------------------------------------------------------- FaultIo

/// One deterministic fault, addressed by the global 1-based ordinal of
/// the write it hits (appends and atomic writes share the counter).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Fault {
    /// The `nth` write fails with an I/O error; nothing is persisted.
    FailWrite {
        /// 1-based ordinal of the write to fail.
        nth: usize,
    },
    /// Every write from the `nth` on fails — a disk that stays broken
    /// (ENOSPC, pulled volume), the case that must flip the store
    /// read-only rather than keep acking into a sequence gap.
    FailWritesFrom {
        /// 1-based ordinal of the first failing write.
        nth: usize,
    },
    /// The `nth` write persists only its first `keep` bytes — a torn
    /// write (power loss mid-append). Later writes succeed normally.
    TornWrite {
        /// 1-based ordinal of the write to tear.
        nth: usize,
        /// Bytes of the payload that reach storage.
        keep: usize,
    },
    /// The `nth` write persists with one bit flipped — silent
    /// corruption the CRC must catch at recovery.
    FlipBit {
        /// 1-based ordinal of the write to corrupt.
        nth: usize,
        /// Byte offset within that write's payload.
        byte: usize,
        /// Bit index 0..8 within the byte.
        bit: u8,
    },
    /// The `nth` write sleeps `millis` before persisting normally — a
    /// slow disk flush. Used to assert that readers never serialize
    /// behind seal I/O (the ISSUE-8 headline bug).
    SlowWrite {
        /// 1-based ordinal of the write to delay.
        nth: usize,
        /// Milliseconds to sleep before the write proceeds.
        millis: u64,
    },
}

/// [`Io`] wrapper that injects one [`Fault`] at a deterministic point
/// in the write sequence. Reads, removals, and listings pass through
/// untouched — recovery always sees exactly what "survived the crash".
pub struct FaultIo<I: Io> {
    inner: I,
    fault: Fault,
    writes: usize,
}

impl<I: Io> FaultIo<I> {
    /// Wrap `inner`, arming `fault`.
    pub fn new(inner: I, fault: Fault) -> FaultIo<I> {
        FaultIo { inner, fault, writes: 0 }
    }

    /// Writes observed so far (for sizing fault plans in tests).
    pub fn writes(&self) -> usize {
        self.writes
    }

    /// Unwrap the inner [`Io`] (tests recover from what survived).
    pub fn into_inner(self) -> I {
        self.inner
    }

    /// Apply the armed fault to this write's payload, if it is the
    /// targeted ordinal. `Ok(None)` means "drop the write entirely".
    fn mangle(&mut self, bytes: &[u8]) -> io::Result<Option<Vec<u8>>> {
        self.writes += 1;
        match self.fault {
            Fault::FailWrite { nth } if nth == self.writes => {
                Err(io::Error::new(io::ErrorKind::Other, "injected write failure"))
            }
            Fault::FailWritesFrom { nth } if nth <= self.writes => {
                Err(io::Error::new(io::ErrorKind::Other, "injected persistent write failure"))
            }
            Fault::TornWrite { nth, keep } if nth == self.writes => {
                Ok(Some(bytes[..keep.min(bytes.len())].to_vec()))
            }
            Fault::FlipBit { nth, byte, bit } if nth == self.writes => {
                let mut out = bytes.to_vec();
                if let Some(b) = out.get_mut(byte) {
                    *b ^= 1u8 << (bit & 7);
                }
                Ok(Some(out))
            }
            Fault::SlowWrite { nth, millis } if nth == self.writes => {
                std::thread::sleep(std::time::Duration::from_millis(millis));
                Ok(Some(bytes.to_vec()))
            }
            _ => Ok(Some(bytes.to_vec())),
        }
    }
}

impl<I: Io> Io for FaultIo<I> {
    fn read(&mut self, path: &Path) -> io::Result<Option<Vec<u8>>> {
        self.inner.read(path)
    }

    fn append(&mut self, path: &Path, bytes: &[u8], fsync: bool) -> io::Result<()> {
        match self.mangle(bytes)? {
            Some(b) => self.inner.append(path, &b, fsync),
            None => Ok(()),
        }
    }

    fn write_atomic(&mut self, path: &Path, bytes: &[u8], fsync: bool) -> io::Result<()> {
        // a torn atomic write is still atomic-or-absent on a real fs;
        // modelling the tear as a short *file* covers the stricter case
        match self.mangle(bytes)? {
            Some(b) => self.inner.write_atomic(path, &b, fsync),
            None => Ok(()),
        }
    }

    fn remove(&mut self, path: &Path) -> io::Result<()> {
        self.inner.remove(path)
    }

    fn list(&mut self, dir: &Path) -> io::Result<Vec<String>> {
        self.inner.list(dir)
    }
}
