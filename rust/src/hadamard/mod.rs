//! Fast Walsh–Hadamard transform + practical RHT (paper Alg. 5).
//!
//! The quantization hot path: RaBitQ-H rotates every weight column with a
//! Randomized Hadamard Transform before grid quantization. This module is
//! the Rust (CPU) implementation the paper itself uses for the quantization
//! phase; the Pallas kernel (python/compile/kernels/hadamard.py) is the
//! inference-path twin and both are property-tested against each other via
//! golden vectors.
//!
//! `fwht` is in-place, O(d log d), with the first two butterfly stages
//! unrolled pairwise to cut loop overhead (see EXPERIMENTS.md §Perf).

use crate::rng::Rng;
use crate::tensor::Matrix;

/// Is n a power of two (n >= 1)?
#[inline]
pub fn is_pow2(n: usize) -> bool {
    n != 0 && n & (n - 1) == 0
}

/// Largest power of two <= n.
#[inline]
pub fn floor_pow2(n: usize) -> usize {
    debug_assert!(n >= 1);
    1usize << (usize::BITS - 1 - n.leading_zeros())
}

/// In-place unnormalized FWHT over a power-of-2-length slice.
///
/// After the call, `x` holds `H_d @ x` with the Sylvester Hadamard matrix.
/// Multiply by 1/sqrt(d) for the orthonormal version.
pub fn fwht_unnormalized(x: &mut [f32]) {
    let d = x.len();
    debug_assert!(is_pow2(d), "FWHT needs power-of-2 length, got {d}");
    let mut h = 1;
    // stage 1 (h=1) unrolled: adjacent pairs
    if d >= 2 {
        let mut i = 0;
        while i < d {
            let a = x[i];
            let b = x[i + 1];
            x[i] = a + b;
            x[i + 1] = a - b;
            i += 2;
        }
        h = 2;
    }
    // stage 2 (h=2) unrolled
    if d >= 4 {
        let mut i = 0;
        while i < d {
            let (a0, a1, b0, b1) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
            x[i] = a0 + b0;
            x[i + 1] = a1 + b1;
            x[i + 2] = a0 - b0;
            x[i + 3] = a1 - b1;
            i += 4;
        }
        h = 4;
    }
    while h < d {
        let mut i = 0;
        while i < d {
            let (lo, hi) = x[i..i + 2 * h].split_at_mut(h);
            for (a, b) in lo.iter_mut().zip(hi.iter_mut()) {
                let t = *a;
                *a = t + *b;
                *b = t - *b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// In-place orthonormal FWHT: x <- H_d x / sqrt(d).
pub fn fwht(x: &mut [f32]) {
    fwht_unnormalized(x);
    let scale = 1.0 / (x.len() as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// Batched orthonormal FWHT: treat `data` as `data.len() / d` contiguous
/// rows of length `d` and transform every row in one parallel,
/// cache-blocked pass (each worker streams whole rows, so a row's butterfly
/// stages run while it is L1/L2-resident). `threads == 0` means
/// [`crate::threadpool::default_threads`] (`RAANA_THREADS` applies).
/// Runs on the process-wide persistent pool
/// ([`crate::threadpool::global`]); bit-deterministic in the thread
/// count and pool width — rows are independent and chunking is fixed by
/// the caller.
pub fn fwht_batch(data: &mut [f32], d: usize, threads: usize) {
    assert!(is_pow2(d), "fwht_batch needs power-of-2 row length, got {d}");
    assert_eq!(data.len() % d, 0, "data length must be a multiple of d");
    let rows = data.len() / d;
    let threads = if threads == 0 {
        crate::threadpool::default_threads()
    } else {
        threads
    };
    if rows <= 1 || threads <= 1 {
        for row in data.chunks_mut(d) {
            fwht(row);
        }
        return;
    }
    let rows_per = rows.div_ceil(threads * 2).max(1);
    crate::threadpool::parallel_chunks_mut(data, rows_per * d, threads, |_, chunk| {
        for row in chunk.chunks_mut(d) {
            fwht(row);
        }
    });
}

/// In-place RHT: x <- H D x / sqrt(d), with D = diag(signs).
pub fn rht(x: &mut [f32], signs: &[f32]) {
    debug_assert_eq!(x.len(), signs.len());
    for (v, &s) in x.iter_mut().zip(signs) {
        *v *= s;
    }
    fwht(x);
}

/// In-place inverse RHT: x <- D H x / sqrt(d) (H symmetric, D^2 = I).
pub fn rht_inverse(x: &mut [f32], signs: &[f32]) {
    fwht(x);
    for (v, &s) in x.iter_mut().zip(signs) {
        *v *= s;
    }
}

/// Practical RHT for arbitrary dimension d (paper Alg. 5).
///
/// Finds d_hat = 2^floor(log2 d) and applies an independent RHT to the
/// first d_hat entries and then to the last d_hat entries (the two windows
/// overlap when d is not a power of 2). The composition is orthonormal, so
/// inner products are preserved and the inverse is the reverse composition.
#[derive(Clone, Debug)]
pub struct PracticalRht {
    pub d: usize,
    pub d_hat: usize,
    /// Signs for the first window [0, d_hat).
    pub signs1: Vec<f32>,
    /// Signs for the second window [d - d_hat, d); empty if d is a power of 2.
    pub signs2: Vec<f32>,
}

impl PracticalRht {
    /// Sample fresh Rademacher diagonals from `rng`.
    pub fn sample(d: usize, rng: &mut Rng) -> Self {
        assert!(d >= 1);
        let d_hat = floor_pow2(d);
        let signs1 = rng.rademacher_vec(d_hat);
        let signs2 = if d_hat == d { Vec::new() } else { rng.rademacher_vec(d_hat) };
        PracticalRht { d, d_hat, signs1, signs2 }
    }

    /// Stored-bit cost: one Rademacher bit per sign.
    pub fn stored_bits(&self) -> usize {
        self.signs1.len() + self.signs2.len()
    }

    /// Apply in place to a d-length vector.
    pub fn apply(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.d);
        rht(&mut x[..self.d_hat], &self.signs1);
        if !self.signs2.is_empty() {
            let start = self.d - self.d_hat;
            rht(&mut x[start..], &self.signs2);
        }
    }

    /// Apply the inverse in place (reverse order of the two windows).
    pub fn apply_inverse(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.d);
        if !self.signs2.is_empty() {
            let start = self.d - self.d_hat;
            rht_inverse(&mut x[start..], &self.signs2);
        }
        rht_inverse(&mut x[..self.d_hat], &self.signs1);
    }

    /// Apply to every column of a (d x c) matrix.
    pub fn apply_columns(&self, m: &mut Matrix) {
        assert_eq!(m.rows, self.d);
        let mut buf = vec![0f32; self.d];
        for j in 0..m.cols {
            m.col_view(j).copy_into(&mut buf);
            self.apply(&mut buf);
            m.set_col(j, &buf);
        }
    }

    /// Apply the inverse to every column of a (d x c) matrix.
    pub fn apply_inverse_columns(&self, m: &mut Matrix) {
        assert_eq!(m.rows, self.d);
        let mut buf = vec![0f32; self.d];
        for j in 0..m.cols {
            m.col_view(j).copy_into(&mut buf);
            self.apply_inverse(&mut buf);
            m.set_col(j, &buf);
        }
    }

    /// Apply to every row of an (n x d) matrix (the inference-side
    /// transform of activations in paper Alg. 3), in one parallel batch.
    pub fn apply_rows(&self, m: &mut Matrix) {
        self.apply_rows_threaded(m, 0);
    }

    /// [`PracticalRht::apply_rows`] with an explicit thread count
    /// (0 = default), on the process-wide persistent pool. Rows are
    /// independent and chunking is fixed by the caller, so the result is
    /// bit-deterministic in `threads` and in the pool width.
    pub fn apply_rows_threaded(&self, m: &mut Matrix, threads: usize) {
        assert_eq!(m.cols, self.d);
        let d = self.d;
        let rows = m.rows;
        let threads = if threads == 0 {
            crate::threadpool::default_threads()
        } else {
            threads
        };
        if rows <= 1 || threads <= 1 {
            for i in 0..rows {
                self.apply(m.row_mut(i));
            }
            return;
        }
        let rows_per = rows.div_ceil(threads * 2).max(1);
        crate::threadpool::parallel_chunks_mut(&mut m.data, rows_per * d, threads, |_, chunk| {
            for row in chunk.chunks_mut(d) {
                self.apply(row);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor;

    fn randvec(n: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).gaussian_vec(n)
    }

    #[test]
    fn fwht_matches_explicit_matrix() {
        // H_4 explicit
        let h4: [[f32; 4]; 4] = [
            [1.0, 1.0, 1.0, 1.0],
            [1.0, -1.0, 1.0, -1.0],
            [1.0, 1.0, -1.0, -1.0],
            [1.0, -1.0, -1.0, 1.0],
        ];
        let x = [0.5f32, -1.0, 2.0, 3.0];
        let mut got = x;
        fwht_unnormalized(&mut got);
        for i in 0..4 {
            let want: f32 = (0..4).map(|j| h4[i][j] * x[j]).sum();
            assert!((got[i] - want).abs() < 1e-5, "{i}: {} vs {want}", got[i]);
        }
    }

    #[test]
    fn fwht_is_involution() {
        for logd in [0, 1, 3, 6, 10] {
            let d = 1 << logd;
            let x = randvec(d, 42 + logd as u64);
            let mut y = x.clone();
            fwht(&mut y);
            fwht(&mut y);
            for (a, b) in x.iter().zip(&y) {
                assert!((a - b).abs() < 1e-3, "d={d}");
            }
        }
    }

    #[test]
    fn fwht_preserves_norm() {
        let x = randvec(512, 7);
        let n0 = tensor::norm(&x);
        let mut y = x;
        fwht(&mut y);
        assert!((tensor::norm(&y) - n0).abs() / n0 < 1e-5);
    }

    #[test]
    fn rht_preserves_inner_products() {
        let mut rng = Rng::new(3);
        let signs = rng.rademacher_vec(256);
        let a = randvec(256, 1);
        let b = randvec(256, 2);
        let ip0 = tensor::dot(&a, &b);
        let (mut ra, mut rb) = (a, b);
        rht(&mut ra, &signs);
        rht(&mut rb, &signs);
        let ip1 = tensor::dot(&ra, &rb);
        assert!((ip0 - ip1).abs() < 1e-3 * ip0.abs().max(1.0));
    }

    #[test]
    fn rht_inverse_roundtrip() {
        let mut rng = Rng::new(9);
        let signs = rng.rademacher_vec(128);
        let x = randvec(128, 4);
        let mut y = x.clone();
        rht(&mut y, &signs);
        rht_inverse(&mut y, &signs);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn practical_rht_pow2_equals_plain() {
        let mut rng = Rng::new(5);
        let p = PracticalRht::sample(64, &mut rng);
        assert!(p.signs2.is_empty());
        let x = randvec(64, 6);
        let mut a = x.clone();
        p.apply(&mut a);
        let mut b = x;
        rht(&mut b, &p.signs1);
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-6);
        }
    }

    #[test]
    fn practical_rht_arbitrary_dims_roundtrip_and_norm() {
        for d in [3usize, 5, 12, 100, 192, 300, 1000] {
            let mut rng = Rng::new(d as u64);
            let p = PracticalRht::sample(d, &mut rng);
            let x = randvec(d, d as u64 + 1);
            let n0 = tensor::norm(&x);
            let mut y = x.clone();
            p.apply(&mut y);
            assert!((tensor::norm(&y) - n0).abs() / n0 < 1e-4, "norm d={d}");
            p.apply_inverse(&mut y);
            for (a, b) in x.iter().zip(&y) {
                assert!((a - b).abs() < 1e-3, "roundtrip d={d}");
            }
        }
    }

    #[test]
    fn practical_rht_preserves_inner_products_nonpow2() {
        let d = 300;
        let mut rng = Rng::new(17);
        let p = PracticalRht::sample(d, &mut rng);
        let a = randvec(d, 1);
        let b = randvec(d, 2);
        let ip0 = tensor::dot(&a, &b);
        let (mut ra, mut rb) = (a, b);
        p.apply(&mut ra);
        p.apply(&mut rb);
        assert!((tensor::dot(&ra, &rb) - ip0).abs() < 1e-3 * ip0.abs().max(1.0));
    }

    #[test]
    fn columns_and_rows_agree_with_vector_apply() {
        let d = 96;
        let mut rng = Rng::new(23);
        let p = PracticalRht::sample(d, &mut rng);
        let mut m = Matrix::from_vec(d, 3, randvec(d * 3, 8));
        let col0: Vec<f32> = m.col(0);
        p.apply_columns(&mut m);
        let mut want = col0;
        p.apply(&mut want);
        for (a, b) in m.col(0).iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }

        let mut mr = Matrix::from_vec(2, d, randvec(2 * d, 9));
        let row1: Vec<f32> = mr.row(1).to_vec();
        p.apply_rows(&mut mr);
        let mut wr = row1;
        p.apply(&mut wr);
        for (a, b) in mr.row(1).iter().zip(&wr) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn inverse_columns_roundtrip_matrix() {
        let d = 192; // non-power-of-2
        let mut rng = Rng::new(31);
        let p = PracticalRht::sample(d, &mut rng);
        let m0 = Matrix::from_vec(d, 5, randvec(d * 5, 10));
        let mut m = m0.clone();
        p.apply_columns(&mut m);
        p.apply_inverse_columns(&mut m);
        assert!(m.rel_err(&m0) < 1e-4);
    }

    #[test]
    fn fwht_batch_matches_per_row_fwht() {
        for (rows, d) in [(1usize, 64usize), (7, 128), (33, 256), (4, 1)] {
            let data = randvec(rows * d, (rows * d) as u64);
            let mut batch = data.clone();
            fwht_batch(&mut batch, d, 4);
            let mut golden = data;
            for row in golden.chunks_mut(d) {
                fwht(row);
            }
            assert_eq!(batch, golden, "rows={rows} d={d}");
        }
    }

    #[test]
    fn fwht_batch_thread_counts_agree() {
        let d = 128;
        let data = randvec(19 * d, 99);
        let mut a = data.clone();
        let mut b = data;
        fwht_batch(&mut a, d, 1);
        fwht_batch(&mut b, d, 8);
        assert_eq!(a, b);
    }

    #[test]
    fn apply_rows_threaded_matches_serial() {
        let d = 300; // non-power-of-2: both RHT windows exercised
        let mut rng = Rng::new(41);
        let p = PracticalRht::sample(d, &mut rng);
        let data = randvec(9 * d, 43);
        let mut a = Matrix::from_vec(9, d, data.clone());
        let mut b = Matrix::from_vec(9, d, data);
        p.apply_rows_threaded(&mut a, 1);
        p.apply_rows_threaded(&mut b, 8);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn rotation_flattens_coordinates() {
        // After RHT a spiky vector spreads out: max |coord| shrinks toward
        // ||x||/sqrt(d) — the property RaBitQ's grid quantizer relies on.
        let d = 1024;
        let mut x = vec![0f32; d];
        x[7] = 10.0;
        let mut rng = Rng::new(77);
        let p = PracticalRht::sample(d, &mut rng);
        p.apply(&mut x);
        let maxabs = x.iter().fold(0f32, |m, v| m.max(v.abs()));
        assert!(maxabs < 1.0, "max {maxabs} should be ~10/sqrt(1024)=0.31");
    }
}
