//! Model metadata (from the AOT manifest) + parameter store + checkpoints.
//!
//! The manifest JSON written by `python/compile/aot.py` is the single
//! source of truth for parameter order and shapes — the Rust side never
//! hardcodes the model architecture. Checkpoints use a simple
//! magic/header/raw-f32 container (`.rkpt`).

use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{bail, Context, Result};

use crate::json::{self, Value};
use crate::tensor::Matrix;

/// Process-wide count of name-based parameter/linear lookups (linear
/// string scans over the manifest tables: [`Manifest::param_index`],
/// [`Manifest::linear_index`], [`ModelParams::index_of`]).
static NAME_RESOLUTIONS: AtomicUsize = AtomicUsize::new(0);

/// Read the resolution counter. Mirrors `rabitq::dequant_calls`: the native
/// forward resolves every index once at `NativeModel` construction, so a
/// full prefill + any number of decode steps must leave this counter
/// unchanged — regression-tested in `rust/tests/integration.rs`
/// (`native_serving_performs_zero_name_resolutions`).
pub fn name_resolutions() -> usize {
    NAME_RESOLUTIONS.load(Ordering::Relaxed)
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub calib_batch: usize,
    pub params: Vec<ParamSpec>,
    pub linears: Vec<LinearSpec>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A registered (quantizable) linear layer.
#[derive(Clone, Debug)]
pub struct LinearSpec {
    pub name: String,
    pub param: String,
    pub bias: String,
    pub d: usize,
    pub c: usize,
    pub m: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text)?;
        let model = v.req("model")?;
        let params = v
            .req("params")?
            .as_arr()
            .context("params not array")?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.req_str("name")?.to_string(),
                    shape: p
                        .req("shape")?
                        .as_arr()
                        .context("shape not array")?
                        .iter()
                        .map(|x| x.as_usize().context("shape entry"))
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let linears = v
            .req("linears")?
            .as_arr()
            .context("linears not array")?
            .iter()
            .map(|l| {
                Ok(LinearSpec {
                    name: l.req_str("name")?.to_string(),
                    param: l.req_str("param")?.to_string(),
                    bias: l.req_str("bias")?.to_string(),
                    d: l.req_usize("d")?,
                    c: l.req_usize("c")?,
                    m: l.req_usize("m")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            name: model.req_str("name")?.to_string(),
            vocab: model.req_usize("vocab")?,
            d_model: model.req_usize("d_model")?,
            n_layers: model.req_usize("n_layers")?,
            n_heads: model.req_usize("n_heads")?,
            d_ff: model.req_usize("d_ff")?,
            seq_len: model.req_usize("seq_len")?,
            train_batch: model.req_usize("train_batch")?,
            eval_batch: model.req_usize("eval_batch")?,
            calib_batch: model.req_usize("calib_batch")?,
            params,
            linears,
        })
    }

    /// Index of a parameter by name — a **counted** string scan (see
    /// [`name_resolutions`]); hot paths resolve once and hold the index.
    pub fn param_index(&self, name: &str) -> Result<usize> {
        NAME_RESOLUTIONS.fetch_add(1, Ordering::Relaxed);
        self.params
            .iter()
            .position(|p| p.name == name)
            .with_context(|| format!("unknown param '{name}'"))
    }

    /// Index of a registered linear by its param name — a **counted**
    /// string scan (see [`name_resolutions`]). The native forward resolves
    /// all of these at `NativeModel` construction and never again.
    pub fn linear_index(&self, name: &str) -> Result<usize> {
        NAME_RESOLUTIONS.fetch_add(1, Ordering::Relaxed);
        self.linears
            .iter()
            .position(|l| l.param == name)
            .with_context(|| format!("linear '{name}' not registered in manifest"))
    }

    /// Total parameter count.
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Total quantizable parameter count (the paper's Σ m_k).
    pub fn total_linear_params(&self) -> usize {
        self.linears.iter().map(|l| l.m).sum()
    }
}

/// Build a manifest programmatically, mirroring `param_specs` /
/// `linear_registry` in python/compile/model.py exactly. Lets the native
/// backend, tests, and benches run without the AOT artifact tree.
#[allow(clippy::too_many_arguments)]
pub fn synthetic_manifest(
    name: &str,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    d_ff: usize,
    seq_len: usize,
    vocab: usize,
    eval_batch: usize,
) -> Manifest {
    let mut params = vec![
        ParamSpec { name: "tok_emb".into(), shape: vec![vocab, d_model] },
        ParamSpec { name: "pos_emb".into(), shape: vec![seq_len, d_model] },
    ];
    let mut linears = Vec::new();
    for i in 0..n_layers {
        let p = format!("blk{i}.");
        let mut push = |n: &str, shape: Vec<usize>| {
            params.push(ParamSpec { name: format!("{p}{n}"), shape });
        };
        push("ln1.scale", vec![d_model]);
        push("ln1.bias", vec![d_model]);
        push("attn.wq", vec![d_model, d_model]);
        push("attn.wq.b", vec![d_model]);
        push("attn.wk", vec![d_model, d_model]);
        push("attn.wk.b", vec![d_model]);
        push("attn.wv", vec![d_model, d_model]);
        push("attn.wv.b", vec![d_model]);
        push("attn.wo", vec![d_model, d_model]);
        push("attn.wo.b", vec![d_model]);
        push("ln2.scale", vec![d_model]);
        push("ln2.bias", vec![d_model]);
        push("mlp.fc1", vec![d_model, d_ff]);
        push("mlp.fc1.b", vec![d_ff]);
        push("mlp.fc2", vec![d_ff, d_model]);
        push("mlp.fc2.b", vec![d_model]);
        for (nm, din, dout) in [
            ("attn.wq", d_model, d_model),
            ("attn.wk", d_model, d_model),
            ("attn.wv", d_model, d_model),
            ("attn.wo", d_model, d_model),
            ("mlp.fc1", d_model, d_ff),
            ("mlp.fc2", d_ff, d_model),
        ] {
            linears.push(LinearSpec {
                name: format!("blk{i}.{nm}"),
                param: format!("blk{i}.{nm}"),
                bias: format!("blk{i}.{nm}.b"),
                d: din,
                c: dout,
                m: din * dout,
            });
        }
    }
    params.push(ParamSpec { name: "ln_f.scale".into(), shape: vec![d_model] });
    params.push(ParamSpec { name: "ln_f.bias".into(), shape: vec![d_model] });
    params.push(ParamSpec { name: "lm_head".into(), shape: vec![d_model, vocab] });
    Manifest {
        name: name.to_string(),
        vocab,
        d_model,
        n_layers,
        n_heads,
        d_ff,
        seq_len,
        train_batch: eval_batch,
        eval_batch,
        calib_batch: 1,
        params,
        linears,
    }
}

/// Flat parameter store, tensors in manifest order.
#[derive(Clone)]
pub struct ModelParams {
    pub specs: Vec<ParamSpec>,
    pub tensors: Vec<Vec<f32>>,
}

impl ModelParams {
    pub fn zeros(manifest: &Manifest) -> Self {
        ModelParams {
            specs: manifest.params.clone(),
            tensors: manifest.params.iter().map(|p| vec![0.0; p.numel()]).collect(),
        }
    }

    pub fn from_tensors(manifest: &Manifest, tensors: Vec<Vec<f32>>) -> Result<Self> {
        anyhow::ensure!(tensors.len() == manifest.params.len(), "tensor count");
        for (t, s) in tensors.iter().zip(&manifest.params) {
            anyhow::ensure!(t.len() == s.numel(), "size mismatch for {}", s.name);
        }
        Ok(ModelParams { specs: manifest.params.clone(), tensors })
    }

    /// Index of a tensor by name — a **counted** string scan (see
    /// [`name_resolutions`]). Tensors are stored in manifest order, so an
    /// index resolved here (or via [`Manifest::param_index`]) stays valid
    /// for direct `tensors[i]` access for the life of the store.
    pub fn index_of(&self, name: &str) -> Result<usize> {
        NAME_RESOLUTIONS.fetch_add(1, Ordering::Relaxed);
        self.specs
            .iter()
            .position(|p| p.name == name)
            .with_context(|| format!("unknown param '{name}'"))
    }

    pub fn get(&self, name: &str) -> Result<&[f32]> {
        Ok(&self.tensors[self.index_of(name)?])
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Vec<f32>> {
        let i = self.index_of(name)?;
        Ok(&mut self.tensors[i])
    }

    /// View a 2-D parameter as a Matrix (copies).
    pub fn matrix(&self, name: &str) -> Result<Matrix> {
        let i = self.index_of(name)?;
        let spec = &self.specs[i];
        anyhow::ensure!(spec.shape.len() == 2, "{name} is not 2-D");
        Ok(Matrix::from_vec(spec.shape[0], spec.shape[1], self.tensors[i].clone()))
    }

    pub fn set_matrix(&mut self, name: &str, m: &Matrix) -> Result<()> {
        let i = self.index_of(name)?;
        let spec = &self.specs[i];
        anyhow::ensure!(
            spec.shape == vec![m.rows, m.cols],
            "shape mismatch writing {name}"
        );
        self.tensors[i].copy_from_slice(&m.data);
        Ok(())
    }

    /// Frobenius norm of a parameter.
    pub fn frobenius(&self, name: &str) -> Result<f64> {
        Ok(self
            .get(name)?
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt())
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    // -------------------------------------------------------------- .rkpt

    const MAGIC: &'static [u8; 8] = b"RKPT\x01\x00\x00\x00";

    /// Save to the simple binary checkpoint format.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let header = Value::Arr(
            self.specs
                .iter()
                .map(|p| {
                    json::obj(vec![
                        ("name", json::s(&p.name)),
                        (
                            "shape",
                            Value::Arr(
                                p.shape.iter().map(|&x| json::num(x as f64)).collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        )
        .to_json();
        let mut f = fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(Self::MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for t in &self.tensors {
            // SAFETY-free: serialize via to_le_bytes per chunk
            let mut buf = Vec::with_capacity(t.len() * 4);
            for &v in t {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            f.write_all(&buf)?;
        }
        Ok(())
    }

    /// Load a checkpoint previously written by [`ModelParams::save`].
    pub fn load(path: &Path) -> Result<Self> {
        let mut f = fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != Self::MAGIC {
            bail!("{} is not a .rkpt checkpoint", path.display());
        }
        let mut lenb = [0u8; 8];
        f.read_exact(&mut lenb)?;
        let hlen = u64::from_le_bytes(lenb) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = json::parse(std::str::from_utf8(&hbuf)?)?;
        let specs: Vec<ParamSpec> = header
            .as_arr()
            .context("header not array")?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.req_str("name")?.to_string(),
                    shape: p
                        .req("shape")?
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .map(|x| x.as_usize().context("shape entry"))
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<_>>()?;
        let mut tensors = Vec::with_capacity(specs.len());
        for spec in &specs {
            let n = spec.numel();
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)
                .with_context(|| format!("reading tensor {}", spec.name))?;
            let mut t = Vec::with_capacity(n);
            for ch in buf.chunks_exact(4) {
                t.push(f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]));
            }
            tensors.push(t);
        }
        Ok(ModelParams { specs, tensors })
    }
}

/// Standard artifact-directory layout helpers.
pub struct ArtifactPaths {
    pub dir: PathBuf,
}

impl ArtifactPaths {
    pub fn new(root: &Path, model: &str) -> Self {
        ArtifactPaths { dir: root.join(model) }
    }

    pub fn hlo(&self, entry: &str) -> PathBuf {
        self.dir.join(format!("{entry}.hlo.txt"))
    }

    pub fn manifest(&self) -> PathBuf {
        self.dir.join("manifest.json")
    }
}

/// Locate the artifacts root: $RAANA_ARTIFACTS or ./artifacts.
pub fn artifacts_root() -> PathBuf {
    std::env::var("RAANA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI_MANIFEST: &str = r#"{
        "model": {"name":"t","vocab":256,"d_model":8,"n_layers":1,
                  "n_heads":2,"d_ff":16,"seq_len":4,"train_batch":2,
                  "eval_batch":2,"calib_batch":1},
        "params": [
            {"name":"w1","shape":[8,16]},
            {"name":"w1.b","shape":[16]},
            {"name":"v","shape":[4]}
        ],
        "linears": [
            {"name":"w1","param":"w1","bias":"w1.b","d":8,"c":16,"m":128}
        ]
    }"#;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(MINI_MANIFEST).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.d_model, 8);
        assert_eq!(m.params.len(), 3);
        assert_eq!(m.linears[0].m, 128);
        assert_eq!(m.total_params(), 8 * 16 + 16 + 4);
        assert_eq!(m.total_linear_params(), 128);
        assert_eq!(m.param_index("v").unwrap(), 2);
        assert!(m.param_index("nope").is_err());
    }

    #[test]
    fn params_get_set_matrix() {
        let m = Manifest::parse(MINI_MANIFEST).unwrap();
        let mut p = ModelParams::zeros(&m);
        let mat = Matrix::from_fn(8, 16, |i, j| (i * 16 + j) as f32);
        p.set_matrix("w1", &mat).unwrap();
        assert_eq!(p.matrix("w1").unwrap().data, mat.data);
        assert!(p.matrix("v").is_err()); // 1-D
        assert!(p.set_matrix("w1", &Matrix::zeros(4, 4)).is_err());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let m = Manifest::parse(MINI_MANIFEST).unwrap();
        let mut p = ModelParams::zeros(&m);
        for (i, t) in p.tensors.iter_mut().enumerate() {
            for (j, v) in t.iter_mut().enumerate() {
                *v = (i * 1000 + j) as f32 * 0.5 - 3.0;
            }
        }
        let dir = std::env::temp_dir().join(format!("raana_test_{}", std::process::id()));
        let path = dir.join("ckpt.rkpt");
        p.save(&path).unwrap();
        let q = ModelParams::load(&path).unwrap();
        assert_eq!(p.specs, q.specs);
        assert_eq!(p.tensors, q.tensors);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("raana_test_bad_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.rkpt");
        fs::write(&path, b"NOTRKPT_blah").unwrap();
        assert!(ModelParams::load(&path).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn frobenius_norm() {
        let m = Manifest::parse(MINI_MANIFEST).unwrap();
        let mut p = ModelParams::zeros(&m);
        p.get_mut("v").unwrap().copy_from_slice(&[3.0, 4.0, 0.0, 0.0]);
        assert!((p.frobenius("v").unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn synthetic_manifest_mirrors_python_schema() {
        let m = synthetic_manifest("syn", 64, 2, 2, 256, 32, 256, 2);
        // 2 embeddings + 16 per block + final LN pair + lm_head
        assert_eq!(m.params.len(), 2 + 16 * 2 + 3);
        assert_eq!(m.linears.len(), 6 * 2);
        assert_eq!(m.params[0].name, "tok_emb");
        assert_eq!(m.params[0].shape, vec![256, 64]);
        assert_eq!(m.linears[5].param, "blk0.mlp.fc2");
        assert_eq!((m.linears[5].d, m.linears[5].c), (256, 64));
        assert_eq!(m.linears[5].bias, "blk0.mlp.fc2.b");
        assert_eq!(m.total_linear_params(), 2 * (4 * 64 * 64 + 2 * 64 * 256));
        // every linear's param and bias exist in the param list
        for lin in &m.linears {
            assert!(m.param_index(&lin.param).is_ok(), "{}", lin.param);
            assert!(m.param_index(&lin.bias).is_ok(), "{}", lin.bias);
        }
        // params load as a zeroed store without error
        let p = ModelParams::zeros(&m);
        assert_eq!(p.total_params(), m.total_params());
    }

    #[test]
    fn artifact_paths() {
        let a = ArtifactPaths::new(Path::new("artifacts"), "tiny");
        assert_eq!(a.hlo("fwd_loss"), PathBuf::from("artifacts/tiny/fwd_loss.hlo.txt"));
        assert_eq!(a.manifest(), PathBuf::from("artifacts/tiny/manifest.json"));
    }
}
