//! # RaanA — fast, flexible, data-efficient post-training quantization
//!
//! A reproduction of *"RaanA: A Fast, Flexible, and Data-Efficient
//! Post-Training Quantization Algorithm"* (Yang, Gao, Hu; 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: the RaBitQ-H quantizer
//!   ([`rabitq`], [`quant`]), the AllocateBits bit-width optimizer
//!   ([`allocate`]), calibration ([`calib`]), baselines ([`baselines`]),
//!   perplexity evaluation ([`eval`]), training driver ([`train`]), a
//!   batching inference server ([`serve`]) with an HTTP/1.1 front-end
//!   ([`net`]: streaming, cancellation, backpressure), and the
//!   synthetic-corpus substrate ([`data`]).
//! * **L2/L1 (python/compile)** — a JAX transformer whose linear layers
//!   call Pallas kernels, AOT-lowered once to HLO-text artifacts that the
//!   [`runtime`] module loads and executes via PJRT. Python never runs on
//!   the request path.
//! * **Fused CPU kernels** ([`kernels`]) — the serving hot path: a
//!   cache-blocked, thread-parallel packed-code GEMM (`qgemm`) plus a
//!   register-tiled dense GEMM. [`runtime::ModelRuntime`] keeps RaBitQ
//!   codes resident ([`runtime::PackedLayers`]) and computes `fwd_logits`
//!   straight from them — zero full-matrix dequantization per forward,
//!   with a pure-Rust transformer forward standing in when PJRT artifacts
//!   are absent. The same machinery compresses the serving KV cache
//!   ([`kvq`]): K/V rows live as packed RaBitQ codes with a per-layer
//!   AllocateBits bit plan, and attention runs directly over the codes
//!   (`kernels::attend_cached_q`). It also backs a second workload: a
//!   RaBitQ-native vector index ([`index`]) whose collections store
//!   embedding rows as packed codes, answer top-k with an
//!   estimated-scan + exact-rerank two-phase query, and pick
//!   per-collection bit-widths with AllocateBits under a byte budget —
//!   served over HTTP as `/v1/embed` + `/v1/collections/...`
//!   ([`serve::index::IndexServer`]). For horizontal scale-out, the
//!   [`cluster`] module runs N such nodes behind a consistent-hashing
//!   router with bit-identical scatter-gather queries and fleet health.
//!   Cross-cutting telemetry lives in [`obs`]: a std-only metrics
//!   registry behind `GET /metrics`, per-request tracing with cluster-wide
//!   id propagation, and phase-level timing of the quantized hot path.
//!
//! Entry points: the `raana` binary (see `rust/src/main.rs`) and the
//! examples under `examples/`.

pub mod allocate;
pub mod baselines;
pub mod benchlib;
pub mod calib;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod hadamard;
pub mod index;
pub mod json;
pub mod kernels;
pub mod kvq;
pub mod model;
pub mod net;
pub mod obs;
pub mod quant;
pub mod rabitq;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod threadpool;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
