//! Calibration (paper §4.2): estimate per-layer sensitivities α_k and the
//! activation statistics consumed by the tricks and the GPTQ baseline.
//!
//! * **Few-shot** — `n_c` training sequences (the paper uses 5).
//! * **Zero-shot** — the single synthetic sentence from the paper, repeated
//!   100 times; no real data touched.
//!
//! Per calibration sample the AOT `calib_grads` artifact returns
//! `(||dL/dH_k||_F, ||X_k||_F)` for every registered linear layer in one
//! backward pass, and `calib_capture` returns the raw layer inputs `X_k`
//! from which we accumulate mean rows, column norms (tricks) and Gram
//! matrices `X^T X` (GPTQ baseline).

use anyhow::Result;

use crate::allocate::alpha_from_calib;
use crate::data;
use crate::model::ModelParams;
use crate::quant::LayerCalib;
use crate::runtime::{lit_i32, to_vec_f32, ModelRuntime};
use crate::tensor::Matrix;

/// Which calibration data to use.
#[derive(Clone, Debug, PartialEq)]
pub enum CalibMode {
    /// `n` sequences from the training split (paper default n = 5).
    FewShot(usize),
    /// The paper's single synthetic sentence.
    ZeroShot,
}

/// Everything downstream passes need from calibration.
pub struct CalibResult {
    /// α_k per registered linear layer (paper eq. 23).
    pub alphas: Vec<f64>,
    /// Activation statistics per layer (tricks).
    pub layer_stats: Vec<LayerCalib>,
    /// Gram matrices X^T X per layer (GPTQ baseline).
    pub hessians: Vec<Matrix>,
    /// Per-channel mean |X| per layer (AWQ baseline).
    pub act_mean_abs: Vec<Vec<f64>>,
    /// Number of calibration sequences used.
    pub n_samples: usize,
}

/// Build the calibration token sequences for a mode.
pub fn calib_sequences(
    mode: &CalibMode,
    corpus: &data::Corpus,
    seq_len: usize,
) -> Vec<Vec<i32>> {
    match mode {
        CalibMode::FewShot(n) => (0..*n)
            .map(|i| corpus.train_seq(i * 7).to_vec()) // spread over the split
            .collect(),
        CalibMode::ZeroShot => {
            let toks = data::tokenize(&data::zero_shot_text());
            vec![toks[..seq_len].to_vec()]
        }
    }
}

/// Run calibration for `params` with the given mode.
pub fn calibrate(
    mrt: &ModelRuntime,
    params: &ModelParams,
    mode: &CalibMode,
    corpus: &data::Corpus,
) -> Result<CalibResult> {
    let m = &mrt.manifest;
    let seqs = calib_sequences(mode, corpus, m.seq_len);
    anyhow::ensure!(!seqs.is_empty(), "no calibration sequences");
    anyhow::ensure!(m.calib_batch == 1, "calib artifacts are lowered at B=1");

    let nl = m.linears.len();
    let mut gnorm_acc = vec![0f64; nl];
    let mut xnorm_acc = vec![0f64; nl];
    let mut mean_acc: Vec<Vec<f64>> =
        m.linears.iter().map(|l| vec![0.0; l.d]).collect();
    let mut sq_acc: Vec<Vec<f64>> =
        m.linears.iter().map(|l| vec![0.0; l.d]).collect();
    let mut abs_acc: Vec<Vec<f64>> =
        m.linears.iter().map(|l| vec![0.0; l.d]).collect();
    let mut gram: Vec<Matrix> =
        m.linears.iter().map(|l| Matrix::zeros(l.d, l.d)).collect();
    let mut rows_seen = vec![0usize; nl];

    let param_lits = mrt.param_literals(params)?;
    for seq in &seqs {
        anyhow::ensure!(seq.len() == m.seq_len, "calib sequence length");
        let tok = lit_i32(seq, &[1, m.seq_len])?;

        // gradients + norms
        let mut inputs = param_lits.clone();
        inputs.push(tok.clone());
        let outs = mrt.calib_grads_art()?.run(&inputs)?;
        let gnorms = to_vec_f32(&outs[0])?;
        let xnorms = to_vec_f32(&outs[1])?;
        anyhow::ensure!(gnorms.len() == nl && xnorms.len() == nl, "calib arity");
        for k in 0..nl {
            gnorm_acc[k] += gnorms[k] as f64;
            xnorm_acc[k] += xnorms[k] as f64;
        }

        // raw activations
        let mut inputs = param_lits.clone();
        inputs.push(tok);
        let caps = mrt.calib_capture_art()?.run(&inputs)?;
        // output 0 is the loss (kept to stop XLA pruning params); 1.. = X_k
        anyhow::ensure!(caps.len() == nl + 1, "capture arity");
        for (k, cap) in caps.iter().skip(1).enumerate() {
            let d = m.linears[k].d;
            let flat = to_vec_f32(cap)?;
            let rows = flat.len() / d;
            let x = Matrix::from_vec(rows, d, flat);
            for i in 0..rows {
                let r = x.row(i);
                for (j, &v) in r.iter().enumerate() {
                    mean_acc[k][j] += v as f64;
                    sq_acc[k][j] += (v as f64) * (v as f64);
                    abs_acc[k][j] += (v as f64).abs();
                }
            }
            // Gram accumulate: X^T X
            gram[k].add_assign(&x.transpose().matmul(&x));
            rows_seen[k] += rows;
        }
    }

    let n = seqs.len() as f64;
    let mut alphas = Vec::with_capacity(nl);
    let mut layer_stats = Vec::with_capacity(nl);
    let mut act_mean_abs = Vec::with_capacity(nl);
    for (k, lin) in m.linears.iter().enumerate() {
        let wnorm = params.frobenius(&lin.param)?;
        alphas.push(alpha_from_calib(
            lin.d,
            gnorm_acc[k] / n,
            xnorm_acc[k] / n,
            wnorm,
        ));
        let rows = rows_seen[k].max(1) as f64;
        let mean_input: Vec<f32> =
            mean_acc[k].iter().map(|&s| (s / rows) as f32).collect();
        let col_norms: Vec<f64> = sq_acc[k].iter().map(|&s| s.sqrt()).collect();
        layer_stats.push(LayerCalib { mean_input, col_norms });
        act_mean_abs.push(abs_acc[k].iter().map(|&s| s / rows).collect());
    }

    Ok(CalibResult {
        alphas,
        layer_stats,
        hessians: gram,
        act_mean_abs,
        n_samples: seqs.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Corpus;

    #[test]
    fn zero_shot_sequence_is_single_and_trimmed() {
        let corpus = Corpus::from_text(&data::synthwiki(128 * 20, 1), 128, 0.2);
        let seqs = calib_sequences(&CalibMode::ZeroShot, &corpus, 128);
        assert_eq!(seqs.len(), 1);
        assert_eq!(seqs[0].len(), 128);
        let s = data::detokenize(&seqs[0]);
        assert!(s.starts_with("The curious fox"));
    }

    #[test]
    fn few_shot_sequences_count_and_spread() {
        let corpus = Corpus::from_text(&data::synthwiki(128 * 100, 2), 128, 0.2);
        let seqs = calib_sequences(&CalibMode::FewShot(5), &corpus, 128);
        assert_eq!(seqs.len(), 5);
        assert!(seqs.iter().all(|s| s.len() == 128));
        // the 5 sequences should not all be identical
        assert!(seqs.windows(2).any(|w| w[0] != w[1]));
    }
}
