//! Training driver: loops the AOT `train_step` artifact (AdamW fwd+bwd+
//! update fused into one HLO executable) from Rust. Python never runs here.

use anyhow::Result;

use crate::data::Corpus;
use crate::model::ModelParams;
use crate::rng::Rng;
use crate::runtime::{
    lit_i32, lit_scalar_f32, lit_scalar_i32, to_scalar_f32, to_vec_f32, ModelRuntime,
};
use crate::util::Timer;

/// Training hyperparameters.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f64,
    /// Linear warmup steps before cosine decay to `lr * 0.1`.
    pub warmup: usize,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { steps: 300, lr: 3e-3, warmup: 20, seed: 1234, log_every: 20 }
    }
}

/// Loss-curve entry.
#[derive(Clone, Debug)]
pub struct TrainLog {
    pub step: usize,
    pub loss: f32,
    pub lr: f64,
    pub secs: f64,
}

fn lr_at(cfg: &TrainConfig, step: usize) -> f64 {
    if step < cfg.warmup {
        cfg.lr * (step + 1) as f64 / cfg.warmup as f64
    } else {
        let t = (step - cfg.warmup) as f64 / (cfg.steps - cfg.warmup).max(1) as f64;
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
        cfg.lr * (0.1 + 0.9 * cos)
    }
}

/// Train `params` in place; returns the loss curve.
pub fn train(
    mrt: &ModelRuntime,
    params: &mut ModelParams,
    corpus: &Corpus,
    cfg: &TrainConfig,
) -> Result<Vec<TrainLog>> {
    let m = &mrt.manifest;
    let np = m.params.len();
    let mut rng = Rng::new(cfg.seed);
    let timer = Timer::start();

    // Adam state starts at zero.
    let mut mstate: Vec<Vec<f32>> =
        params.tensors.iter().map(|t| vec![0.0; t.len()]).collect();
    let mut vstate = mstate.clone();

    let mut logs = Vec::new();
    for step in 0..cfg.steps {
        let lr = lr_at(cfg, step);
        let batch = corpus.train_batch(m.train_batch, &mut rng);

        let mut inputs = Vec::with_capacity(3 * np + 3);
        for (spec, t) in params.specs.iter().zip(&params.tensors) {
            inputs.push(crate::runtime::lit_f32(t, &spec.shape)?);
        }
        for (spec, t) in params.specs.iter().zip(&mstate) {
            inputs.push(crate::runtime::lit_f32(t, &spec.shape)?);
        }
        for (spec, t) in params.specs.iter().zip(&vstate) {
            inputs.push(crate::runtime::lit_f32(t, &spec.shape)?);
        }
        inputs.push(lit_scalar_i32(step as i32));
        inputs.push(lit_scalar_f32(lr as f32));
        inputs.push(lit_i32(&batch, &[m.train_batch, m.seq_len])?);

        let outs = mrt.train_step_art()?.run(&inputs)?;
        anyhow::ensure!(outs.len() == 3 * np + 1, "train_step arity");
        for (i, t) in params.tensors.iter_mut().enumerate() {
            *t = to_vec_f32(&outs[i])?;
        }
        for (i, t) in mstate.iter_mut().enumerate() {
            *t = to_vec_f32(&outs[np + i])?;
        }
        for (i, t) in vstate.iter_mut().enumerate() {
            *t = to_vec_f32(&outs[2 * np + i])?;
        }
        let loss = to_scalar_f32(&outs[3 * np])?;
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}");

        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            crate::info!(
                "train step {step:>5} loss {loss:.4} lr {lr:.2e} ({:.1}s)",
                timer.secs()
            );
            logs.push(TrainLog { step, loss, lr, secs: timer.secs() });
        }
    }
    Ok(logs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let cfg = TrainConfig { steps: 100, lr: 1e-2, warmup: 10, ..Default::default() };
        assert!(lr_at(&cfg, 0) < lr_at(&cfg, 9)); // warming up
        assert!((lr_at(&cfg, 9) - 1e-2).abs() < 1.1e-3); // near peak
        assert!(lr_at(&cfg, 99) < lr_at(&cfg, 50)); // decaying
        assert!(lr_at(&cfg, 99) >= 0.1 * 1e-2 - 1e-9); // floor
    }
}
