//! Benchmark harness substrate (no criterion in the offline vendor set).
//!
//! Warmup + timed iterations with median/p95 reporting, plus a tiny table
//! printer used by the paper-table benches to emit the same rows the paper
//! reports.

use std::path::Path;
use std::time::Instant;

use crate::json::{self, Value};
use crate::util::{human_secs, mean, percentile};

/// Result of a timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub secs: Vec<f64>,
}

impl BenchResult {
    pub fn median(&self) -> f64 {
        percentile(&self.secs, 50.0)
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.secs, 95.0)
    }

    pub fn mean(&self) -> f64 {
        mean(&self.secs)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12} median {:>12} mean {:>12} p95  ({} iters)",
            self.name,
            human_secs(self.median()),
            human_secs(self.mean()),
            human_secs(self.p95()),
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut secs = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        secs.push(t.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), iters, secs }
}

/// Time a single run of `f` (for long end-to-end benches).
pub fn bench_once<F: FnOnce() -> R, R>(name: &str, f: F) -> (BenchResult, R) {
    let t = Instant::now();
    let r = f();
    let el = t.elapsed().as_secs_f64();
    (
        BenchResult { name: name.to_string(), iters: 1, secs: vec![el] },
        r,
    )
}

/// Fixed-width text table, used to print paper-style result tables.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }
}

/// JSON value for a [`BenchResult`] (milliseconds) — the machine-readable
/// form the `BENCH_*.json` files record so the perf trajectory is
/// comparable across PRs.
pub fn bench_json(r: &BenchResult) -> Value {
    json::obj(vec![
        ("median_ms", json::num(r.median() * 1e3)),
        ("mean_ms", json::num(r.mean() * 1e3)),
        ("p95_ms", json::num(r.p95() * 1e3)),
        ("iters", json::num(r.iters as f64)),
    ])
}

/// Write a JSON report to `path` (pretty-enough single-line rendering).
pub fn write_json_report(path: &Path, v: &Value) -> anyhow::Result<()> {
    std::fs::write(path, v.to_json())?;
    Ok(())
}

/// Format a perplexity / number cell the way the paper does.
pub fn fmt_ppl(v: f64) -> String {
    if !v.is_finite() {
        "NAN".into()
    } else if v >= 1e4 {
        format!("{:.1e}", v)
    } else if v >= 100.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 5);
        assert_eq!(r.secs.len(), 5);
        assert!(r.median() >= 0.0);
        assert!(r.p95() >= r.median());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Method", "bits", "ppl"]);
        t.row(vec!["fp16".into(), "16".into(), "5.68".into()]);
        t.row(vec!["RaanA".into(), "2.1".into(), "13.70".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
        assert!(lines[0].contains("Method"));
    }

    #[test]
    fn ppl_formatting() {
        assert_eq!(fmt_ppl(5.678), "5.68");
        assert_eq!(fmt_ppl(123.4), "123.4");
        assert_eq!(fmt_ppl(260_000.0), "2.6e5");
        assert_eq!(fmt_ppl(f64::NAN), "NAN");
    }

    #[test]
    fn bench_once_returns_value() {
        let (r, v) = bench_once("x", || 42);
        assert_eq!(v, 42);
        assert_eq!(r.iters, 1);
    }

    #[test]
    fn bench_json_roundtrips_through_parser() {
        let r = BenchResult { name: "k".into(), iters: 3, secs: vec![0.001, 0.002, 0.003] };
        let v = bench_json(&r);
        let parsed = json::parse(&v.to_json()).unwrap();
        assert_eq!(parsed.req_usize("iters").unwrap(), 3);
        assert!(parsed.req("median_ms").unwrap().as_f64().unwrap() > 1.0);
    }

    #[test]
    fn json_report_written_to_disk() {
        let dir = std::env::temp_dir().join(format!("raana_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let v = json::obj(vec![("bench", json::s("kernels")), ("threads", json::num(8.0))]);
        write_json_report(&path, &v).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = json::parse(&text).unwrap();
        assert_eq!(parsed.req_str("bench").unwrap(), "kernels");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
