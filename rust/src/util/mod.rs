//! Small shared utilities: wall-clock timers, human formatting, logging.

use std::time::Instant;

/// Simple scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds since construction.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds since construction.
    pub fn millis(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Format a byte count as a human-readable string.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration in seconds adaptively (us / ms / s).
pub fn human_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

/// Minimal leveled stderr logger controlled by `RAANA_LOG` (error..trace).
#[derive(Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

pub fn log_level() -> Level {
    match std::env::var("RAANA_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= $crate::util::Level::Info {
            eprintln!("[raana] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= $crate::util::Level::Debug {
            eprintln!("[raana:debug] {}", format!($($arg)*));
        }
    };
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation of a slice.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Index of the largest element (greedy token choice over logits): first
/// occurrence wins ties, 0 for an empty slice. NaN entries are never
/// selected over finite ones.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bestv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bestv {
            bestv = x;
            best = i;
        }
    }
    best
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy: the value
/// at rank `ceil(p/100 · n)` (1-based), clamped into `[1, n]` so p = 0
/// yields the minimum and p = 100 the maximum. Total panic-free: empty
/// input yields 0.0 and the sort uses `total_cmp`, so a stray NaN cannot
/// abort a stats endpoint mid-request.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert!(human_secs(0.000_001).contains("µs"));
        assert!(human_secs(0.005).contains("ms"));
        assert!(human_secs(2.0).contains("s"));
    }

    #[test]
    fn stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        // true nearest-rank: ceil(50/100·4) = rank 2 ⇒ 2.0 (the rounded
        // linear index this replaced returned 3.0 here)
        assert!((percentile(&xs, 50.0) - 2.0).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 75.0) - 3.0).abs() < 1e-12);
        // rank 5 of 5 needs p strictly past 80, nearest-rank style
        let ys = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!((percentile(&ys, 80.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&ys, 90.0) - 5.0).abs() < 1e-12);
        assert!(std_dev(&xs) > 0.0);
    }

    #[test]
    fn percentile_empty_and_single_do_not_panic() {
        // the serve stats path hits these shapes before any completion
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[], 95.0), 0.0);
        assert_eq!(percentile(&[0.7], 0.0), 0.7);
        assert_eq!(percentile(&[0.7], 50.0), 0.7);
        assert_eq!(percentile(&[0.7], 100.0), 0.7);
        // NaN must not abort the sort (total order puts it last)
        let with_nan = [0.2, f64::NAN, 0.1];
        assert_eq!(percentile(&with_nan, 0.0), 0.1);
        assert!(mean(&[]) == 0.0 && std_dev(&[1.0]) == 0.0);
    }

    #[test]
    fn argmax_edge_cases() {
        assert_eq!(argmax(&[]), 0, "empty slice defaults to 0");
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
        assert_eq!(argmax(&[f32::NAN, 1.0, f32::NAN]), 1, "NaN never selected");
        assert_eq!(argmax(&[2.0, 2.0, 1.0]), 0, "first occurrence wins ties");
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        let a = t.secs();
        let b = t.secs();
        assert!(b >= a);
    }
}
