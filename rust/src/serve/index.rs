//! Index serving: the [`IndexServer`] that fronts the retrieval
//! subsystem ([`crate::index`]) next to the generation batcher.
//!
//! Unlike generation — which needs a dedicated batcher thread to
//! amortize model steps across KV lanes — index operations are
//! synchronous and short, so the `IndexServer` is a thread-safe handle
//! the HTTP connection workers call **directly**: embeds run the native
//! forward on the caller's thread (the fused kernels fan out on the
//! crate's shared worker pool, the same threads the batcher's kernels
//! use), and collection reads/writes go straight to the internally
//! synchronized [`DurableStore`] — queries and stats share a read
//! lock, adds serialize on the durability engine, and seal/compaction
//! file I/O runs without the store lock, so a query never queues
//! behind a slow disk flush (the PR-6 design serialized every request
//! on one store mutex, which stalled reads for the whole of each
//! snapshot write). That keeps generate and index traffic on one
//! front-end and one thread pool without coupling index latency to the
//! batcher's round cadence.
//!
//! The embedding backend is optional: an `IndexServer` without one
//! still serves vector-in/vector-out add + query (callers bring their
//! own embeddings); `/v1/embed` and text-shaped requests then refuse
//! with a typed error.
#![deny(missing_docs)]

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::index::durability::{DurabilityConfig, DurableStore, RecoveryReport};
use crate::index::{CollectionInfo, IndexConfig, IndexError, SearchHit};
use crate::model::{Manifest, ModelParams};
use crate::obs::{self, trace};
use crate::runtime::native::{NativeModel, PackedLayers};

/// The model triple an [`IndexServer`] embeds with: manifest + weights
/// (+ packed codes, so embeds ride the same zero-dequant `qgemm` path
/// as generation).
pub struct EmbedBackend {
    manifest: Manifest,
    model: NativeModel,
    params: ModelParams,
    packed: Option<PackedLayers>,
}

impl EmbedBackend {
    /// Validate the model shape and build the backend.
    pub fn new(
        manifest: Manifest,
        params: ModelParams,
        packed: Option<PackedLayers>,
    ) -> Result<EmbedBackend> {
        let model = NativeModel::new(&manifest)?;
        if let Some(p) = &packed {
            anyhow::ensure!(
                p.layers.len() == manifest.linears.len(),
                "packed layer arity {} != {} registered linears",
                p.layers.len(),
                manifest.linears.len()
            );
        }
        Ok(EmbedBackend { manifest, model, params, packed })
    }

    /// Embedding dimension (the model's hidden width).
    pub fn dim(&self) -> usize {
        self.model.d_model
    }

    /// Longest token context one embed accepts before truncation.
    pub fn window(&self) -> usize {
        self.model.seq_len
    }
}

/// Aggregate index-serving counters (`GET /v1/collections` reports
/// them alongside the per-collection table).
#[derive(Clone, Debug, Default)]
pub struct IndexServerStats {
    /// Embeddings computed (directly or inside text-shaped add/query).
    pub embeds: usize,
    /// Rows added across all collections.
    pub rows_added: usize,
    /// Top-k queries answered.
    pub queries: usize,
    /// Collections currently live.
    pub collections: usize,
    /// Rows currently stored across collections.
    pub rows: usize,
    /// Total scan payload in bytes (codes + rescales — the budgeted
    /// quantity).
    pub code_bytes: usize,
    /// Immutable sealed segments across collections.
    pub segments: usize,
    /// Rows still in mutable heads (covered only by the WAL).
    pub head_rows: usize,
    /// Completed compaction passes since startup.
    pub compactions: usize,
    /// True when adds are WAL-logged to a data dir (`--data-dir`).
    pub durable: bool,
    /// True when a durability failure flipped the store read-only
    /// (adds refused with 503 until restart); always `false` for
    /// ephemeral servers.
    pub read_only: bool,
    /// Rows restored at startup (sealed segments + WAL replay); `None`
    /// on ephemeral servers — `/v1/stats` omits the field.
    pub recovered_rows: Option<usize>,
    /// WAL records dropped at startup to corruption or sequence gaps;
    /// `None` on ephemeral servers.
    pub dropped_records: Option<usize>,
}

/// Thread-safe serving handle over a [`VectorStore`] plus an optional
/// embedding model — what [`crate::net`] routes `/v1/embed` and
/// `/v1/collections/...` to. See the module docs for the threading
/// model.
///
/// [`VectorStore`]: crate::index::VectorStore
pub struct IndexServer {
    backend: Option<EmbedBackend>,
    store: DurableStore,
    embeds: AtomicUsize,
    rows_added: AtomicUsize,
    queries: AtomicUsize,
}

impl IndexServer {
    fn from_parts(backend: Option<EmbedBackend>, store: DurableStore) -> IndexServer {
        IndexServer {
            backend,
            store,
            embeds: AtomicUsize::new(0),
            rows_added: AtomicUsize::new(0),
            queries: AtomicUsize::new(0),
        }
    }

    /// Vector-only index server (no embedding model): add and query take
    /// caller-supplied vectors; `/v1/embed` refuses. Ephemeral — restart
    /// loses the store (see [`IndexServer::open_durable`]).
    pub fn new(cfg: IndexConfig) -> Result<IndexServer, IndexError> {
        Ok(IndexServer::from_parts(None, DurableStore::ephemeral(cfg)?))
    }

    /// Vector-only index server persisting to `dcfg.data_dir`: recovery
    /// runs before the server accepts traffic (manifest + segment load,
    /// then WAL replay — see [`crate::index::durability`]), and every
    /// acknowledged add is WAL-logged first.
    pub fn open_durable(
        cfg: IndexConfig,
        dcfg: DurabilityConfig,
    ) -> Result<IndexServer, IndexError> {
        Ok(IndexServer::from_parts(None, DurableStore::open(cfg, dcfg)?))
    }

    /// Index server with an embedding backend: text/token requests embed
    /// through `manifest` + `params` (+ `packed` codes when supplied —
    /// the zero-dequant serving path). With `durability`, the store is
    /// recovered from and persisted to the data dir.
    pub fn with_embedder(
        cfg: IndexConfig,
        durability: Option<DurabilityConfig>,
        manifest: Manifest,
        params: ModelParams,
        packed: Option<PackedLayers>,
    ) -> Result<IndexServer> {
        let backend = EmbedBackend::new(manifest, params, packed)?;
        let store = match durability {
            Some(dcfg) => DurableStore::open(cfg, dcfg)?,
            None => DurableStore::ephemeral(cfg)?,
        };
        Ok(IndexServer::from_parts(Some(backend), store))
    }

    /// Embedding dimension, when an embedding backend is attached.
    pub fn embed_dim(&self) -> Option<usize> {
        self.backend.as_ref().map(EmbedBackend::dim)
    }

    /// Embed one token sequence: mean-pooled, L2-normalized final hidden
    /// states ([`NativeModel::embed`]). Sequences beyond the model
    /// window are truncated to its first `window()` tokens
    /// (deterministic, documented truncation — retrieval favors the
    /// document head). Typed errors: no backend, empty input, or
    /// out-of-vocab tokens.
    pub fn embed(&self, tokens: &[i32]) -> Result<Vec<f32>, IndexError> {
        let be = self.backend.as_ref().ok_or_else(|| {
            IndexError::BadQuery("this index server has no embedding model attached".into())
        })?;
        if tokens.is_empty() {
            return Err(IndexError::BadQuery("cannot embed an empty token sequence".into()));
        }
        let vocab = be.model.vocab;
        if let Some(&t) = tokens.iter().find(|&&t| t < 0 || t as usize >= vocab) {
            return Err(IndexError::BadQuery(format!(
                "token {t} outside vocabulary 0..{vocab}"
            )));
        }
        let take = tokens.len().min(be.model.seq_len);
        let out = be
            .model
            .embed(&be.manifest, &be.params, be.packed.as_ref(), &tokens[..take], 0)
            .map_err(|e| IndexError::Shape(format!("embed forward failed: {e}")))?;
        self.embeds.fetch_add(1, Ordering::Relaxed);
        Ok(out)
    }

    /// Append rows to a collection (created on first use): `vecs` is
    /// row-major with `d` columns. Returns `(first_id, rows_added)`.
    /// See [`crate::index::VectorStore::add`] for the budget-policy
    /// admission check. On a durable server the add is WAL-logged
    /// before this returns (fsync per the configured policy); queries
    /// keep running while the record — or a cadence seal it triggers —
    /// is being written.
    pub fn add(
        &self,
        name: &str,
        vecs: &[f32],
        d: usize,
    ) -> Result<(usize, usize), IndexError> {
        let out = self.store.add(name, vecs, d, 0)?;
        self.rows_added.fetch_add(out.1, Ordering::Relaxed);
        Ok(out)
    }

    /// [`IndexServer::add`] guarded by an expected first row id (the
    /// cluster router's exactly-once shard add — see
    /// [`crate::index::VectorStore::add_expect`]): refuses with a typed
    /// conflict, mutating nothing, when the collection's row count
    /// moved.
    pub fn add_expect(
        &self,
        name: &str,
        vecs: &[f32],
        d: usize,
        expect_first_id: usize,
    ) -> Result<(usize, usize), IndexError> {
        let out = self.store.add_expect(name, vecs, d, 0, expect_first_id)?;
        self.rows_added.fetch_add(out.1, Ordering::Relaxed);
        Ok(out)
    }

    /// Seal every non-empty head into an immutable segment and commit a
    /// new manifest generation (no-op on ephemeral servers). Exposed
    /// for orderly shutdown.
    pub fn seal_now(&self) -> Result<(), IndexError> {
        self.store.seal_now()
    }

    /// Run one compaction pass (merge small segments, rewrite
    /// stale-width files, seal heads — see
    /// [`DurableStore::compact_now`]). Returns whether any work
    /// happened.
    pub fn compact_now(&self) -> Result<bool, IndexError> {
        self.store.compact_now(0)
    }

    /// Spawn the background compactor: one [`IndexServer::compact_now`]
    /// pass every `interval`, until the returned handle is stopped (or
    /// dropped). Failures are logged and retried next tick — compaction
    /// is an optimization, never required for durability.
    pub fn start_compactor(self: &Arc<IndexServer>, interval: Duration) -> CompactorHandle {
        let stop = Arc::new(AtomicBool::new(false));
        let srv = Arc::clone(self);
        let flag = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("index-compactor".into())
            .spawn(move || loop {
                std::thread::park_timeout(interval);
                if flag.load(Ordering::Relaxed) {
                    return;
                }
                if let Err(e) = srv.store.compact_now(0) {
                    crate::info!("background compaction failed (will retry): {e}");
                }
            })
            .expect("spawning the index compactor thread");
        CompactorHandle { stop, thread: Some(thread) }
    }

    /// Startup recovery outcome; `None` on ephemeral servers.
    pub fn recovery(&self) -> Option<RecoveryReport> {
        self.store.recovery()
    }

    /// Two-phase top-k query against one collection (see
    /// [`crate::index::Collection::query`]). Takes only a store read
    /// lock — queries run concurrently with each other and with
    /// seal/compaction I/O.
    pub fn query(
        &self,
        name: &str,
        q: &[f32],
        k: usize,
        rerank_factor: usize,
    ) -> Result<Vec<SearchHit>, IndexError> {
        let t0 = trace::tracer().now_us();
        let hits = self.store.query(name, q, k, rerank_factor, 0)?;
        let dur = trace::tracer().now_us().saturating_sub(t0);
        obs::metrics().index_query_us.observe_us(dur);
        trace::record_ambient("index_query", t0, dur, hits.len() as i64);
        self.queries.fetch_add(1, Ordering::Relaxed);
        Ok(hits)
    }

    /// Phase-1 shard scan for the cluster's scatter-gather (see
    /// [`crate::index::Collection::scan_candidates`]): `(local_rows,
    /// local top-take estimated candidates)`. Counts as a query — each
    /// shard's participation in a distributed query shows up in its own
    /// serving counters.
    pub fn scan_candidates(
        &self,
        name: &str,
        q: &[f32],
        take: usize,
    ) -> Result<(usize, Vec<SearchHit>), IndexError> {
        let t0 = trace::tracer().now_us();
        let out = self.store.scan_candidates(name, q, take, 0)?;
        let dur = trace::tracer().now_us().saturating_sub(t0);
        obs::metrics().index_scan_us.observe_us(dur);
        trace::record_ambient("index_scan", t0, dur, out.1.len() as i64);
        self.queries.fetch_add(1, Ordering::Relaxed);
        Ok(out)
    }

    /// Phase-2 shard rerank for the cluster's scatter-gather (see
    /// [`crate::index::Collection::exact_scores`]): exact scores of
    /// `ids`, input order.
    pub fn exact_scores(
        &self,
        name: &str,
        q: &[f32],
        ids: &[usize],
    ) -> Result<Vec<SearchHit>, IndexError> {
        let t0 = trace::tracer().now_us();
        let out = self.store.exact_scores(name, q, ids)?;
        let dur = trace::tracer().now_us().saturating_sub(t0);
        obs::metrics().index_rerank_us.observe_us(dur);
        trace::record_ambient("index_rerank", t0, dur, ids.len() as i64);
        Ok(out)
    }

    /// Per-collection accounting snapshot, name order.
    pub fn collections(&self) -> Vec<CollectionInfo> {
        self.store.store().infos()
    }

    /// Aggregate serving counters + store accounting (+ the recovery
    /// outcome on durable servers).
    pub fn stats(&self) -> IndexServerStats {
        // engine-side facts first, store read lock second — never both
        // at once (writers take engine then store; overlapping the
        // other way here could deadlock)
        let durable = self.store.is_durable();
        let read_only = self.store.is_read_only();
        let recovery = self.store.recovery();
        let compactions = self.store.compactions();
        let (collections, rows, code_bytes, segments, head_rows) = {
            let s = self.store.store();
            (s.len(), s.rows(), s.code_bytes(), s.segments(), s.head_rows())
        };
        IndexServerStats {
            embeds: self.embeds.load(Ordering::Relaxed),
            rows_added: self.rows_added.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            collections,
            rows,
            code_bytes,
            segments,
            head_rows,
            compactions,
            durable,
            read_only,
            recovered_rows: recovery.map(|r| r.recovered_rows()),
            dropped_records: recovery.map(|r| r.dropped_records),
        }
    }
}

/// Handle to the background compactor thread spawned by
/// [`IndexServer::start_compactor`]. Stopping (or dropping) the handle
/// wakes the thread and joins it; the in-flight pass, if any, runs to
/// completion first (compaction commits are atomic — there is no
/// partial state to interrupt).
pub struct CompactorHandle {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl CompactorHandle {
    /// Signal the compactor to exit and wait for it.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            t.thread().unpark();
            let _ = t.join();
        }
    }
}

impl Drop for CompactorHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::durability::FsyncPolicy;
    use crate::index::io::{Fault, FaultIo, MemIo};
    use crate::index::{IndexPolicy, Metric};
    use crate::model::synthetic_manifest;
    use crate::quant::{LayerCalib, TrickConfig};
    use crate::runtime::native::native_init;
    use std::path::PathBuf;
    use std::time::Instant;

    fn embed_fixture(seed: u64) -> IndexServer {
        let manifest = synthetic_manifest("idx-serve", 32, 1, 2, 64, 16, 256, 1);
        let params = native_init(&manifest, seed);
        let stats: Vec<LayerCalib> =
            manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
        let bits = vec![4u8; manifest.linears.len()];
        let packed = PackedLayers::quantize(
            &manifest, &params, &bits, &stats, &TrickConfig::none(), seed, 1,
        )
        .unwrap();
        IndexServer::with_embedder(
            IndexConfig::default(),
            None,
            manifest,
            params,
            Some(packed),
        )
        .unwrap()
    }

    #[test]
    fn embed_add_query_round_trip() {
        let srv = embed_fixture(3);
        let d = srv.embed_dim().unwrap();
        // three "documents" (byte-token sequences), then self-retrieval
        let docs: Vec<Vec<i32>> = vec![
            (0..10).map(|i| (i * 7 % 256) as i32).collect(),
            (0..10).map(|i| (i * 13 % 256) as i32).collect(),
            (0..10).map(|i| (i * 29 % 256) as i32).collect(),
        ];
        for doc in &docs {
            let e = srv.embed(doc).unwrap();
            assert_eq!(e.len(), d);
            srv.add("docs", &e, d).unwrap();
        }
        let probe = srv.embed(&docs[1]).unwrap();
        let hits = srv.query("docs", &probe, 2, 4).unwrap();
        assert_eq!(hits[0].id, 1, "a document must retrieve itself");
        assert!((hits[0].score - 1.0).abs() < 1e-4, "cosine self-score ~1");
        let stats = srv.stats();
        assert_eq!(stats.embeds, 4);
        assert_eq!(stats.rows_added, 3);
        assert_eq!(stats.queries, 1);
        assert_eq!(stats.collections, 1);
        assert_eq!(stats.rows, 3);
        assert_eq!(stats.head_rows, 3, "nothing sealed on an ephemeral server");
        assert_eq!(stats.segments, 0);
        assert_eq!(stats.compactions, 0);
        assert!(stats.code_bytes > 0);
    }

    #[test]
    fn embed_truncates_long_contexts_and_rejects_bad_tokens() {
        let srv = embed_fixture(5);
        // longer than the window: truncates to the first seq_len tokens
        let long: Vec<i32> = (0..64).map(|i| (i % 256) as i32).collect();
        let head: Vec<i32> = long[..16].to_vec(); // fixture seq_len = 16
        assert_eq!(srv.embed(&long).unwrap(), srv.embed(&head).unwrap());
        assert!(matches!(srv.embed(&[]), Err(IndexError::BadQuery(_))));
        assert!(matches!(srv.embed(&[300]), Err(IndexError::BadQuery(_))));
        assert!(matches!(srv.embed(&[-1]), Err(IndexError::BadQuery(_))));
    }

    #[test]
    fn vector_only_server_serves_without_embedder() {
        let srv = IndexServer::new(IndexConfig {
            policy: IndexPolicy::Uniform(8),
            metric: Metric::Cosine,
            ..Default::default()
        })
        .unwrap();
        assert!(srv.embed_dim().is_none());
        assert!(matches!(srv.embed(&[1, 2]), Err(IndexError::BadQuery(_))));
        let v: Vec<f32> = (0..8).map(|i| i as f32).collect();
        srv.add("raw", &v, 8).unwrap();
        let hits = srv.query("raw", &v, 1, 4).unwrap();
        assert_eq!(hits[0].id, 0);
        // typed errors pass through the serving layer untouched
        assert!(matches!(
            srv.query("nope", &v, 1, 4),
            Err(IndexError::NoSuchCollection(_))
        ));
    }

    #[test]
    fn concurrent_adds_and_queries_are_safe() {
        let srv = Arc::new(IndexServer::new(IndexConfig::default()).unwrap());
        let d = 16usize;
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = Arc::clone(&srv);
            handles.push(std::thread::spawn(move || {
                let vecs = crate::rng::Rng::new(t).gaussian_vec(8 * d);
                s.add("conc", &vecs, d).unwrap();
                let q = crate::rng::Rng::new(100 + t).gaussian_vec(d);
                for _ in 0..4 {
                    let _ = s.query("conc", &q, 3, 4);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.stats().rows, 32);
    }

    #[test]
    fn query_completes_while_a_slow_seal_is_in_flight() {
        // the PR-8 headline regression: under the old single store
        // mutex, a query issued during snapshot I/O waited for the
        // whole write. Delay the seal's segment write (global write
        // ordinal 3: two WAL appends precede it) and assert a
        // concurrent query returns promptly anyway.
        let d = 16usize;
        let dcfg = DurabilityConfig {
            data_dir: PathBuf::from("/idx"),
            fsync: FsyncPolicy::Never,
            snapshot_every: 2,
            segment_rows: 0,
        };
        let io = FaultIo::new(MemIo::new(), Fault::SlowWrite { nth: 3, millis: 500 });
        let store =
            DurableStore::open_with(IndexConfig::default(), dcfg, Box::new(io)).unwrap();
        let srv = Arc::new(IndexServer::from_parts(None, store));
        let v0 = crate::rng::Rng::new(1).gaussian_vec(d);
        srv.add("a", &v0, d).unwrap(); // write 1: WAL append
        let s2 = Arc::clone(&srv);
        let slow_add = std::thread::spawn(move || {
            let t = Instant::now();
            // write 2: WAL append; rows cadence fires → seal: write 3
            // is the segment file, slowed 500 ms
            s2.add("a", &crate::rng::Rng::new(2).gaussian_vec(d), d).unwrap();
            t.elapsed()
        });
        std::thread::sleep(Duration::from_millis(100)); // let the seal start
        let t = Instant::now();
        let hits = srv.query("a", &v0, 1, 4).unwrap();
        let query_elapsed = t.elapsed();
        assert_eq!(hits[0].id, 0, "self-retrieval mid-seal");
        let add_elapsed = slow_add.join().unwrap();
        assert!(
            add_elapsed >= Duration::from_millis(400),
            "the seal really was slowed: {add_elapsed:?}"
        );
        assert!(
            query_elapsed < Duration::from_millis(250),
            "a query must not serialize behind seal I/O: {query_elapsed:?}"
        );
        // and the seal completed normally despite the slow write
        let stats = srv.stats();
        assert_eq!(stats.segments, 1);
        assert_eq!(stats.head_rows, 0);
    }

    #[test]
    fn background_compactor_merges_on_its_own() {
        let d = 8usize;
        let dcfg = DurabilityConfig {
            data_dir: PathBuf::from("/idx"),
            fsync: FsyncPolicy::Never,
            snapshot_every: 1, // every 1-row add seals its own segment
            segment_rows: 0,
        };
        let store =
            DurableStore::open_with(IndexConfig::default(), dcfg, Box::new(MemIo::new()))
                .unwrap();
        let srv = Arc::new(IndexServer::from_parts(None, store));
        for seed in 0..4u64 {
            srv.add("a", &crate::rng::Rng::new(seed).gaussian_vec(d), d).unwrap();
        }
        assert_eq!(srv.stats().segments, 4);
        let compactor = srv.start_compactor(Duration::from_millis(10));
        let deadline = Instant::now() + Duration::from_secs(5);
        while srv.stats().compactions == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        compactor.stop();
        let stats = srv.stats();
        assert_eq!(stats.compactions, 1, "one pass merged everything; later ticks are no-ops");
        assert_eq!(stats.segments, 1);
        assert_eq!(stats.rows, 4);
    }
}
