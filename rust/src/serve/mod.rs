//! Batching inference server: the L3 request path over quantized weights.
//!
//! Architecture (vLLM-router-style, scaled to this repo): callers submit
//! [`Request`]s to a [`Server`] handle; a batcher thread drains the queue,
//! packs up to `eval_batch` prompts into one fixed-shape `fwd_logits`
//! execution, samples one token per sequence, and re-queues unfinished
//! sequences — continuous batching over a fixed window. Python is never on
//! this path; the weights are the (de)quantized parameters.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::Result;

use crate::model::{Manifest, ModelParams};
use crate::runtime::{ModelRuntime, PackedLayers};
use crate::util::percentile;

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Greedy if 0.0, else temperature sampling with this temperature.
    pub temperature: f32,
    pub seed: u64,
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub latency_secs: f64,
    /// Number of batch steps this request rode in.
    pub steps: usize,
}

struct Active {
    req: Request,
    generated: Vec<i32>,
    submitted: Instant,
    steps: usize,
    done_tx: mpsc::Sender<Completion>,
}

struct Shared {
    queue: Mutex<VecDeque<Active>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
}

/// Server handle. Dropping it stops the batcher thread.
pub struct Server {
    shared: Arc<Shared>,
    worker: Option<thread::JoinHandle<Result<ServerStats>>>,
    next_id: Mutex<u64>,
}

/// Aggregate metrics reported on shutdown.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub completions: usize,
    pub batch_steps: usize,
    pub total_rows: usize,
    pub tokens_generated: usize,
    pub latencies: Vec<f64>,
    pub wall_secs: f64,
}

impl ServerStats {
    pub fn mean_batch_occupancy(&self, batch: usize) -> f64 {
        if self.batch_steps == 0 {
            return 0.0;
        }
        self.total_rows as f64 / (self.batch_steps * batch) as f64
    }

    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.wall_secs
    }

    pub fn p50_latency(&self) -> f64 {
        percentile(&self.latencies, 50.0)
    }

    pub fn p95_latency(&self) -> f64 {
        percentile(&self.latencies, 95.0)
    }
}

fn softmax_sample(logits: &[f32], temperature: f32, seed: u64, step: usize) -> i32 {
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap_or(0);
    }
    let mut rng = crate::rng::Rng::new(seed ^ (step as u64).wrapping_mul(0x9E37));
    let maxl = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let exps: Vec<f64> = logits
        .iter()
        .map(|&x| (((x - maxl) / temperature) as f64).exp())
        .collect();
    let mut cum = Vec::with_capacity(exps.len());
    let mut acc = 0.0;
    for e in exps {
        acc += e;
        cum.push(acc);
    }
    rng.sample_cumulative(&cum) as i32
}

impl Server {
    /// Start a server over `params` (typically quantized weights).
    ///
    /// PJRT handles are not `Send`, so the batcher thread constructs its
    /// own runtime via `factory` (e.g. `|| ModelRuntime::load(...)` with a
    /// fresh `Runtime::cpu()`); `params` moves into the thread. The fixed
    /// window is the model's `seq_len` and the batch is `eval_batch`.
    pub fn start<F>(factory: F, params: ModelParams) -> Server
    where
        F: FnOnce() -> Result<ModelRuntime> + Send + 'static,
    {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let s2 = Arc::clone(&shared);
        let worker = thread::spawn(move || {
            let mrt = factory()?;
            batcher_loop(s2, mrt, params)
        });
        Server { shared, worker: Some(worker), next_id: Mutex::new(1) }
    }

    /// Serve from resident packed weights on the native backend: the
    /// batcher's `fwd_logits` computes directly on RaBitQ codes via
    /// `qgemm` — no AOT artifacts, no dense weight reads, zero
    /// dequantization on the request path.
    pub fn start_native_packed(
        manifest: Manifest,
        params: ModelParams,
        packed: PackedLayers,
    ) -> Server {
        Server::start(
            move || {
                let mut mrt = ModelRuntime::native(manifest)?;
                mrt.attach_packed(packed)?;
                Ok(mrt)
            },
            params,
        )
    }

    /// Submit a request; returns a receiver for the completion.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        temperature: f32,
        seed: u64,
    ) -> (u64, mpsc::Receiver<Completion>) {
        let id = {
            let mut g = self.next_id.lock().unwrap();
            let id = *g;
            *g += 1;
            id
        };
        let (tx, rx) = mpsc::channel();
        let act = Active {
            req: Request { id, prompt, max_new_tokens, temperature, seed },
            generated: Vec::new(),
            submitted: Instant::now(),
            steps: 0,
            done_tx: tx,
        };
        self.shared.queue.lock().unwrap().push_back(act);
        self.shared.cv.notify_one();
        (id, rx)
    }

    /// Stop the batcher (after draining) and collect stats.
    pub fn shutdown(mut self) -> Result<ServerStats> {
        {
            let mut s = self.shared.shutdown.lock().unwrap();
            *s = true;
        }
        self.shared.cv.notify_all();
        let handle = self.worker.take().expect("not yet shut down");
        handle.join().map_err(|_| anyhow::anyhow!("batcher panicked"))?
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if self.worker.is_some() {
            {
                let mut s = self.shared.shutdown.lock().unwrap();
                *s = true;
            }
            self.shared.cv.notify_all();
            if let Some(h) = self.worker.take() {
                let _ = h.join();
            }
        }
    }
}

fn batcher_loop(
    shared: Arc<Shared>,
    mrt: ModelRuntime,
    params: ModelParams,
) -> Result<ServerStats> {
    let m = &mrt.manifest;
    let (batch, seq) = (m.eval_batch, m.seq_len);
    let mut stats = ServerStats::default();
    let start = Instant::now();

    loop {
        // grab up to `batch` active requests
        let mut work: Vec<Active> = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if !q.is_empty() {
                    break;
                }
                if *shared.shutdown.lock().unwrap() {
                    stats.wall_secs = start.elapsed().as_secs_f64();
                    return Ok(stats);
                }
                let (guard, _) = shared
                    .cv
                    .wait_timeout(q, std::time::Duration::from_millis(20))
                    .unwrap();
                q = guard;
            }
            let take = q.len().min(batch);
            q.drain(..take).collect()
        };

        // pack the fixed-shape window: right-align (prompt + generated),
        // left-pad with zeros, last real token at position seq-1
        let mut tokens = vec![0i32; batch * seq];
        for (row, act) in work.iter().enumerate() {
            let mut ctx: Vec<i32> = act
                .req
                .prompt
                .iter()
                .chain(act.generated.iter())
                .copied()
                .collect();
            if ctx.len() > seq {
                ctx.drain(..ctx.len() - seq);
            }
            let off = row * seq + (seq - ctx.len());
            tokens[off..row * seq + seq].copy_from_slice(&ctx);
        }

        let logits = mrt.last_logits(&params, &tokens)?;
        let vocab = m.vocab;
        stats.batch_steps += 1;
        stats.total_rows += work.len();

        // sample, update, re-queue or complete
        for (row, mut act) in work.drain(..).enumerate() {
            let l = &logits[row * vocab..(row + 1) * vocab];
            let tok = softmax_sample(l, act.req.temperature, act.req.seed, act.steps);
            act.generated.push(tok);
            act.steps += 1;
            stats.tokens_generated += 1;
            if act.generated.len() >= act.req.max_new_tokens {
                let latency = act.submitted.elapsed().as_secs_f64();
                stats.latencies.push(latency);
                stats.completions += 1;
                let _ = act.done_tx.send(Completion {
                    id: act.req.id,
                    tokens: act.generated,
                    latency_secs: latency,
                    steps: act.steps,
                });
            } else {
                shared.queue.lock().unwrap().push_back(act);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_sampling_is_argmax() {
        let logits = vec![0.1f32, 2.0, -1.0, 1.9];
        assert_eq!(softmax_sample(&logits, 0.0, 0, 0), 1);
    }

    #[test]
    fn temperature_sampling_in_range_and_seeded() {
        let logits = vec![0.0f32; 16];
        let a = softmax_sample(&logits, 1.0, 42, 3);
        let b = softmax_sample(&logits, 1.0, 42, 3);
        assert_eq!(a, b);
        assert!((0..16).contains(&a));
    }

    #[test]
    fn native_packed_server_generates_tokens() {
        use crate::model::synthetic_manifest;
        use crate::quant::{LayerCalib, TrickConfig};
        use crate::runtime::{native_init, PackedLayers};

        let manifest = synthetic_manifest("serve-native", 32, 1, 2, 64, 8, 256, 2);
        let params = native_init(&manifest, 17);
        let stats: Vec<LayerCalib> =
            manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
        let bits = vec![4u8; manifest.linears.len()];
        let packed = PackedLayers::quantize(
            &manifest, &params, &bits, &stats, &TrickConfig::none(), 1, 1,
        )
        .unwrap();
        let server = Server::start_native_packed(manifest, params, packed);
        let (_, rx) = server.submit(vec![1, 2, 3], 4, 0.0, 0);
        let c = rx.recv().unwrap();
        assert_eq!(c.tokens.len(), 4);
        assert!(c.tokens.iter().all(|&t| (0..256).contains(&t)));
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.completions, 1);
        assert_eq!(stats.tokens_generated, 4);
    }

    #[test]
    fn stats_math() {
        let s = ServerStats {
            completions: 2,
            batch_steps: 4,
            total_rows: 12,
            tokens_generated: 40,
            latencies: vec![0.1, 0.2],
            wall_secs: 2.0,
        };
        assert!((s.mean_batch_occupancy(4) - 0.75).abs() < 1e-12);
        assert!((s.throughput_tok_s() - 20.0).abs() < 1e-12);
        assert!(s.p95_latency() >= s.p50_latency());
    }
}
